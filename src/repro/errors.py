"""Structured serving-error taxonomy (fault-tolerant serving, ISSUE 6).

Every failure the serving stack can produce is an ``EngineError`` subclass
carrying the request id it concerns (when there is one) plus free-form
``context`` fields, so callers can route failures per request instead of
tearing the engine down.  The contract enforced by the chaos suite
(``tests/test_faults.py``) is:

  * no *unstructured* exception ever escapes ``Engine.step()`` — anything
    unexpected is wrapped in ``InternalError`` (with ``__cause__`` kept);
  * failures attributable to one request (bad sampling params, NaN logits,
    deadline miss, allocation starvation with no recourse) fail *that*
    request (``Status.FAILED``, pages released) while the rest of the
    batch keeps decoding;
  * admission-time rejections are ``Backpressure`` — a structured
    "try again later" with a retry hint, never silent queue growth.

Several classes double-inherit the builtin exception their call site used
to raise (``ValueError`` / ``RuntimeError``): the taxonomy is a refinement
of the old surface, not a break — ``except ValueError`` call sites and the
pre-existing tests keep working.

This module sits below both ``core`` (allocator) and ``serving`` so either
layer may raise structured errors without an import cycle.
"""

from __future__ import annotations

from typing import Optional


class EngineError(Exception):
    """Base of every structured serving failure.

    Attributes:
      rid:      request id the failure concerns, or None for engine-level
                failures (e.g. a transient device error on the whole step).
      context:  free-form keyword details (resource, limit, observed, ...).
    """

    def __init__(self, message: str = "", *, rid: Optional[int] = None,
                 **context):
        self.message = message
        self.rid = rid
        self.context = context
        super().__init__(message)

    def __str__(self) -> str:  # "<msg> [rid=3 resource=pages]"
        tail = []
        if self.rid is not None:
            tail.append(f"rid={self.rid}")
        tail += [f"{k}={v}" for k, v in self.context.items()]
        return self.message + (f" [{' '.join(tail)}]" if tail else "")


class InvalidRequest(EngineError, ValueError):
    """The request is malformed (bad sampling params, bad shape): rejected
    at ``add_request`` time, before it holds any resources."""


class EngineConfigError(EngineError, ValueError):
    """A build-time configuration is unusable: invalid engine/scheduler
    knob values, an unknown kernel backend or combine mode, an unknown
    model family / layer code / activation.  Raised while constructing the
    stack (never mid-step), before any request holds resources."""


class UnsupportedFeature(EngineError, NotImplementedError):
    """A structurally valid configuration asks for a combination the
    current implementation does not support yet (e.g. chunked prefill
    through recurrent layer families).  Distinct from ``EngineConfigError``:
    the config is legal, the capability is missing — callers can fall back
    (the engine drops to monolithic prefill paths) instead of erroring."""


class DistributedSetupError(EngineError, RuntimeError):
    """The distributed layer cannot resolve its environment: a named mesh
    axis is undefined, no mesh context is active where one is required.
    Raised at trace/setup time by ``repro.distributed``, not mid-collective."""


class RequestTooLong(InvalidRequest):
    """prompt + max_new_tokens exceeds the engine's ``max_seq_len`` (also
    raised for forks whose child would outgrow the device block table)."""


class PoolExhausted(EngineError, RuntimeError):
    """A page/slot reservation could not be served and no preemption
    candidate exists — the starved *request* fails; the engine lives on."""


class NumericsError(EngineError):
    """The numerics guard found non-finite (NaN/Inf) logits in this
    request's row.  The poisoned request fails; co-batched rows are
    unaffected (per-row isolation is gated by ``tests/test_faults.py``)."""


class SchedulerInvariantError(EngineError, RuntimeError):
    """An internal scheduler/allocator invariant broke: double free,
    free of an unknown rid, a block-table row outgrowing the device
    table.  Indicates a bug (or an injected allocator fault), never user
    error — surfaced loudly instead of silently corrupting the free list."""


class DeadlineExceeded(EngineError):
    """The request ran past its ``deadline_steps`` (or produced no first
    token within ``ttft_deadline_steps``) and was failed by the scheduler."""


class TransientDeviceError(EngineError):
    """A (possibly injected) transient device failure on a prefill/decode
    dispatch.  ``Engine.step`` retries the dispatch with backoff up to
    ``max_step_retries`` times before letting this escape."""


class InternalError(EngineError, RuntimeError):
    """Wrapper for any *unstructured* exception caught escaping
    ``Engine.step()`` — keeps the original as ``__cause__``."""


class Backpressure(EngineError):
    """Structured admission rejection (bounded queue full, or pool above
    the admission high-watermark).  Carries a retry hint so clients can
    back off instead of hammering a saturated engine.

    Attributes:
      reason:            "queue_full" | "pool_watermark"
      retry_after_steps: engine-step estimate before retrying is useful
      queue_depth:       waiting-queue length at rejection time
      pool_util:         pool utilisation in [0, 1] at rejection time
    """

    def __init__(self, message: str = "", *, reason: str = "queue_full",
                 retry_after_steps: int = 1, queue_depth: int = 0,
                 pool_util: float = 0.0, **context):
        super().__init__(message, reason=reason,
                         retry_after_steps=retry_after_steps,
                         queue_depth=queue_depth,
                         pool_util=round(pool_util, 4), **context)
        self.reason = reason
        self.retry_after_steps = retry_after_steps
        self.queue_depth = queue_depth
        self.pool_util = pool_util
