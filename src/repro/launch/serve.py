"""Serving launcher: continuous-batching engine over the paged KV cache.

Runs the full engine loop (admission → prefill → paged decode → sampling)
on CPU with a reduced config; on TPU the same engine runs with
``impl="pallas"`` and the mesh-sharded decode schemes.

Usage:
  python -m repro.launch.serve --arch granite-8b --smoke --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serving import Engine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--pool-tokens", type=int, default=None,
                    help="oversubscribe the page pool (paper's memory win)")
    ap.add_argument("--no-paged", action="store_true",
                    help="contiguous baseline (the paper's comparison)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    eng = Engine(cfg, max_slots=args.max_slots, max_seq_len=args.max_seq_len,
                 pool_tokens=args.pool_tokens, paged=not args.no_paged)

    rng = np.random.default_rng(0)
    reqs, extras = [], []
    for i in range(args.requests):
        plen = int(rng.integers(4, args.max_seq_len - args.max_new))
        prompt = rng.integers(0, min(cfg.vocab_size, 256),
                              size=plen).tolist()
        reqs.append(Request(prompt=prompt, max_new_tokens=args.max_new,
                            temperature=args.temperature))
        extra = None
        if cfg.family == "vlm":
            extra = {"image_embeds": rng.standard_normal(
                (cfg.n_image_tokens, cfg.d_vision), np.float32)}
        elif cfg.family == "encdec":
            extra = {"frames": rng.standard_normal(
                (cfg.n_audio_frames, cfg.d_model), np.float32)}
        extras.append(extra)

    t0 = time.perf_counter()
    eng.generate(reqs, extras=extras)
    wall = time.perf_counter() - t0
    total_new = sum(len(r.output) for r in reqs)
    print(f"\n{args.requests} requests, {total_new} tokens in {wall:.1f}s "
          f"({total_new/wall:.1f} tok/s aggregate)")
    print(f"engine steps: {eng.steps}  preemptions: "
          f"{eng.scheduler.preempted}")
    mr = eng.memory_report()
    print(f"kv pool {mr['pool_bytes']/2**20:.1f} MiB; overhead vs "
          f"theoretical min: {mr['overhead_frac']*100:.1f}%")
    for r in reqs[:4]:
        print(f"  req {r.rid}: prompt {r.prompt_len} -> {len(r.output)} new, "
              f"ttft {r.metrics.get('ttft_s', -1):.3f}s")


if __name__ == "__main__":
    main()
