"""Training launcher.

On real hardware this runs the pjit-sharded train step on the production
mesh; on this CPU container it runs the same code path end-to-end on a
1-device mesh (reduced configs) — the multi-pod mesh is exercised by
``dryrun.py`` (lower+compile only).

Usage:
  python -m repro.launch.train --arch granite-8b --smoke --steps 200
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data import synthetic_batches
from repro.distributed.sharding import use_mesh
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import (abstract_batch, batch_shardings, build_step,
                                plan_for)
from repro.models.api import build_model
from repro.training.checkpoint import save
from repro.training.state import TrainState


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (needs 256 devices)")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    run = RunConfig(model=cfg, seq_len=args.seq, global_batch=args.batch,
                    kind="train")
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    plan = plan_for(run, mesh, attn_impl="jnp" if args.smoke else "chunked")
    step, abstract, shardings, model = build_step(run, plan,
                                                  dtype=jnp.float32)

    with use_mesh(mesh, plan.rules):
        params = model.init_params(jax.random.PRNGKey(0))
        state = TrainState.create(params)
        jstep = jax.jit(step, in_shardings=(shardings["state"],
                                            shardings["batch"]),
                        donate_argnums=(0,))
        data = synthetic_batches(args.batch, args.seq, cfg.vocab_size,
                                 cfg=cfg)
        t0 = time.perf_counter()
        for i, batch in enumerate(data):
            if i >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = jstep(state, batch)
            if (i + 1) % args.log_every == 0:
                print(f"step {i+1:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({time.perf_counter()-t0:.1f}s)", flush=True)
    if args.checkpoint:
        save(args.checkpoint, state.params)
        print("saved", args.checkpoint)


if __name__ == "__main__":
    main()
