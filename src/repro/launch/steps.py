"""Step factories + sharding plans for every (run × mesh) combination.

This is the single source of truth the multi-pod dry-run, the train/serve
drivers, and the roofline harness all share: given a ``RunConfig`` and a
mesh, build

  * the jit-able step function (train / prefill / serve),
  * abstract inputs (ShapeDtypeStructs — no device allocation),
  * in/out shardings for every input,

so ``jax.jit(fn, in_shardings=...).lower(**abstract).compile()`` is the
whole dry-run.

Sharding plan summary (DESIGN.md §4):
  train/prefill — GSPMD: batch over ("pod","data"), sequence-parallel
    activations over "model" between blocks, TP weights over "model",
    FSDP "embed" over "data" for ≥8B models (config override).
  decode — shard_map schemes: "tp" (kv heads over model), "dp" (bounded
    ring pools, kv replicated), "kvp" (pages striped over model,
    flash-decoding psum combine).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import (AxisRules, DEFAULT_RULES,
                                        make_param_shardings, use_mesh)
from repro.models.api import build_model
from repro.training.loop import make_train_step
from repro.training.optimizer import AdamWState
from repro.training.state import TrainState


@dataclass
class Plan:
    run: RunConfig
    mesh: Mesh
    rules: AxisRules
    batch_axes: Tuple[str, ...]
    scheme: str  # decode distribution scheme: local | tp | dp | kvp
    kv_axes: Tuple[str, ...]
    microbatches: int
    attn_impl: str
    zero_pod: bool = False
    notes: Dict[str, Any] = field(default_factory=dict)

    @property
    def n_kv_shards(self) -> int:
        return _mesh_prod(self.mesh, self.kv_axes) if self.scheme == "kvp" else 1


def _mesh_prod(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[a] for a in axes) if axes else 1


def plan_for(run: RunConfig, mesh: Mesh, *,
             microbatches: Optional[int] = None,
             attn_impl: str = "chunked",
             scheme: Optional[str] = None,
             seq_parallel: bool = True,
             ws_decode: bool = False,
             ring: bool = False,
             zero_pod: bool = False) -> Plan:
    cfg = run.model
    rules = DEFAULT_RULES.extend(**cfg.axis_overrides)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_size = sizes.get("model", 1)

    # batch axes: use only as much of (pod, data) as the batch divides
    cand = tuple(a for a in ("pod", "data") if a in sizes)
    batch_axes: Tuple[str, ...] = ()
    prod = 1
    for a in cand:
        if run.global_batch % (prod * sizes[a]) == 0:
            batch_axes += (a,)
            prod *= sizes[a]
    if ws_decode and run.kind == "decode":
        # weight-stationary decode (§Perf H3): keep the 2D-sharded weights
        # in place and psum small activation partials over "data" instead
        # of all-gathering FSDP weight shards every token
        batch_axes = ()
        prod = 1
        rules = rules.extend(batch=None, act_embed=("data",))
    else:
        rules = rules.extend(batch=batch_axes or None)

    if ring and run.kind in ("train", "prefill") \
            and run.seq_len % model_size == 0:
        # ring attention (§Perf H2): activations stay seq-sharded through
        # attention. For inference (no weight grads) q/k/v keep full heads
        # — GSPMD gathers the MB-scale weight shards instead of the
        # GB-scale activations. For training, replicated qkv weights would
        # un-shard their f32 gradients (+66 GiB/dev at 405B — measured,
        # `--tag ring_train`); keep heads sharded and let GSPMD insert the
        # head↔seq all-to-all at the ring boundary (Ulysses-style).
        rules = rules.extend(seq=("model",), attn_seq=("model",))
        if run.kind == "prefill":
            rules = rules.extend(heads=None, kv_heads=None)
        attn_impl = "ring"

    if run.kind == "train":
        # sequence parallelism: activations shard over "model" between blocks
        if seq_parallel and run.seq_len % model_size == 0:
            rules = rules.extend(seq=("model",))
        if microbatches is None:
            # keep per-device f32 logits under ~256 MB
            vocab_shards = model_size if cfg.vocab_size % model_size == 0 else 1
            per_dev = (run.global_batch * run.seq_len // max(prod, 1)
                       * cfg.vocab_size // vocab_shards * 4)
            microbatches = 1
            while per_dev / microbatches > 256e6 and \
                    run.global_batch % (microbatches * 2 * prod) == 0:
                microbatches *= 2
        sch = "n/a"
        kv_axes: Tuple[str, ...] = ()
    else:
        if (run.kind == "prefill" and seq_parallel
                and run.seq_len % model_size == 0):
            rules = rules.extend(seq=("model",))
        microbatches = 1
        if run.kind == "prefill":
            # prefill pools: pages × batch-axes, head_dim × "model" — the
            # layout write_prefill_sharded scatters into locally; decode's
            # kvp striping is a phase-boundary reshard (DESIGN.md §4)
            return Plan(run=run, mesh=mesh, rules=rules,
                        batch_axes=batch_axes, scheme="prefill_local",
                        kv_axes=(), microbatches=1, attn_impl=attn_impl)
        window = cfg.window if "W" in cfg.pattern() else 0
        sch = scheme or cfg.decode_scheme
        if sch in ("auto", "n/a"):
            if cfg.n_kv_heads % model_size == 0:
                sch = "tp"
            elif window > 0:
                sch = "dp"
            else:
                sch = "kvp"
        if sch == "tp" and cfg.n_kv_heads % model_size != 0:
            sch = "kvp"
        if sch == "kvp" and window > 0:
            sch = "dp"
        kv_axes = (tuple(a for a in mesh.axis_names if a not in batch_axes)
                   if sch == "kvp" else ())

    return Plan(run=run, mesh=mesh, rules=rules, batch_axes=batch_axes,
                scheme=sch, kv_axes=kv_axes, microbatches=microbatches,
                attn_impl=attn_impl, zero_pod=zero_pod)


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------
def _ns(plan: Plan, *axes) -> NamedSharding:
    return NamedSharding(plan.mesh, P(*axes))


def _param_shardings(model, plan: Plan, dtype):
    return make_param_shardings(plan.mesh, plan.rules, model.param_axes(),
                                model.abstract_params(dtype))


def train_state_shardings(model, plan: Plan, dtype=jnp.bfloat16,
                          zero_pod: bool = False):
    p = _param_shardings(model, plan, dtype)
    scalar = _ns(plan)
    mom = p
    if zero_pod and "pod" in plan.mesh.axis_names:
        # ZeRO-1 over the pod axis: optimizer moments additionally shard
        # their "embed" dim across pods (params stay pod-replicated; the
        # update's reshard is the classic ZeRO gather, amortised per step)
        emb = tuple(plan.rules.physical("embed") or ())
        if "pod" not in emb:
            mom_rules = plan.rules.extend(embed=("pod",) + emb)
            mom = make_param_shardings(plan.mesh, mom_rules,
                                       model.param_axes(),
                                       model.abstract_params(dtype))
    return TrainState(params=p, opt=AdamWState(mu=mom, nu=mom, count=scalar),
                      step=scalar)


def batch_shardings(run: RunConfig, plan: Plan) -> Dict[str, NamedSharding]:
    ba = plan.batch_axes or None
    out = {"inputs": _ns(plan, ba, None), "targets": _ns(plan, ba, None)}
    cfg = run.model
    if cfg.family == "vlm":
        out["image_embeds"] = _ns(plan, ba, None, None)
    if cfg.family == "encdec":
        out["frames"] = _ns(plan, ba, None, None)
    return out


def decode_state_shardings(model, plan: Plan, state_abstract) -> Dict:
    """Shardings for the decode/prefill state dict, keyed like the state."""
    ba = plan.batch_axes or None
    page_axes: Tuple[str, ...] = tuple(plan.batch_axes)
    if plan.scheme == "kvp":
        page_axes += plan.kv_axes
    pa = page_axes or None
    kv = plan.kv_axes or None

    out: Dict[str, Any] = {}
    for key, val in state_abstract.items():
        if key == "pos":
            out[key] = _ns(plan, ba)
        elif key in ("k_pages", "v_pages"):
            if plan.scheme == "prefill_local":
                # pages × batch axes, head_dim × model (shard-local writes)
                msz = (_mesh_prod(plan.mesh, ("model",))
                       if "model" in plan.mesh.axis_names else 0)
                hd = "model" if msz and val.shape[-1] % msz == 0 else None
                out[key] = _ns(plan, None, pa, None, None, hd)
                continue
            # tp: kv-head dim over "model"; kvp: pages striped over kv axes
            kvh = "model" if plan.scheme == "tp" else None
            out[key] = _ns(plan, None, pa, None, kvh, None)
        elif key == "tables":
            out[key] = _ns(plan, ba, kv if plan.scheme == "kvp" else None,
                           None)
        elif key in ("cross_k", "cross_v"):
            out[key] = _ns(plan, None, ba, None, None, None)
        elif key in ("k_buf", "v_buf"):
            out[key] = _ns(plan, None, ba, None, None, None)
        elif key == "rec":
            out[key] = jax.tree_util.tree_map(
                lambda a: _ns(plan, None, ba,
                              *(None,) * (len(a.shape) - 2)), val)
        else:
            out[key] = _ns(plan)
    return out


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def abstract_batch(run: RunConfig, dtype=jnp.bfloat16) -> Dict[str, Any]:
    cfg = run.model
    B, S = run.global_batch, run.seq_len
    out = {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32),
           "targets": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_vision), dtype)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), dtype)
    return out


def abstract_train_state(model, dtype=jnp.bfloat16,
                         moment_dtype=None) -> TrainState:
    p = model.abstract_params(dtype)
    mdt = moment_dtype or dtype
    mom = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, mdt), p)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    return TrainState(params=p, opt=AdamWState(mu=mom, nu=mom, count=scalar),
                      step=scalar)


# ---------------------------------------------------------------------------
# step builders (return fn, kwargs-of-abstract-args, in_shardings dict)
# ---------------------------------------------------------------------------
def build_train_step(run: RunConfig, plan: Plan, dtype=jnp.bfloat16,
                     moment_dtype=None):
    model = build_model(run.model)
    base = make_train_step(model, lr=3e-4, impl=plan.attn_impl)
    mb = plan.microbatches

    if mb == 1:
        step = base
    else:
        from repro.training.optimizer import adamw_update, clip_by_global_norm

        def step(state: TrainState, batch: Dict):
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

            mbatch = {k: split(v) for k, v in batch.items()}

            def loss_of(p, b):
                loss, parts = model.loss_fn(p, b, impl=plan.attn_impl)
                return loss, parts

            def acc_body(carry, b):
                g_acc, loss_acc = carry
                (loss, _), g = jax.value_and_grad(loss_of, has_aux=True)(
                    state.params, b)
                g_acc = jax.tree_util.tree_map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.float32(0.0)),
                                            mbatch)
            grads = jax.tree_util.tree_map(lambda g: g / mb, grads)
            loss = loss / mb
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            new_p, new_opt = adamw_update(grads, state.opt, state.params,
                                          lr=3e-4)
            return (TrainState(new_p, new_opt, state.step + 1),
                    {"loss": loss, "grad_norm": gnorm})

    st_sh = train_state_shardings(model, plan, dtype,
                                  zero_pod=plan.zero_pod)
    b_sh = batch_shardings(run, plan)
    args = {"state": abstract_train_state(model, dtype, moment_dtype),
            "batch": abstract_batch(run, dtype)}
    shardings = {"state": st_sh, "batch": b_sh}
    return step, args, shardings, model


def build_prefill_step(run: RunConfig, plan: Plan, dtype=jnp.bfloat16):
    model = build_model(run.model)
    cfg = run.model
    B, S = run.global_batch, run.seq_len
    state_abs = model.init_decode_state(run, dtype=dtype,
                                        n_kv_shards=plan.n_kv_shards,
                                        abstract=True)
    ba = plan.batch_axes or None

    def step(params, tokens, lens, state, extra=None):
        fn = getattr(model, "prefill_scanned", model.prefill)
        logits, st = fn(params, tokens, state, lens=lens, extra=extra,
                        impl=plan.attn_impl)
        return logits, st

    args: Dict[str, Any] = {
        "params": model.abstract_params(dtype),
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "lens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "state": state_abs,
    }
    shardings: Dict[str, Any] = {
        "params": _param_shardings(model, plan, dtype),
        "tokens": _ns(plan, ba, None),
        "lens": _ns(plan, ba),
        "state": decode_state_shardings(model, plan, state_abs),
    }
    if cfg.family == "vlm":
        args["extra"] = {"image_embeds": jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_vision), dtype)}
        shardings["extra"] = {"image_embeds": _ns(plan, ba, None, None)}
    elif cfg.family == "encdec":
        args["extra"] = {"frames": jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), dtype)}
        shardings["extra"] = {"frames": _ns(plan, ba, None, None)}
    return step, args, shardings, model


def build_serve_step(run: RunConfig, plan: Plan, dtype=jnp.bfloat16):
    """Decode: ONE new token per sequence against a seq_len KV cache."""
    model = build_model(run.model)
    B = run.global_batch
    state_abs = model.init_decode_state(run, dtype=dtype,
                                        n_kv_shards=plan.n_kv_shards,
                                        abstract=True)
    ba = plan.batch_axes or None
    attn_ctx = {"scheme": plan.scheme, "batch_axes": plan.batch_axes}

    def step(params, tokens, state):
        return model.decode_step(params, tokens, state,
                                 impl="ref", attn_ctx=attn_ctx)

    args = {
        "params": model.abstract_params(dtype),
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "state": state_abs,
    }
    shardings = {
        "params": _param_shardings(model, plan, dtype),
        "tokens": _ns(plan, ba),
        "state": decode_state_shardings(model, plan, state_abs),
    }
    return step, args, shardings, model


def build_step(run: RunConfig, plan: Plan, dtype=jnp.bfloat16):
    if run.kind == "train":
        return build_train_step(run, plan, dtype)
    if run.kind == "prefill":
        return build_prefill_step(run, plan, dtype)
    return build_serve_step(run, plan, dtype)


def lower_step(run: RunConfig, plan: Plan, dtype=jnp.bfloat16):
    """Trace + lower (no compile). Returns (lowered, model)."""
    step, args, shardings, model = build_step(run, plan, dtype)
    names = list(args)
    in_sh = tuple(shardings[n] for n in names)
    donate = tuple(i for i, n in enumerate(names) if n == "state")

    with use_mesh(plan.mesh, plan.rules):
        jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=donate)
        lowered = jitted.lower(*(args[n] for n in names))
    return lowered, model
