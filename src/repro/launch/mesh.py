"""Production mesh builders (functions, never module-level constants).

Target: TPU v5e. Single pod = 16×16 = 256 chips, mesh ("data", "model").
Multi-pod = 2 pods = 512 chips, mesh ("pod", "data", "model") — the "pod"
axis carries pure data parallelism across the inter-pod links.
"""

from __future__ import annotations

import jax

# v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
