import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_XLA_EXTRA", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers + compiles.

For one pair this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. lowers + compiles the step (train_step / prefill / serve_step) with
     the Plan's explicit shardings — ShapeDtypeStructs only, no allocation,
  3. records memory_analysis (the fits-proof), cost_analysis, and the
     HLO-parsed per-collective bytes,
  4. re-lowers L1/L2 reduced-depth variants for the scan-body cost
     correction (DESIGN.md §7), and emits the corrected roofline terms.

Results land in experiments/dryrun/<arch>__<shape>__<mesh>[__tag].json.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all --mesh pod
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, make_run
from repro.configs.base import ModelConfig, RunConfig
from repro.launch import hlo_analysis as ha
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_step, plan_for

SKIPS = {
    # (arch, shape): reason — recorded, not silently dropped
    ("whisper-medium", "long_500k"):
        "decoder is bounded by design (448 positions; enc-dec cross-attn "
        "is fixed-length) — a 524k-token decoder context is architecturally "
        "meaningless (DESIGN.md §5)",
}

# archs that need the sliding-window variant to make long_500k sub-quadratic
SWA_FOR_LONG = {"nemotron-4-340b", "nemotron-4-15b", "llama3-405b",
                "granite-8b", "granite-moe-1b-a400m", "olmoe-1b-7b",
                "llama-3.2-vision-11b", "llama2-7b"}


def make_run_for(arch: str, shape: str) -> Optional[RunConfig]:
    if (arch, shape) in SKIPS:
        return None
    variant = "swa" if (shape == "long_500k" and arch in SWA_FOR_LONG) else "base"
    cfg = get_config(arch)
    if shape == "train_4k" and cfg.remat == "none":
        cfg = cfg.replace(remat="full")
    return make_run(cfg, shape, variant=variant)


def reduced_depth(cfg: ModelConfig, n_units: int) -> ModelConfig:
    """Same config with n_units layer groups (for the L1/L2 correction)."""
    unit = len(cfg.layer_pattern)
    rem = cfg.n_layers % unit
    # scan_unroll: the probes must compile loop-free — XLA cost_analysis
    # counts a while body once regardless of trip count, so a scanned L1/L2
    # pair would report delta≈0 (DESIGN.md §7)
    updates: Dict[str, Any] = {"n_layers": n_units * unit + rem,
                               "scan_unroll": True}
    if cfg.n_encoder_layers:
        updates["n_encoder_layers"] = n_units
    return cfg.replace(**updates)


def n_groups_of(cfg: ModelConfig) -> int:
    return cfg.n_layers // len(cfg.layer_pattern)


def run_pair(arch: str, shape: str, multi_pod: bool,
             microbatches: Optional[int] = None,
             scheme: Optional[str] = None,
             attn_impl: str = "chunked",
             tag: str = "", with_correction: bool = True,
             overrides: Optional[Dict[str, Any]] = None,
             seq_parallel: bool = True,
             ws_decode: bool = False,
             ring: bool = False,
             zero_pod: bool = False) -> Dict[str, Any]:
    run = make_run_for(arch, shape)
    if run is not None and overrides:
        run = RunConfig(model=run.model.replace(**overrides),
                        seq_len=run.seq_len, global_batch=run.global_batch,
                        kind=run.kind, variant=run.variant)
    mesh_name = "multipod" if multi_pod else "pod"
    out: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "tag": tag}
    if run is None:
        out["status"] = "skipped"
        out["reason"] = SKIPS[(arch, shape)]
        return out

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    plan = plan_for(run, mesh, microbatches=microbatches, scheme=scheme,
                    attn_impl=attn_impl, seq_parallel=seq_parallel,
                    ws_decode=ws_decode, ring=ring, zero_pod=zero_pod)
    out["plan"] = {"batch_axes": plan.batch_axes, "scheme": plan.scheme,
                   "kv_axes": plan.kv_axes, "microbatches": plan.microbatches,
                   "variant": run.variant,
                   "rules": {k: v for k, v in plan.rules.table.items()
                             if v is not None}}

    t0 = time.time()
    lowered, _ = lower_step(run, plan)
    compiled = lowered.compile()
    out["compile_s"] = round(time.time() - t0, 1)
    out["memory"] = ha.memory_stats(compiled)
    out["cost_full"] = ha.extract_cost(compiled)
    hlo = compiled.as_text()
    out["collectives_full"] = ha.collective_bytes(hlo)
    shadow = ha.f32_shadow_bytes(hlo)
    out["f32_shadow"] = shadow
    # TPU-estimated peak: CPU peak minus the largest CPU-only f32 shadow
    # buffer (conservative; see hlo_analysis.f32_shadow_bytes)
    out["memory"]["peak_bytes_tpu_est"] = (out["memory"]["peak_bytes"]
                                           - shadow["max"])
    out["status"] = "ok"

    if with_correction:
        # L1/L2 delta correction for scan-body costs
        costs = {}
        for n_units in (1, 2):
            cfg_n = reduced_depth(run.model, n_units)
            run_n = RunConfig(model=cfg_n, seq_len=run.seq_len,
                              global_batch=run.global_batch, kind=run.kind,
                              variant=run.variant)
            # probes run microbatches=1: the grad-accumulation scan is a
            # while loop too (cost counted once) — totals are mb-invariant
            plan_n = plan_for(run_n, mesh, microbatches=1,
                              scheme=scheme, attn_impl=attn_impl,
                              seq_parallel=seq_parallel,
                              ws_decode=ws_decode, ring=ring,
                              zero_pod=zero_pod)
            low_n = lower_step(run_n, plan_n)[0]
            comp_n = low_n.compile()
            costs[n_units] = {
                **ha.extract_cost(comp_n),
                "coll": ha.collective_bytes(comp_n.as_text())["total"],
            }
        n = n_groups_of(run.model)
        c1, c2 = costs[1], costs[2]
        corrected = {
            "flops": c1["flops"] + (n - 1) * (c2["flops"] - c1["flops"]),
            "bytes": c1["bytes"] + (n - 1) * (c2["bytes"] - c1["bytes"]),
            "coll_bytes": c1["coll"] + (n - 1) * (c2["coll"] - c1["coll"]),
            "n_groups": n,
        }
        out["cost_l1"] = c1
        out["cost_l2"] = c2
        out["cost_corrected"] = corrected

        terms = ha.roofline_terms(corrected["flops"], corrected["bytes"],
                                  corrected["coll_bytes"])
        n_tokens = (run.global_batch * run.seq_len if run.kind != "decode"
                    else run.global_batch)
        mf_total = ha.model_flops(run.model, n_tokens, run.kind)
        terms["model_flops_per_dev"] = mf_total / n_dev
        terms["useful_frac"] = (mf_total / n_dev) / max(corrected["flops"], 1.0)
        out["roofline"] = terms
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--scheme", default=None)
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--override", default="",
                    help="comma key=val ModelConfig overrides (perf iters), "
                         "e.g. --override moe_ep=True,remat=none")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-correction", action="store_true")
    ap.add_argument("--no-seqpar", action="store_true",
                    help="disable train/prefill sequence parallelism (perf)")
    ap.add_argument("--zero-pod", action="store_true",
                    help="ZeRO-1: shard optimizer moments over the pod "
                         "axis (multipod only)")
    ap.add_argument("--ring", action="store_true",
                    help="ring attention (context parallelism) for "
                         "train/prefill (perf)")
    ap.add_argument("--ws-decode", action="store_true",
                    help="weight-stationary decode: psum activation "
                         "partials instead of gathering FSDP weights (perf)")
    ap.add_argument("--outdir", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = (list(INPUT_SHAPES) if args.shape == "all"
              else args.shape.split(","))
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    overrides: Dict[str, Any] = {}
    for kv in (args.override.split(",") if args.override else []):
        k, v = kv.split("=")
        if k == "fsdp" and v == "off":
            overrides["axis_overrides"] = {}  # drop the embed->data FSDP rule
            continue
        overrides[k] = {"True": True, "False": False}.get(
            v, int(v) if v.isdigit() else v)

    os.makedirs(args.outdir, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multipod" if mp else "pod"
                stem = f"{arch}__{shape}__{mesh_name}"
                if args.tag:
                    stem += f"__{args.tag}"
                try:
                    res = run_pair(arch, shape, mp,
                                   microbatches=args.microbatches,
                                   scheme=args.scheme,
                                   attn_impl=args.attn_impl, tag=args.tag,
                                   with_correction=not args.no_correction,
                                   overrides=overrides or None,
                                   seq_parallel=not args.no_seqpar,
                                   ws_decode=args.ws_decode, ring=args.ring,
                                   zero_pod=args.zero_pod)
                except Exception as e:  # noqa: BLE001 — recorded, not hidden
                    res = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-3000:]}
                    failures += 1
                with open(os.path.join(args.outdir, stem + ".json"), "w") as f:
                    json.dump(res, f, indent=1)
                status = res["status"]
                extra = ""
                if status == "ok":
                    mem = res["memory"]["peak_bytes"] / 2**30
                    extra = f" peak={mem:.2f}GiB compile={res['compile_s']}s"
                    if "roofline" in res:
                        r = res["roofline"]
                        extra += (f" bottleneck={r['bottleneck']}"
                                  f" useful={r['useful_frac']:.2f}")
                print(f"[{status:>7}] {stem}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} pair(s) failed")


if __name__ == "__main__":
    main()
