"""HLO-text analysis: collective bytes, cost correction, roofline terms.

``cost_analysis()`` gives per-device FLOPs/bytes but (a) counts while-loop
(scan) bodies ONCE regardless of trip count, and (b) has no collective
breakdown.  This module fixes both:

  * ``collective_bytes`` parses the post-SPMD HLO for all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute ops and
    sums the bytes each moves per device (with the standard ring-algorithm
    factors).
  * the L1/L2 *delta correction*: lower the same step with 1 and 2 layer
    groups; per-group cost = delta; corrected = L1 + (n_groups-1)·delta.
    This scales FLOPs, bytes, and collective bytes uniformly and works for
    forward, backward, and optimizer code without instrumenting the scan.

Roofline terms (v5e, DESIGN.md A2):
    compute    = flops_per_device / 197e12
    memory     = hbm_bytes_per_device / 819e9
    collective = ici_bytes_per_device / (n_links · 50e9)
"""

from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# `%x = bf16[1,2048,128]{2,1,0} all-gather(...)` — capture result type + op
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_TUPLE_OP_RE = re.compile(
    r"=\s*\((.*?)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_REPL_GROUPS_V2_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _REPL_GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPL_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved over ICI, by collective kind.

    Ring-algorithm cost model per device:
      all-gather:        out − in  (receives the other shards)
      reduce-scatter:    in − out
      all-reduce:        2 · in · (g−1)/g
      all-to-all:        in · (g−1)/g
      collective-permute: in  (one hop)
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            size = _shape_bytes(dtype, dims)
        else:
            m = _TUPLE_OP_RE.search(line)
            if not m:
                continue
            kind = m.group(2)
            size = sum(_shape_bytes(t.group(1), t.group(2))
                       for t in _SHAPE_RE.finditer(m.group(1)))
            # async tuple shapes repeat (in, out); halve to the out estimate
            size //= 2
        g = _group_size(line)
        if g <= 1:
            continue
        if kind == "all-gather":
            out[kind] += size * (g - 1) / g
        elif kind == "reduce-scatter":
            out[kind] += size * (g - 1) / g
        elif kind == "all-reduce":
            out[kind] += 2 * size * (g - 1) / g
        elif kind == "all-to-all":
            out[kind] += size * (g - 1) / g
        else:  # collective-permute
            out[kind] += size
    out["total"] = sum(out.values())
    return out


_F32_CONVERT_RE = re.compile(
    r"%\S+ = f32\[([0-9,]+)\]\S*\s+convert\(%\S*param")


def f32_shadow_bytes(hlo_text: str, min_bytes: int = 32 << 20
                     ) -> Dict[str, float]:
    """CPU-backend artifact accounting (DESIGN.md §7).

    XLA:CPU's float-normalization pass legalizes bf16 scatter /
    dynamic-update-slice / dot by upcasting whole operands to f32 — for a
    layer-stacked KV pool or weight stack that materializes a pool-sized
    f32 "shadow" buffer that does NOT exist on TPU (native bf16).  We sum
    the ≥min_bytes f32 convert-of-parameter buffers; ``max`` is the
    conservative single-buffer correction (XLA reuses shadow buffers, so
    subtracting the sum would over-correct).
    """
    sizes = []
    for line in hlo_text.splitlines():
        m = _F32_CONVERT_RE.search(line)
        if m:
            b = _shape_bytes("f32", m.group(1))
            if b >= min_bytes:
                sizes.append(b)
    return {"max": float(max(sizes, default=0)),
            "sum": float(sum(sizes)), "count": float(len(sizes))}


def extract_cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_stats(compiled) -> Dict[str, float]:
    ms = compiled.memory_analysis()
    return {
        "argument_bytes": float(ms.argument_size_in_bytes),
        "output_bytes": float(ms.output_size_in_bytes),
        "temp_bytes": float(ms.temp_size_in_bytes),
        "alias_bytes": float(ms.alias_size_in_bytes),
        "peak_bytes": float(ms.argument_size_in_bytes
                            + ms.output_size_in_bytes
                            + ms.temp_size_in_bytes
                            - ms.alias_size_in_bytes),
    }


def roofline_terms(flops: float, hbm_bytes: float, ici_bytes: float,
                   n_links: int = 4) -> Dict[str, float]:
    """Per-device seconds for each roofline term (v5e constants)."""
    t = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": ici_bytes / (n_links * ICI_BW),
    }
    t["bottleneck"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: t[k])
    return t


def model_flops(cfg, n_tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); 2·N·D for
    inference-like kinds (no backward)."""
    from repro.utils.tree import count_params
    from repro.models.api import build_model
    import jax

    model = build_model(cfg)
    spec = model.abstract_params()
    total = sum(int(np.prod(l.shape))
                for l in jax.tree_util.tree_leaves(spec))
    if cfg.is_moe:
        # active = total − (inactive expert params)
        E, k = cfg.n_experts, cfg.top_k
        expert = 3 * cfg.d_model * cfg.expert_ff * cfg.n_layers
        total_expert = E * expert
        active = total - total_expert + k * expert
    else:
        active = total
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * n_tokens
