"""Small pytree utilities used across the framework."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def count_params(tree) -> int:
    """Total number of scalar parameters in a pytree of arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) for l in leaves if hasattr(l, "shape")))


def param_bytes(tree) -> int:
    """Total bytes of a pytree of (Shape)(Dtype)Structs or arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
    return total


def tree_map_with_path_names(fn, tree):
    """tree_map where fn receives ("a/b/c", leaf)."""

    def _fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)
