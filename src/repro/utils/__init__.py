from repro.utils.tree import (
    count_params,
    param_bytes,
    tree_map_with_path_names,
)

__all__ = ["count_params", "param_bytes", "tree_map_with_path_names"]
