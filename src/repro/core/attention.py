"""Attention dispatch — the paper's "drop-in deployability" surface (§I-B).

One entry point per phase; a config flag (`paged_attention`) switches between
the paged implementation and the contiguous baseline, exactly like the
paper's FMS integration ("via configuration flags, requiring no model
re-training or architecture edits").

  * ``prefill_attention`` — full-sequence causal/windowed attention
    (flex kernel or jnp fallback) used by training and prompt prefill;
  * ``decode_attention``  — one token against the paged KV pools
    (Pallas kernel / oracle), optionally distributed with a
    flash-decoding-style online-softmax combine across mesh axes
    (the `kvp` scheme — our beyond-paper extension);
  * ``decode_attention_contiguous`` — the paper's baseline: a max-length
    pre-allocated cache.

All functions are GQA-aware and sharding-agnostic (they may run inside
`shard_map`; `kv_psum_axes` enables the cross-shard combine).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flex
from repro.kernels.flex_attention.ops import flex_attention
from repro.kernels.paged_attention.ops import paged_attention, paged_prefill
from repro.kernels.paged_attention.ref import ring_slot_positions

# re-export: serving/bench code sizes decode grids through this module
from repro.kernels.paged_attention.ops import choose_decode_params  # noqa: F401
from repro.kernels.paged_attention.ops import choose_prefill_params  # noqa: F401


def prefill_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, Hkv, D)
    v: jax.Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
    lens: Optional[jax.Array] = None,
    causal: bool = True,
    impl: str = "jnp",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Full-sequence attention for training / prefill.  Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if impl == "ring":
        # context parallelism: sequence-sharded online-softmax attention
        # with K/V rotating over the "model" axis (DESIGN.md / §Perf H2)
        from repro.distributed.ring import ring_attention, ring_available
        if ring_available(S):
            return ring_attention(q, k, v, lens=lens, causal=causal,
                                  window=window, softcap=softcap)
        impl = "chunked"  # no mesh / indivisible seq: local fallback
    mods = []
    if causal:
        mods.append(flex.sliding_window_mask(window) if window > 0
                    else flex.causal_mask)
    elif window > 0:
        mods.append(flex.sliding_window_mask(window))
    if lens is not None:
        mods.append(flex.padding_mask(lens))
    mask_mod = flex.and_masks(*mods) if mods else flex.full_mask
    score_mod = flex.softcap_score(softcap) if softcap > 0 else None

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if impl == "pallas":
        out = flex_attention(qt, kt, vt, mask_mod=mask_mod,
                             score_mod=score_mod, window=window,
                             interpret=interpret)
    elif impl == "chunked":
        # flash-style two-level chunking: O(q_chunk·kv_chunk) live scores.
        # This is the path the multi-pod dry-run lowers for long sequences
        # (the dense path would claim O(S²) temp bytes at 32k).
        out = _chunked_attention(qt, kt, vt, mask_mod, score_mod)
    else:
        # jnp path: identical math, O(S²) scores — fine for smoke tests
        out = _dense_attention(qt, kt, vt, mask_mod, score_mod)
    return out.transpose(0, 2, 1, 3)


def _dense_attention(q, k, v, mask_mod, score_mod):
    """(B,H,Q,D)x(B,Hkv,K,D) dense masked attention in f32 accumulation."""
    B, H, Q, D = q.shape
    Hkv, K = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)
    qg = (q * scale).reshape(B, Hkv, G, Q, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    bi = jnp.arange(B)[:, None, None, None, None]
    hi = jnp.arange(H).reshape(Hkv, G)[None, :, :, None, None]
    qi = jnp.arange(Q)[None, None, None, :, None]
    ki = jnp.arange(K)[None, None, None, None, :]
    if score_mod is not None:
        s = score_mod(s, bi, hi, qi, ki)
    m = mask_mod(bi, hi, qi, ki)
    s = jnp.where(m, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)
    return out.reshape(B, H, Q, D)


def _chunked_attention(q, k, v, mask_mod, score_mod,
                       q_chunk: int = 512, kv_chunk: int = 1024):
    """(B,H,Q,D)x(B,Hkv,K,D) online-softmax attention in (qc × kc) tiles.

    Pure-JAX flash: an outer ``lax.map`` over q-chunks and an inner
    ``lax.scan`` over kv-chunks keep live score buffers at
    (B,Hkv,G,qc,kc) regardless of sequence length.  Mask/score mods are
    evaluated per tile on index arrays (the FlexAttention contract), so any
    composed mod works unchanged.  Rectangular iteration (no tile skipping)
    — the Pallas kernel does the skipping on real hardware; here the HLO
    FLOPs over-count causal attention by ≤2×, which the roofline notes.
    """
    B, H, Q, D = q.shape
    Hkv, K = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)
    qc = min(q_chunk, Q)
    kc = min(kv_chunk, K)
    nq = -(-Q // qc)
    nk = -(-K // kc)
    Qp, Kp = nq * qc, nk * kc
    qpad = jnp.pad(q, ((0, 0), (0, 0), (0, Qp - Q), (0, 0)))
    kpad = jnp.pad(k, ((0, 0), (0, 0), (0, Kp - K), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, Kp - K), (0, 0)))
    # (nq, B, Hkv, G, qc, D) / (nk, B, Hkv, kc, D)
    qt = (qpad.reshape(B, Hkv, G, nq, qc, D).transpose(3, 0, 1, 2, 4, 5)
          * scale).astype(q.dtype)
    kt = kpad.reshape(B, Hkv, nk, kc, D).transpose(2, 0, 1, 3, 4)
    vt = vpad.reshape(B, Hkv, nk, kc, D).transpose(2, 0, 1, 3, 4)

    bi = jnp.arange(B)[:, None, None, None, None]
    hi = jnp.arange(H).reshape(Hkv, G)[None, :, :, None, None]

    def q_block(args):
        qi, qb = args  # qb: (B, Hkv, G, qc, D)
        q_idx = (qi * qc + jnp.arange(qc))[None, None, None, :, None]

        def kv_body(carry, kv):
            m, l, acc = carry
            kj, kb, vb = kv
            k_idx = (kj * kc + jnp.arange(kc))[None, None, None, None, :]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb,
                           preferred_element_type=jnp.float32)
            if score_mod is not None:
                s = score_mod(s, bi, hi, q_idx, k_idx)
            live = mask_mod(bi, hi, q_idx, k_idx) & (k_idx < K)
            s = jnp.where(live, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(live, jnp.exp(s - m_safe[..., None]), 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, Hkv, G, qc), -jnp.inf),
                jnp.zeros((B, Hkv, G, qc)),
                jnp.zeros((B, Hkv, G, qc, D)))
        (m, l, acc), _ = jax.lax.scan(
            kv_body, init, (jnp.arange(nk), kt, vt))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (jnp.arange(nq), qt))  # (nq,B,Hkv,G,qc,D)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, H, Qp, D)
    return out[:, :, :Q].astype(q.dtype)


def prefill_attention_paged(
    q: jax.Array,  # (B, C, H, D) — one prompt *chunk* per sequence
    k_pages: jax.Array,  # (num_pages, P, Hkv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    kv_lens: jax.Array,  # (B,) cached tokens incl. the chunk
    q_start: jax.Array,  # (B,) absolute position of chunk token 0
    *,
    softcap: float = 0.0,
    impl: str = "ref",
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: Optional[int] = None,
    num_splits: Optional[int] = None,
    combine_mode: Optional[str] = None,
    backend: Optional[str] = None,
    q_block: Optional[int] = None,
) -> jax.Array:
    """Chunked paged prefill attention — the prompt-phase counterpart of
    `decode_attention`.  The chunk's K/V must already sit in the pages
    (write-then-attend, like the decode path): queries attend causally
    over the cached prefix pages *and* the chunk's own causal part, all
    read through the block table.  ``impl="pallas"`` runs the prefix-aware
    Q-block × KV-block kernel (TPU or GPU lowering per ``backend``);
    anything else runs the jnp oracle.  Returns (B, C, H, D)."""
    kernel_impl = "pallas" if impl == "pallas" else "ref"
    return paged_prefill(
        q, k_pages, v_pages, block_tables, kv_lens, q_start,
        softcap=softcap, impl=kernel_impl, interpret=interpret,
        kv_scale=kv_scale, pages_per_block=pages_per_block,
        num_splits=num_splits, combine_mode=combine_mode, backend=backend,
        q_block=q_block)


def prefill_attention_windowed_chunk(
    q: jax.Array,  # (B, C, H, D)
    k_new: jax.Array,  # (B, C, Hkv, D) — the chunk's fresh K/V
    v_new: jax.Array,
    k_pages: jax.Array,  # (num_pages, P, Hkv, D) — ring pools, pre-write
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, ring)
    q_start: jax.Array,  # (B,) cached prefix length (chunk NOT yet written)
    q_lens: jax.Array,  # (B,) live chunk tokens
    *,
    window: int,
    softcap: float = 0.0,
    kv_scale: float = 0.0,
) -> jax.Array:
    """Sliding-window chunked prefill (attend-then-write fallback).

    Ring-paged 'W' layers cannot use the write-then-attend kernel: a long
    chunk's writes wrap the ring and overwrite prefix slots earlier
    queries still need.  Instead the chunk attends over the *intact* ring
    prefix (gathered, the slots hold exactly the last ``ring·P ≥ window``
    prefix positions) plus its own fresh K/V, and the caller scatters the
    chunk into the ring afterwards.  Bounded working set — the ring is
    small by construction, so a jnp path suffices."""
    B, C, H, D = q.shape
    num_pages, P, Hkv, _ = k_pages.shape
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)

    ring = -(-window // P) + 1
    # mixed dense/windowed models share one table sized for the dense
    # layers — only the first `ring` columns are ring slots here
    block_tables = block_tables[:, :ring]
    S = block_tables.shape[1] * P

    safe = jnp.clip(block_tables, 0, num_pages - 1)
    kpre = jax.lax.optimization_barrier(
        k_pages[safe].reshape(B, S, Hkv, D))
    vpre = jax.lax.optimization_barrier(
        v_pages[safe].reshape(B, S, Hkv, D))
    if kv_scale > 0:
        kpre = (kpre.astype(jnp.float32) * kv_scale).astype(q.dtype)
        vpre = (vpre.astype(jnp.float32) * kv_scale).astype(q.dtype)

    # positions the ring slots hold w.r.t. the *prefix* (length q_start)
    pre_pos = ring_slot_positions(q_start, P, ring, S)  # (B, S)
    qpos = q_start[:, None] + jnp.arange(C)[None, :]  # (B, C)
    live_pre = ((pre_pos >= 0) & (pre_pos < q_start[:, None])
                & (block_tables >= 0)[:, :, None].repeat(P, 2).reshape(B, S))
    # sliding window: k ≤ q and q − k < window (flex.sliding_window_mask)
    mask_pre = (live_pre[:, None, :]
                & (qpos[:, :, None] - pre_pos[:, None, :] < window))
    ci = jnp.arange(C)
    mask_new = ((ci[None, :] <= ci[:, None])
                & (ci[:, None] - ci[None, :] < window))[None]  # (1, C, C)
    mask_new = mask_new & (ci[None, None, :] < q_lens[:, None, None])
    mask = jnp.concatenate(
        [mask_pre, jnp.broadcast_to(mask_new, (B, C, C))], axis=2)

    k_all = jnp.concatenate([kpre, k_new.astype(kpre.dtype)], axis=1)
    v_all = jnp.concatenate([vpre, v_new.astype(vpre.dtype)], axis=1)
    qg = (q * scale).reshape(B, C, Hkv, G, D)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, k_all.astype(q.dtype)
                   ).astype(jnp.float32)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[:, None, None, :, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bkgcs,bskd->bckgd", w, v_all.astype(jnp.float32))
    return out.reshape(B, C, H, D).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, H, D) — one token per sequence
    k_pages: jax.Array,  # (num_pages, P, Hkv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    window: int = 0,
    softcap: float = 0.0,
    impl: str = "ref",
    kv_psum_axes: Tuple[str, ...] = (),
    page_stride: int = 1,
    page_offset=0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: Optional[int] = None,
    num_splits: Optional[int] = None,
    combine_mode: Optional[str] = None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Paged decode attention; distributed combine over ``kv_psum_axes``.

    When ``kv_psum_axes`` is non-empty this runs *inside* `shard_map` with
    the page dim sharded across those axes: each shard computes a partial
    online-softmax (m, l, o) over its local pages and the partials merge
    with the numerically-stable two-pass combine (flash-decoding on a mesh,
    `collectives.merge_flash_partials` — the same reduction implementation
    the single-device split-K kernel uses).
    ``page_stride``/``page_offset`` describe round-robin page striping:
    local table slot j holds *logical* page j·stride + offset.

    ``pages_per_block`` / ``num_splits`` are the single-device Pallas
    kernel's KV-block width and split-K factor (``None`` → auto-tuned,
    see `choose_decode_params`); the kvp path's split-K happens across the
    mesh instead, so they only apply to the local kernel.  ``combine_mode``
    selects the split-K merge implementation on *both* paths ("pallas" =
    fused combine kernel, "jnp" = epilogue; None → auto).  ``backend``
    picks the local kernel's lowering ("tpu" scalar-prefetch pipeline or
    "gpu" Triton in-kernel gather; None → auto from the running platform).
    """
    if not kv_psum_axes:
        return paged_attention(q, k_pages, v_pages, block_tables, lens,
                               window=window, softcap=softcap, impl=impl,
                               interpret=interpret, kv_scale=kv_scale,
                               pages_per_block=pages_per_block,
                               num_splits=num_splits,
                               combine_mode=combine_mode, backend=backend)

    # --- local partials ---------------------------------------------------
    m_l, l_l, o_l = _partial_decode(q, k_pages, v_pages, block_tables, lens,
                                    window=window, softcap=softcap,
                                    page_stride=page_stride,
                                    page_offset=page_offset,
                                    kv_scale=kv_scale)
    # --- cross-shard combine (shared with the split-K kernel) --------------
    from repro.distributed.collectives import merge_flash_partials
    return merge_flash_partials(m_l, l_l, o_l, kv_psum_axes,
                                combine_mode=combine_mode,
                                out_dtype=q.dtype, interpret=interpret)


def _partial_decode(q, k_pages, v_pages, block_tables, lens, *, window=0,
                    softcap=0.0, page_stride=1, page_offset=0,
                    kv_scale=0.0):
    """Un-normalised decode attention over the local page shard.

    Returns (m, l, o·l) with shapes ((B,H), (B,H), (B,H,D)) — f32.
    block_tables here maps to *local* physical pages; dead entries are -1.
    lens is the per-sequence *global* length; with page striping, local
    table slot j covers logical page j·page_stride + page_offset.
    """
    B, H, D = q.shape
    num_pages, P, Hkv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    S = max_pages * P
    scale = 1.0 / np.sqrt(D)

    safe = jnp.clip(block_tables, 0, num_pages - 1)
    # optimization_barrier: keeps any downstream dtype convert pinned to the
    # gathered page-working-set instead of being hoisted onto the whole pool
    # (the CPU backend's float-normalization pass would otherwise shadow the
    # full pool in f32 — pool-sized dead memory; harmless no-op on TPU).
    k = jax.lax.optimization_barrier(k_pages[safe].reshape(B, S, Hkv, D))
    v = jax.lax.optimization_barrier(v_pages[safe].reshape(B, S, Hkv, D))
    if kv_scale > 0:  # int8 pools: dequantize the gathered working set
        k = (k.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v = (v.astype(jnp.float32) * kv_scale).astype(q.dtype)

    if window > 0:
        assert page_stride == 1, "windowed caches are never page-striped"
        ring = -(-window // P) + 1
        pos = ring_slot_positions(lens, P, ring, S)
        live = (pos >= 0) & (pos < lens[:, None]) & (pos >= lens[:, None] - window)
        # table may be wider than the ring (mixed dense/windowed models);
        # slots past the ring never hold this layer's KV
        live &= (jnp.arange(S) // P < ring)[None, :]
    else:
        slot = jnp.arange(S)
        pos = (slot // P * page_stride + page_offset) * P + slot % P
        pos = jnp.broadcast_to(pos[None, :], (B, S))
        live = pos < lens[:, None]
    live &= (block_tables >= 0)[:, :, None].repeat(P, 2).reshape(B, S)

    G = H // Hkv
    # keep K/V in their storage dtype (bf16 on TPU — MXU inputs) and
    # accumulate in f32 via preferred_element_type: casting the pools
    # instead would let XLA hoist a full-pool f32 convert out of the layer
    # scan (2× pool bytes of dead memory).
    qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, D).astype(q.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k,
                   preferred_element_type=jnp.float32)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(live[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)  # (B, Hkv, G)
    m_safe = jnp.where(jnp.isfinite(m), m, -1e30)
    p = jnp.where(live[:, None, None, :], jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return (m_safe.reshape(B, H), l.reshape(B, H), o.reshape(B, H, D))


def decode_attention_contiguous(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, max_len, Hkv, D)
    v: jax.Array,
    lens: jax.Array,  # (B,)
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """The paper's baseline: decode against a max-length contiguous cache."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / np.sqrt(D)
    pos = jnp.arange(S)[None, :]
    live = pos < lens[:, None]
    if window > 0:
        live &= pos >= lens[:, None] - window
    qg = (q * scale).reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(live[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v.dtype), v)
    return out.reshape(B, H, D)
