"""Functional KV page manager — the paper's Algorithm 1, TPU-native.

The paper implements RESERVE / ASSIGN / GATHER with a lock-free free-list in
CUDA global memory.  On TPU we express the same state machine *functionally*:
the manager state is a pytree of fixed-shape device arrays and every
operation is a pure, jit-able function with O(1) work per *page slot*
(vectorised masked pops — no data-dependent shapes, no host sync on the
decode critical path).  A host-side mirror (`HostPageManager`) gives the
serving scheduler true O(1) integer ops for admission control.

Page-pool layout contract (see DESIGN.md §4):
  * physical pages live in pools shaped (num_pages, page_size, kv_heads, hd);
  * under the `tp` decode scheme the page dim is sharded over ("pod","data")
    — each data shard owns a private sub-pool and its slice of the batch;
  * under the `kvp` scheme the page dim is additionally sharded over
    ("model",) and a sequence's pages are striped across model shards
    (block tables are per-shard, shape (B, n_shards, pages_per_shard)).

Prefix sharing: `fork` aliases the shared full pages and bumps refcounts —
the paper's copy-on-write trick; the unshared tail page is freshly allocated
and copied at the cache level.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import SchedulerInvariantError

NULL_PAGE = jnp.int32(-1)


class PageState(NamedTuple):
    """Device-side allocator state (a pytree of fixed-shape arrays)."""

    free_stack: jax.Array  # (num_pages,) int32 — free physical page ids
    free_top: jax.Array  # () int32 — number of free pages on the stack
    refcount: jax.Array  # (num_pages,) int32
    block_tables: jax.Array  # (max_seqs, max_pages) int32, NULL_PAGE = empty
    seq_lens: jax.Array  # (max_seqs,) int32 — tokens stored per sequence

    @property
    def num_pages(self) -> int:
        return self.free_stack.shape[0]

    @property
    def max_pages(self) -> int:
        return self.block_tables.shape[1]


def init_state(num_pages: int, max_seqs: int, max_pages_per_seq: int) -> PageState:
    return PageState(
        free_stack=jnp.arange(num_pages - 1, -1, -1, dtype=jnp.int32),
        free_top=jnp.int32(num_pages),
        refcount=jnp.zeros((num_pages,), jnp.int32),
        block_tables=jnp.full((max_seqs, max_pages_per_seq), NULL_PAGE, jnp.int32),
        seq_lens=jnp.zeros((max_seqs,), jnp.int32),
    )


def pages_needed(n_tokens: jax.Array, page_size: int) -> jax.Array:
    return (n_tokens + page_size - 1) // page_size


def reserve(state: PageState, seq_id: jax.Array, new_len: jax.Array,
            page_size: int) -> PageState:
    """Grow seq ``seq_id``'s reservation to cover ``new_len`` tokens (Alg.1 RESERVE).

    Pops however many pages are needed from the free stack in one vectorised
    masked operation.  If the pool is exhausted the state is returned
    unchanged for the overflowing pages (callers check `has_capacity` first —
    the scheduler's admission-control job, as in the paper's FMS integration).
    """
    row = state.block_tables[seq_id]
    cur_pages = pages_needed(state.seq_lens[seq_id], page_size)
    tgt_pages = pages_needed(new_len, page_size)

    slots = jnp.arange(state.max_pages, dtype=jnp.int32)
    need = (slots >= cur_pages) & (slots < tgt_pages)
    # rank of each needed slot among needed slots: 0,1,2,...
    rank = jnp.cumsum(need.astype(jnp.int32)) - 1
    n_new = jnp.sum(need.astype(jnp.int32))
    avail = jnp.minimum(n_new, state.free_top)
    ok = need & (rank < avail)

    # pop: page for rank r = free_stack[free_top - 1 - r]
    idx = jnp.clip(state.free_top - 1 - rank, 0, state.num_pages - 1)
    popped = state.free_stack[idx]
    new_row = jnp.where(ok, popped, row)

    new_ref = state.refcount.at[jnp.where(ok, popped, 0)].add(
        ok.astype(jnp.int32), mode="drop"
    )
    return state._replace(
        block_tables=state.block_tables.at[seq_id].set(new_row),
        free_top=state.free_top - avail,
        refcount=new_ref,
        seq_lens=state.seq_lens.at[seq_id].set(new_len),
    )


def free(state: PageState, seq_id: jax.Array, page_size: int) -> PageState:
    """Release all pages of ``seq_id`` (Alg.1 implicit FREE path).

    Pages whose refcount drops to zero are pushed back on the free stack;
    shared pages just lose one reference.
    """
    row = state.block_tables[seq_id]
    n_pages = pages_needed(state.seq_lens[seq_id], page_size)
    slots = jnp.arange(state.max_pages, dtype=jnp.int32)
    held = (slots < n_pages) & (row >= 0)

    safe_row = jnp.where(held, row, 0)
    ref_after = state.refcount.at[safe_row].add(-held.astype(jnp.int32), mode="drop")
    releasable = held & (ref_after[safe_row] == 0)

    rank = jnp.cumsum(releasable.astype(jnp.int32)) - 1
    n_rel = jnp.sum(releasable.astype(jnp.int32))
    # route non-releasable slots to an out-of-bounds index (dropped) so they
    # can never collide with a real push at the same stack position
    push_idx = jnp.where(releasable, state.free_top + rank, state.num_pages)
    new_stack = state.free_stack.at[push_idx].set(row, mode="drop")
    return state._replace(
        free_stack=new_stack,
        free_top=state.free_top + n_rel,
        refcount=ref_after,
        block_tables=state.block_tables.at[seq_id].set(
            jnp.full((state.max_pages,), NULL_PAGE)
        ),
        seq_lens=state.seq_lens.at[seq_id].set(0),
    )


def fork(state: PageState, src: jax.Array, dst: jax.Array, page_size: int
         ) -> Tuple[PageState, jax.Array]:
    """Prefix-share: dst aliases src's *full* pages (refcount++), and gets a
    fresh page for the partial tail.  Returns (state, tail_src_page) so the
    cache layer can copy the partial page's K/V data (copy-on-write).

    Capacity guard: callers must check ``has_capacity(state, 1)`` before
    forking a sequence with a partial tail — the vectorised `reserve` has
    no failure channel (it silently leaves the overflowing slot unchanged
    on a dry pool), so an unguarded fork would hand dst a NULL tail page
    while the shared-prefix refcounts were already bumped.  The host
    mirror (`HostPageManager.fork`) enforces the same contract by
    returning ``False`` and rolling the bumps back.
    """
    src_len = state.seq_lens[src]
    full_pages = src_len // page_size
    src_row = state.block_tables[src]

    slots = jnp.arange(state.max_pages, dtype=jnp.int32)
    shared = slots < full_pages
    # bump refcounts on shared pages
    safe = jnp.where(shared, src_row, 0)
    ref = state.refcount.at[safe].add(shared.astype(jnp.int32), mode="drop")
    dst_row = jnp.where(shared, src_row, NULL_PAGE)

    state = state._replace(
        refcount=ref,
        block_tables=state.block_tables.at[dst].set(dst_row),
        seq_lens=state.seq_lens.at[dst].set(full_pages * page_size),
    )
    # fresh tail page (if src had a partial page)
    has_tail = src_len % page_size > 0
    tail_src_page = jnp.where(has_tail, src_row[full_pages], NULL_PAGE)
    state = jax.lax.cond(
        has_tail,
        lambda s: reserve(s, dst, src_len, page_size),
        lambda s: s,
        state,
    )
    return state, tail_src_page


def has_capacity(state: PageState, n_pages: jax.Array) -> jax.Array:
    return state.free_top >= n_pages


def used_pages(state: PageState) -> jax.Array:
    return state.num_pages - state.free_top


def lookup(state: PageState, seq_id: jax.Array, pos: jax.Array, page_size: int
           ) -> Tuple[jax.Array, jax.Array]:
    """logical position -> (physical page, offset)  (Alg.1 lines 7-8)."""
    b = pos // page_size
    o = pos % page_size
    return state.block_tables[seq_id, b], o


# ---------------------------------------------------------------------------
# Host-side mirror: true O(1) integer ops for the scheduler's admission logic.
# ---------------------------------------------------------------------------
class HostPageManager:
    """Python mirror of the allocator for scheduling decisions.

    Interface mirrors Alg. 1; every op is O(pages touched) with O(1)
    amortised pops/pushes (list-based stack).  The device `PageState` remains
    the source of truth for what the kernels read.
    """

    def __init__(self, num_pages: int, page_size: int):
        self.page_size = page_size
        self.num_pages = num_pages
        self.free_list = list(range(num_pages - 1, -1, -1))
        self.refcount = [0] * num_pages
        self.tables: dict[int, list[int]] = {}
        self.lens: dict[int, int] = {}
        # optional global prefix cache (core.prefix_cache.PrefixCache wires
        # itself in here).  Cache residency holds one refcount share per
        # cached page, so `free` *retains* shared-prefix pages (refcount
        # drops to >= 1, page stays off the free list) instead of recycling
        # them, and the invariant generalizes to
        #   refcount[p] == table occurrences of p + (1 if cache-resident)
        self.cache = None

    # -- Alg.1 RESERVE ----------------------------------------------------
    def reserve(self, seq_id: int, new_len: int) -> bool:
        row = self.tables.setdefault(seq_id, [])
        cur = len(row)
        tgt = -(-new_len // self.page_size)
        short = (tgt - cur) - len(self.free_list)
        if short > 0 and self.cache is not None:
            # pool pressure: evict LRU *detached* cached pages back onto
            # the free list before refusing — cached-but-unreferenced
            # pages are reclaimable capacity, not allocation
            self.cache.reclaim(short)
        if tgt - cur > len(self.free_list):
            return False  # admission control: caller must wait / preempt
        for _ in range(tgt - cur):
            p = self.free_list.pop()
            self.refcount[p] += 1
            row.append(p)
        self.lens[seq_id] = new_len
        return True

    def extend(self, seq_id: int, n_tokens: int = 1) -> bool:
        return self.reserve(seq_id, self.lens.get(seq_id, 0) + n_tokens)

    def free(self, seq_id: int) -> None:
        """Release all of ``seq_id``'s pages (refcount--; 0 => back on the
        free list).

        Double-free safe: freeing an unknown rid, or a page whose refcount
        is already zero, raises ``SchedulerInvariantError`` instead of
        silently corrupting the free list (the old behavior pushed the
        page twice, so two later sequences could be handed the same
        physical page — silent KV aliasing with no signal)."""
        if seq_id not in self.tables:
            raise SchedulerInvariantError(
                f"free of unknown rid {seq_id}: no table row — double free "
                "or never-reserved rid", rid=seq_id)
        for p in self.tables.pop(seq_id):
            if self.refcount[p] <= 0:
                raise SchedulerInvariantError(
                    f"double free of page {p} (refcount "
                    f"{self.refcount[p]}) while releasing rid {seq_id}",
                    rid=seq_id, page=p)
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.free_list.append(p)
        self.lens.pop(seq_id, None)

    def fork(self, src: int, dst: int) -> bool:
        """Prefix sharing: dst aliases src's full pages (refcount++) and
        reserves a fresh tail page for src's partial page.

        All-or-nothing: if the pool cannot serve the tail page the shared
        refcount bumps are rolled back and ``False`` is returned — the
        caller must not admit the child.  (Silently keeping the bumps
        while the child has no tail row would let the child decode into a
        never-reserved page and desync refcounts from table occupancy.)

        Forking an unknown/freed ``src`` raises ``SchedulerInvariantError``
        with rid context (like ``free``) — the former bare ``KeyError``
        gave the caller no structured signal that it raced a
        free/preemption of the parent.
        """
        if src not in self.tables or src not in self.lens:
            raise SchedulerInvariantError(
                f"fork from unknown rid {src}: no table row — freed, "
                "preempted, or never reserved", rid=src)
        src_len = self.lens[src]
        full = src_len // self.page_size
        row = self.tables[src][:full]
        for p in row:
            self.refcount[p] += 1
        self.tables[dst] = list(row)
        self.lens[dst] = full * self.page_size
        if src_len % self.page_size:
            if not self.reserve(dst, src_len):
                # dry pool: undo the prefix aliasing entirely
                for p in row:
                    self.refcount[p] -= 1
                del self.tables[dst]
                del self.lens[dst]
                return False
        return True

    def clone(self) -> "HostPageManager":
        """Structural copy for speculative exploration (the replint model
        checker branches the allocator at every transition).  The cache
        hook is *not* carried over — ``PrefixCache.clone`` re-wires it so
        a clone never mutates the original's trie."""
        new = HostPageManager.__new__(HostPageManager)
        new.page_size = self.page_size
        new.num_pages = self.num_pages
        new.free_list = list(self.free_list)
        new.refcount = list(self.refcount)
        new.tables = {rid: list(row) for rid, row in self.tables.items()}
        new.lens = dict(self.lens)
        new.cache = None
        return new

    # -- accounting (paper's <5% overhead metric) -------------------------
    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self.free_list)

    @property
    def available_pages(self) -> int:
        """Pages servable on demand: the free list plus cached pages the
        prefix cache can evict (detached chains).  Capacity checks that
        look only at ``free_list`` under-admit when the cache is warm —
        a full-but-detached cache is reclaimable capacity."""
        n = len(self.free_list)
        if self.cache is not None:
            n += self.cache.reclaimable()
        return n

    def bytes_reserved(self, kv_heads: int, head_dim: int, n_layers: int,
                       itemsize: int = 2) -> int:
        per_page = self.page_size * kv_heads * head_dim * 2 * n_layers * itemsize
        return self.used_pages * per_page

    def bytes_theoretical_min(self, kv_heads: int, head_dim: int, n_layers: int,
                              itemsize: int = 2) -> int:
        tokens = sum(self.lens.values())
        return tokens * kv_heads * head_dim * 2 * n_layers * itemsize

    def overhead_frac(self, kv_heads: int = 1, head_dim: int = 1,
                      n_layers: int = 1) -> float:
        mn = self.bytes_theoretical_min(kv_heads, head_dim, n_layers)
        if mn == 0:
            return 0.0
        return self.bytes_reserved(kv_heads, head_dim, n_layers) / mn - 1.0
