"""FlexAttention-style composable masking — the paper's §III-B kernel API.

PyTorch FlexAttention lets users supply ``mask_mod(b, h, q_idx, kv_idx)`` and
``score_mod(score, b, h, q_idx, kv_idx)`` hooks which the compiler fuses into
one attention kernel.  We reproduce the same API in JAX:

  * mask mods are vectorisable predicates over (b, h, q, k) index arrays;
  * combinators ``and_masks`` / ``or_masks`` compose them;
  * ``build_block_mask`` compiles a mod into a FlexAttention-style
    ``BlockMask`` — per (q-block) lists of live kv-blocks plus a
    full/partial flag — which the Pallas prefill kernel uses to *skip*
    fully-masked tiles and to elide the element-wise mask on full tiles;
  * the paper's paged mask  «allow ⟺ (id_q = id_k) ∧ (k ≤ len(id_q))»
    is ``paged_mask(seq_ids, lens)`` over the *gathered* layout, and is
    exactly what the decode kernel enforces via block tables.

All mods broadcast: inputs are integer arrays, output bool array.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

MaskMod = Callable[..., jax.Array]  # (b, h, q_idx, kv_idx) -> bool
ScoreMod = Callable[..., jax.Array]  # (score, b, h, q_idx, kv_idx) -> score


class AuxMod:
    """A mask/score mod that reads auxiliary tensors (FlexAttention's
    "passed as bias" trick — the paper's §III-B sequence-ID / prefix-sum
    vectors).  The Pallas kernel receives ``aux`` as scalar-prefetch
    operands instead of capturing them as constants.

    ``fn(b, h, q, k, *aux)`` (mask) or ``fn(score, b, h, q, k, *aux)``.
    """

    def __init__(self, fn: Callable, aux: Sequence[jax.Array],
                 is_score: bool = False):
        self.fn = fn
        self.aux = tuple(aux)
        self.is_score = is_score

    def __call__(self, *args):
        return self.fn(*args, *self.aux)


def _split(mods):
    """Flatten (fn, n_aux, aux) triples out of a mod list."""
    fns, counts, aux = [], [], []
    for m in mods:
        if isinstance(m, AuxMod):
            fns.append(m.fn)
            counts.append(len(m.aux))
            aux.extend(m.aux)
        else:
            fns.append(m)
            counts.append(0)
    return fns, counts, tuple(aux)


# ---------------------------------------------------------------------------
# mask mods
# ---------------------------------------------------------------------------
def full_mask(b, h, q, k):
    return jnp.ones(jnp.broadcast_shapes(jnp.shape(q), jnp.shape(k)), bool)


def causal_mask(b, h, q, k):
    return k <= q


def sliding_window_mask(window: int) -> MaskMod:
    def mod(b, h, q, k):
        return (k <= q) & (q - k < window)

    return mod


def padding_mask(lens: jax.Array) -> MaskMod:
    """lens: (B,) — kv positions past a sequence's length are dead."""

    def mod(b, h, q, k, lens):
        return k < lens[b]

    return AuxMod(mod, (lens,))


def document_mask(doc_ids: jax.Array) -> MaskMod:
    """Jagged batches packed in one sequence: attend within a document only.

    This is the paper's «id_q = id_k» predicate (§III-B) for packed layouts.
    """

    def mod(b, h, q, k, docs):
        return docs[b, q] == docs[b, k]

    return AuxMod(mod, (doc_ids,))


def prefix_lm_mask(prefix_len: int) -> MaskMod:
    def mod(b, h, q, k):
        return (k <= q) | (k < prefix_len)

    return mod


def _combine(op, mods):
    fns, counts, aux = _split(mods)

    def fn(b, h, q, k, *aux_in):
        out = None
        i = 0
        for f, n in zip(fns, counts):
            r = f(b, h, q, k, *aux_in[i:i + n])
            i += n
            out = r if out is None else op(out, r)
        return out

    if aux:
        return AuxMod(fn, aux)
    return lambda b, h, q, k: fn(b, h, q, k)


def and_masks(*mods: MaskMod) -> MaskMod:
    return _combine(lambda a, b: a & b, mods)


def or_masks(*mods: MaskMod) -> MaskMod:
    return _combine(lambda a, b: a | b, mods)


def paged_mask(slot_seq_ids: jax.Array, slot_pos: jax.Array,
               lens: jax.Array) -> MaskMod:
    """The paper's fused paged predicate (§III-B) over a packed/paged layout:

        allow ⟺ (id_q == id_k) ∧ (pos_k < len(id_q))

    ``slot_seq_ids[s]``: which sequence owns packed slot s;
    ``slot_pos[s]``:     that slot's logical position within its sequence;
    ``lens[i]``:         live length of sequence i.
    """

    def mod(b, h, q, k, sid, pos, lens):
        same = sid[q] == sid[k]
        live = pos[k] < lens[sid[q]]
        return same & live

    return AuxMod(mod, (slot_seq_ids, slot_pos, lens))


# ---------------------------------------------------------------------------
# score mods
# ---------------------------------------------------------------------------
def identity_score(score, b, h, q, k):
    return score


def softcap_score(cap: float) -> ScoreMod:
    def mod(score, b, h, q, k):
        return cap * jnp.tanh(score / cap)

    return mod


def alibi_score(slopes: jax.Array) -> ScoreMod:
    def mod(score, b, h, q, k, slopes):
        return score - slopes[h] * (q - k)

    return AuxMod(mod, (slopes,), is_score=True)


def compose_score(*mods: ScoreMod) -> ScoreMod:
    fns, counts, aux = _split(mods)

    def fn(score, b, h, q, k, *aux_in):
        i = 0
        for f, n in zip(fns, counts):
            score = f(score, b, h, q, k, *aux_in[i:i + n])
            i += n
        return score

    if aux:
        return AuxMod(fn, aux, is_score=True)
    return lambda s, b, h, q, k: fn(s, b, h, q, k)


# ---------------------------------------------------------------------------
# materialisation (reference path) and BlockMask compilation
# ---------------------------------------------------------------------------
def materialize(mod: MaskMod, B: int, H: int, Q: int, K: int) -> jax.Array:
    b = jnp.arange(B)[:, None, None, None]
    h = jnp.arange(H)[None, :, None, None]
    q = jnp.arange(Q)[None, None, :, None]
    k = jnp.arange(K)[None, None, None, :]
    return mod(b, h, q, k)


class BlockMask(NamedTuple):
    """FlexAttention-style compiled sparsity.

    kv_num_blocks: ([B,] num_q_blocks,) — live kv blocks per q block
    kv_indices:    ([B,] num_q_blocks, max_blocks) — their indices (pad = 0)
    is_full:       ([B,] num_q_blocks, max_blocks) — True ⇒ tile needs no
                   element-wise mask (interior of the allowed region)

    The optional leading batch dim supports batch-dependent mods (padding,
    document masks) — mirrors FlexAttention's create_block_mask(B=...).
    """

    kv_num_blocks: jax.Array
    kv_indices: jax.Array
    is_full: jax.Array
    q_block: int
    kv_block: int

    @property
    def batched(self) -> bool:
        return self.kv_indices.ndim == 3

    @property
    def sparsity(self) -> float:
        """Fraction of (q_block, kv_block) tiles skipped entirely."""
        total = int(np.prod(self.kv_indices.shape))
        live = int(jnp.sum(self.kv_num_blocks))
        return 1.0 - live / max(total, 1)


def build_block_mask(mod: MaskMod, Q: int, K: int, q_block: int = 128,
                     kv_block: int = 128, B: Optional[int] = None,
                     h: int = 0) -> BlockMask:
    """Compile a mask mod into block sparsity.

    Streams one q-block row at a time (never materialises Q×K) — mirrors
    FlexAttention's create_block_mask.  Pass ``B`` for batch-dependent mods.
    """
    nq = -(-Q // q_block)
    nk = -(-K // kv_block)

    def row(b, qb):
        q = qb * q_block + jnp.arange(q_block)[:, None]
        k = jnp.arange(nk * kv_block)[None, :]
        valid = (q < Q) & (k < K)
        m = mod(b, h, q, k) & valid
        m = m.reshape(q_block, nk, kv_block)
        any_live = jnp.any(m, axis=(0, 2))
        # "full" means every in-range element of the tile is allowed
        in_range = valid.reshape(q_block, nk, kv_block)
        all_live = jnp.all(m | ~in_range, axis=(0, 2)) & any_live
        return any_live, all_live

    def per_batch(b):
        return jax.lax.map(lambda qb: row(b, qb), jnp.arange(nq))

    if B is None:
        any_live, all_live = per_batch(0)
    else:
        any_live, all_live = jax.lax.map(per_batch, jnp.arange(B))

    counts = jnp.sum(any_live, axis=-1).astype(jnp.int32)
    order = jnp.argsort(~any_live, axis=-1, stable=True)  # live blocks first
    kv_indices = order.astype(jnp.int32)
    is_full = jnp.take_along_axis(all_live, order, axis=-1)
    return BlockMask(kv_num_blocks=counts, kv_indices=kv_indices,
                     is_full=is_full, q_block=q_block, kv_block=kv_block)


def causal_block_mask(Q: int, K: int, q_block: int = 128, kv_block: int = 128,
                      window: int = 0) -> BlockMask:
    """Analytic fast path (no mask evaluation) for causal / sliding-window."""
    nq = -(-Q // q_block)
    nk = -(-K // kv_block)
    qb = np.arange(nq)
    q_lo = qb * q_block
    q_hi = np.minimum(q_lo + q_block, Q) - 1
    # kv block kb spans [kb*kv_block, kb*kv_block + kv_block)
    hi_block = q_hi // kv_block  # last block any q in this row can see
    if window > 0:
        lo_pos = np.maximum(q_lo - window + 1, 0)
        lo_block = lo_pos // kv_block
    else:
        lo_block = np.zeros_like(qb)
    counts = (hi_block - lo_block + 1).astype(np.int32)
    max_blocks = nk
    kv_indices = np.zeros((nq, max_blocks), np.int32)
    is_full = np.zeros((nq, max_blocks), bool)
    for i in range(nq):
        idx = np.arange(lo_block[i], hi_block[i] + 1)
        kv_indices[i, : counts[i]] = idx
        # a tile is full iff its last kv pos <= first q pos (causal interior)
        # and (no window) its first kv pos > q_hi - window
        tile_last = idx * kv_block + kv_block - 1
        tile_first = idx * kv_block
        full = tile_last <= q_lo[i]
        if window > 0:
            full &= tile_first >= q_hi[i] - window + 1
        is_full[i, : counts[i]] = full
    return BlockMask(
        kv_num_blocks=jnp.asarray(counts), kv_indices=jnp.asarray(kv_indices),
        is_full=jnp.asarray(is_full), q_block=q_block, kv_block=kv_block,
    )
