"""Paged KV cache: page pools + block tables, shared across layers.

Pools are shaped (n_layers, num_pages, page_size, kv_heads, head_dim).
All sequences of a batch share one pool (the paper's *global KV cache*);
the same block table row addresses every layer's pool (standard paged-KV
layout — one indirection, L pools).

Three access paths:
  * ``write_prefill``  — scatter a whole prompt's K/V into its pages;
  * ``write_decode``   — scatter one new token per sequence (Alg.1 ASSIGN);
  * ``gather``         — materialise contiguous K/V (Alg.1 GATHER; the
    reference path — the Pallas kernel reads pages *in place* instead).

Sliding-window layers reuse pages as a ring: logical page index wraps modulo
the window's page count, so a 'W' layer's cache is bounded regardless of
sequence length (DESIGN.md §5 — RecurrentGemma local attention, and the
beyond-paper `swa` long-context variant for dense models).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import paging
from repro.core.paging import PageState


class PagedKVCache(NamedTuple):
    k_pages: jax.Array  # (L, num_pages, page_size, kv_heads, head_dim)
    v_pages: jax.Array  # (L, num_pages, page_size, kv_heads, head_dim)
    state: PageState

    @property
    def page_size(self) -> int:
        return self.k_pages.shape[2]

    @property
    def num_pages(self) -> int:
        return self.k_pages.shape[1]


def init_cache(n_layers: int, num_pages: int, page_size: int, kv_heads: int,
               head_dim: int, max_seqs: int, max_pages_per_seq: int,
               dtype=jnp.float32) -> PagedKVCache:
    shape = (n_layers, num_pages, page_size, kv_heads, head_dim)
    return PagedKVCache(
        k_pages=jnp.zeros(shape, dtype),
        v_pages=jnp.zeros(shape, dtype),
        state=paging.init_state(num_pages, max_seqs, max_pages_per_seq),
    )


def _scatter_tokens(pages: jax.Array, phys_pages: jax.Array, offsets: jax.Array,
                    vals: jax.Array) -> jax.Array:
    """pages: (num_pages, P, H, D); phys/offsets: (...,); vals: (..., H, D)."""
    flat_pages = phys_pages.reshape(-1)
    flat_off = offsets.reshape(-1)
    flat_vals = vals.reshape(-1, *vals.shape[-2:])
    # drop writes through NULL pages (unallocated → scheduler bug upstream)
    oob = jnp.where(flat_pages < 0, pages.shape[0], flat_pages)
    return pages.at[oob, flat_off].set(flat_vals, mode="drop")


def write_decode(cache: PagedKVCache, layer: int, seq_ids: jax.Array,
                 positions: jax.Array, k_new: jax.Array, v_new: jax.Array,
                 window: int = 0) -> PagedKVCache:
    """Append one token per sequence at ``positions`` (Alg.1 ASSIGN).

    k_new/v_new: (B, kv_heads, head_dim).  ``window>0`` wraps the logical
    page index (ring of pages) for bounded sliding-window layers.
    """
    ps = cache.page_size
    logical = positions // ps
    if window > 0:
        ring = -(-window // ps) + 1
        logical = logical % ring
    phys = cache.state.block_tables[seq_ids, logical]
    off = positions % ps
    return cache._replace(
        k_pages=cache.k_pages.at[layer].set(
            _scatter_tokens(cache.k_pages[layer], phys, off, k_new)),
        v_pages=cache.v_pages.at[layer].set(
            _scatter_tokens(cache.v_pages[layer], phys, off, v_new)),
    )


def write_layer_decode(k_pages_l: jax.Array, v_pages_l: jax.Array,
                       state: PageState, seq_ids: jax.Array,
                       positions: jax.Array, k_new: jax.Array,
                       v_new: jax.Array, window: int = 0
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-layer variant for use inside the layer scan (pools as scan xs)."""
    ps = k_pages_l.shape[1]
    logical = positions // ps
    if window > 0:
        ring = -(-window // ps) + 1
        logical = logical % ring
    phys = state.block_tables[seq_ids, logical]
    off = positions % ps
    return (_scatter_tokens(k_pages_l, phys, off, k_new),
            _scatter_tokens(v_pages_l, phys, off, v_new))


def write_layer_prefill(k_pages_l: jax.Array, v_pages_l: jax.Array,
                        tables: jax.Array, k: jax.Array, v: jax.Array,
                        lens: jax.Array, window: int = 0
                        ) -> Tuple[jax.Array, jax.Array]:
    """Scatter a full prompt (B, S, H, D) into pages for one layer.

    ``tables``: (B, max_pages) physical pages per sequence.  Positions are
    0..S-1 per sequence; tokens past ``lens`` are masked out.
    """
    B, S = k.shape[:2]
    ps = k_pages_l.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    logical = pos // ps
    valid = pos < lens[:, None]
    if window > 0:
        ring = -(-window // ps) + 1
        logical = logical % ring
        # ring slots would collide for positions > ring*ps back; only write
        # the live window (deterministic: at most one write per (page, off))
        valid &= pos >= lens[:, None] - ring * ps
    phys = jnp.take_along_axis(tables, logical, axis=1)
    off = pos % ps
    phys = jnp.where(valid, phys, -1)
    return (_scatter_tokens(k_pages_l, phys, off, k),
            _scatter_tokens(v_pages_l, phys, off, v))


def write_layer_prefill_at(k_pages_l: jax.Array, v_pages_l: jax.Array,
                           tables: jax.Array, k: jax.Array, v: jax.Array,
                           start: jax.Array, q_lens: jax.Array,
                           window: int = 0
                           ) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prompt *chunk* (B, C, H, D) into pages for one layer.

    The chunked-prefill generalisation of `write_layer_prefill`: chunk
    token ``i`` lands at absolute position ``start[b] + i``; tokens past
    ``q_lens`` (batch padding) are masked out.  ``start == 0`` and
    ``q_lens == lens`` reproduces the whole-prompt scatter exactly.
    ``window > 0`` wraps the logical page index over the ring; writes
    older than the ring capacity are dropped so at most one write hits
    each (page, offset) slot (deterministic scatter).
    """
    B, C = k.shape[:2]
    ps = k_pages_l.shape[1]
    off_i = jnp.arange(C, dtype=jnp.int32)[None, :]
    pos = start[:, None].astype(jnp.int32) + off_i
    logical = pos // ps
    valid = off_i < q_lens[:, None]
    if window > 0:
        ring = -(-window // ps) + 1
        logical = logical % ring
        end = (start + q_lens)[:, None]
        valid &= pos >= end - ring * ps
    phys = jnp.take_along_axis(tables, jnp.minimum(logical,
                                                   tables.shape[1] - 1),
                               axis=1)
    off = pos % ps
    phys = jnp.where(valid, phys, -1)
    return (_scatter_tokens(k_pages_l, phys, off, k),
            _scatter_tokens(v_pages_l, phys, off, v))


def gather_layer(k_pages_l: jax.Array, v_pages_l: jax.Array,
                 tables: jax.Array, max_len: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """Alg.1 GATHER: materialise (B, max_len, H, D) contiguous K/V.

    Reference path only — the Pallas kernel consumes pages without this copy.
    ``tables``: (B, max_pages).
    """
    ps = k_pages_l.shape[1]
    n_pages = -(-max_len // ps)
    tables = tables[:, :n_pages]  # (B, n_pages)
    safe = jnp.clip(tables, 0, k_pages_l.shape[0] - 1)
    k = k_pages_l[safe]  # (B, n_pages, ps, H, D)
    v = v_pages_l[safe]
    mask = (tables >= 0)[:, :, None, None, None]
    k = jnp.where(mask, k, 0).reshape(k.shape[0], n_pages * ps, *k.shape[-2:])
    v = jnp.where(mask, v, 0).reshape(v.shape[0], n_pages * ps, *v.shape[-2:])
    return k[:, :max_len], v[:, :max_len]


def copy_page(cache: PagedKVCache, src_page: jax.Array, dst_page: jax.Array
              ) -> PagedKVCache:
    """Copy one physical page across all layers (fork's copy-on-write tail)."""
    src = jnp.clip(src_page, 0, cache.num_pages - 1)
    dst = jnp.where((src_page < 0) | (dst_page < 0), cache.num_pages, dst_page)
    return cache._replace(
        k_pages=cache.k_pages.at[:, dst].set(cache.k_pages[:, src], mode="drop"),
        v_pages=cache.v_pages.at[:, dst].set(cache.v_pages[:, src], mode="drop"),
    )


# ---------------------------------------------------------------------------
# Contiguous (baseline) cache — the paper's comparison target.
# ---------------------------------------------------------------------------
class ContiguousKVCache(NamedTuple):
    """Max-length pre-allocated cache (the fragmenting baseline, §I)."""

    k: jax.Array  # (L, B, max_len, kv_heads, head_dim)
    v: jax.Array
    lens: jax.Array  # (B,)


def init_contiguous(n_layers: int, batch: int, max_len: int, kv_heads: int,
                    head_dim: int, dtype=jnp.float32) -> ContiguousKVCache:
    shape = (n_layers, batch, max_len, kv_heads, head_dim)
    return ContiguousKVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        lens=jnp.zeros((batch,), jnp.int32),
    )
