from repro.core import attention, cache, flex, paging
from repro.core.cache import ContiguousKVCache, PagedKVCache
from repro.core.paging import HostPageManager, PageState

__all__ = [
    "attention",
    "cache",
    "flex",
    "paging",
    "ContiguousKVCache",
    "PagedKVCache",
    "HostPageManager",
    "PageState",
]
