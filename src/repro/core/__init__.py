from repro.core import attention, cache, flex, paging, prefix_cache
from repro.core.cache import ContiguousKVCache, PagedKVCache
from repro.core.paging import HostPageManager, PageState
from repro.core.prefix_cache import PrefixCache

__all__ = [
    "attention",
    "cache",
    "flex",
    "paging",
    "prefix_cache",
    "ContiguousKVCache",
    "PagedKVCache",
    "HostPageManager",
    "PageState",
    "PrefixCache",
]
