"""Global prefix cache: radix-indexed KV page sharing across requests.

PagedAttention's copy-on-write machinery (arxiv 2309.06180, §CoW sharing)
makes prefix reuse an *allocator* operation: two sequences whose token
prefixes agree can point their block tables at the same physical pages.
``HostPageManager.fork`` already does this for an explicit parent→child
fork; this module generalizes it to *any* pair of requests, vLLM
automatic-prefix-caching / SGLang radix-attention style:

  * every released request indexes its **full** pages into a radix trie
    keyed by ``page_size``-token chunks (the page's exact token content —
    a page is shareable only when every token in it matches, so the trie
    edge IS the hash);
  * admission walks the trie along the new prompt and *attaches* to the
    longest cached chain: each matched page is aliased into the request's
    table row (refcount++), ``mgr.lens``/``prefill_pos`` advance past the
    match, and prefill runs only the un-cached suffix through the
    prefix-aware chunk kernel — zero prefill work for the hit portion;
  * divergence needs no page copy at all: the match is page-granular, so
    the first differing token simply starts a *fresh* page (the partial
    tail is never shared — the same reason ``fork`` copies it).

Residency = one refcount share.  A cached page holds exactly one extra
reference for the trie, so ``mgr.free`` on the donor naturally *retains*
the page (refcount drops to ≥ 1, page stays off the free list) instead of
recycling it, and the allocator invariant generalizes cleanly::

    refcount[p] == occurrences of p across table rows + (1 if cached)

Eviction is LRU and refcount-aware: only chains no live request points at
(refcount == 1) are reclaimable, leaf-first so the trie never orphans an
interior node.  ``HostPageManager.reserve`` reclaims on demand when the
free list alone cannot serve a reservation, so a full cache is *capacity*,
not pressure — schedulers size admission against
``mgr.available_pages = free + reclaimable``.

Safety gates (enforced by the Engine): pages must be immutable once
written, so the cache is only enabled for paged, pure self-attention
models — no windowed layers (ring slots are overwritten in place), no
cross-attention/encdec (K/V depend on per-request image/audio context,
token-keyed sharing would be wrong), no recurrent layers (state is not
page-addressed).
"""

from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.errors import SchedulerInvariantError


class _Node:
    """One cached page: a trie edge labelled by the page's token content."""

    __slots__ = ("chunk", "page", "parent", "children", "last_use", "seq")

    def __init__(self, chunk: Tuple[int, ...], page: int,
                 parent: Optional["_Node"], seq: int):
        self.chunk = chunk
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.last_use = 0
        self.seq = seq  # creation order: deterministic LRU tie-break

    def __repr__(self):  # pragma: no cover - debug aid
        return f"_Node(page={self.page}, children={len(self.children)})"


class PrefixCache:
    """Radix trie over cached KV pages, wired into a ``HostPageManager``.

    The cache owns one refcount share per resident page (residency is
    just another reference), so attach/insert/evict are pure integer
    bookkeeping on the host mirror — the device pools are untouched and
    the kernels gather shared pages through the block tables exactly as
    they gather private ones.

    ``faults`` (optional): a ``FaultPlan`` consulted at the ``attach``
    site — an injected ``evict`` models the cached chain disappearing
    between lookup and attach, and must degrade the admission to a plain
    cold prefill (gated by ``tests/test_faults.py``).
    """

    def __init__(self, manager, faults=None):
        self.mgr = manager
        self.faults = faults
        self.root = _Node((), -1, None, 0)
        self._page_node: Dict[int, _Node] = {}  # page id -> trie node
        self._clock = 0
        self._seq = 0
        # hit accounting (surfaced via Engine.robustness_report)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
        self.attach_faults = 0
        manager.cache = self  # reserve() reclaims through this hook

    # -- index ----------------------------------------------------------
    @property
    def resident_pages(self) -> int:
        return len(self._page_node)

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int], max_tokens: int) -> List[_Node]:
        """Longest cached chain along ``tokens`` (≤ ``max_tokens``),
        page-granular.  Pure lookup: no refcounts touched."""
        ps = self.mgr.page_size
        limit = max(0, max_tokens) // ps
        nodes: List[_Node] = []
        node = self.root
        i = 0
        while len(nodes) < limit:
            chunk = tuple(tokens[i:i + ps])
            if len(chunk) < ps:
                break
            child = node.children.get(chunk)
            if child is None:
                break
            nodes.append(child)
            node = child
            i += ps
        return nodes

    # -- attach (admission-time hit) ------------------------------------
    def attach(self, rid: int, tokens: Sequence[int],
               max_tokens: int) -> int:
        """Alias the longest cached prefix of ``tokens`` into ``rid``'s
        table row and return the matched token count (0 = miss).

        On a hit the request's row starts as the shared chain (one
        refcount bump per page) with ``mgr.lens[rid]`` covering it; the
        caller reserves the suffix and runs prefill from the matched
        position.  ``max_tokens`` caps the match — admission passes
        ``total_len - 1`` so at least one position is always prefilled
        (sampling needs that position's logits).

        Rollback contract: if the caller cannot reserve the suffix it
        calls ``mgr.free(rid)`` — the shared pages keep their residency
        reference and stay cached; nothing leaks.
        """
        if rid in self.mgr.tables:
            raise SchedulerInvariantError(
                f"prefix attach for rid {rid} which already holds a table "
                "row — attach is an admission-time operation", rid=rid)
        nodes = self.match(tokens, max_tokens)
        if not nodes:
            self.misses += 1
            return 0
        if (self.faults is not None
                and self.faults.fire("attach", rid=rid) == "evict"):
            # injected race: the matched chain is evicted between lookup
            # and attach — the admission must degrade to a cold prefill
            self.attach_faults += 1
            self._evict_chain(nodes)
            self.misses += 1
            return 0
        now = self._tick()
        for nd in nodes:
            nd.last_use = now
            self.mgr.refcount[nd.page] += 1
        self.mgr.tables[rid] = [nd.page for nd in nodes]
        matched = len(nodes) * self.mgr.page_size
        self.mgr.lens[rid] = matched
        self.hits += 1
        self.hit_tokens += matched
        return matched

    # -- insert (index written pages) -----------------------------------
    def insert(self, tokens: Sequence[int], row: Sequence[int],
               written: int) -> int:
        """Index ``row``'s first ``written // page_size`` full pages under
        their token content; returns pages newly cached.

        Only *fully written* pages are indexed — a partial tail page is
        mutable (its free slots are still being filled) and never shared.
        Chunks already present keep the existing, content-identical page;
        the duplicate page is simply not indexed (it recycles normally
        when its owner frees).  Idempotent per (tokens, row).
        """
        ps = self.mgr.page_size
        n_full = min(written, len(tokens)) // ps
        node = self.root
        now = self._tick()
        added = 0
        for pi in range(min(n_full, len(row))):
            chunk = tuple(tokens[pi * ps:(pi + 1) * ps])
            child = node.children.get(chunk)
            if child is None:
                page = row[pi]
                if page in self._page_node:
                    break  # already indexed under another path; stop
                self._seq += 1
                child = _Node(chunk, page, node, self._seq)
                node.children[chunk] = child
                self._page_node[page] = child
                self.mgr.refcount[page] += 1  # the residency share
                self.inserted_pages += 1
                added += 1
            child.last_use = now
            node = child
        return added

    # -- eviction -------------------------------------------------------
    def _evict(self, node: _Node) -> None:
        """Drop one detached leaf: residency share released, page back on
        the free list."""
        assert not node.children and self.mgr.refcount[node.page] == 1
        self.mgr.refcount[node.page] = 0
        self.mgr.free_list.append(node.page)
        del node.parent.children[node.chunk]
        del self._page_node[node.page]
        self.evicted_pages += 1

    def _evict_chain(self, nodes: List[_Node]) -> None:
        """Evict a matched chain deepest-first, stopping at the first node
        still pinned (live reference or cached descendants)."""
        for nd in reversed(nodes):
            if nd.children or self.mgr.refcount[nd.page] != 1:
                break
            self._evict(nd)

    def reclaimable(self) -> int:
        """Pages evictable right now: refcount == 1 (no live reference)
        and every cached descendant also evictable (leaf-first order
        exists).  This is the cache's contribution to
        ``mgr.available_pages``."""
        count = 0

        def walk(node: _Node) -> bool:
            nonlocal count
            subtree_ok = True
            for c in node.children.values():
                subtree_ok = walk(c) and subtree_ok
            if node is self.root:
                return subtree_ok
            ok = subtree_ok and self.mgr.refcount[node.page] == 1
            if ok:
                count += 1
            return ok

        walk(self.root)
        return count

    def reclaim(self, n_pages: int) -> int:
        """Evict up to ``n_pages`` detached pages, least-recently-used
        leaves first, back onto the free list.  Returns pages freed.
        Attached chains (refcount ≥ 2) are untouchable — eviction can
        never race a live request off its pages."""
        heap: List[Tuple[int, int, _Node]] = []
        for nd in self._page_node.values():
            if not nd.children and self.mgr.refcount[nd.page] == 1:
                heap.append((nd.last_use, nd.seq, nd))
        heapq.heapify(heap)
        freed = 0
        while heap and freed < n_pages:
            _, _, nd = heapq.heappop(heap)
            if (nd.children or nd.page not in self._page_node
                    or self.mgr.refcount[nd.page] != 1):
                continue  # pinned or re-attached since queued
            parent = nd.parent
            self._evict(nd)
            freed += 1
            if (parent is not self.root and not parent.children
                    and self.mgr.refcount[parent.page] == 1):
                heapq.heappush(heap, (parent.last_use, parent.seq, parent))
        return freed

    def clear(self) -> int:
        """Evict everything evictable (detached chains); attached pages
        stay.  Returns pages freed."""
        return self.reclaim(len(self._page_node))

    def clone(self, manager) -> "PrefixCache":
        """Structural copy wired into ``manager`` (a cloned host mirror —
        see ``HostPageManager.clone``).  Trie topology, residency index,
        LRU clocks and counters are all copied; the clone registers
        itself as ``manager.cache`` and never touches the original."""
        new = PrefixCache.__new__(PrefixCache)
        new.mgr = manager
        new.faults = self.faults
        new.root = _Node((), -1, None, 0)
        new._page_node = {}
        new._clock = self._clock
        new._seq = self._seq
        new.hits = self.hits
        new.misses = self.misses
        new.hit_tokens = self.hit_tokens
        new.inserted_pages = self.inserted_pages
        new.evicted_pages = self.evicted_pages
        new.attach_faults = self.attach_faults

        def copy_children(src: _Node, dst: _Node) -> None:
            for chunk, child in src.children.items():
                c = _Node(chunk, child.page, dst, child.seq)
                c.last_use = child.last_use
                dst.children[chunk] = c
                new._page_node[child.page] = c
                copy_children(child, c)

        copy_children(self.root, new.root)
        manager.cache = new
        return new

    # -- reporting ------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "resident_pages": self.resident_pages,
            "reclaimable_pages": self.reclaimable(),
            "inserted_pages": self.inserted_pages,
            "evicted_pages": self.evicted_pages,
            "attach_faults": self.attach_faults,
        }
