"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    r_t = σ(W_a x_t)                    (recurrence gate)
    i_t = σ(W_x x_t)                    (input gate)
    a_t = exp(c · softplus(Λ)⁻¹-style log a · r_t)   with a = σ(Λ)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x̃_t)

x̃ is the conv1d(width 4)-mixed input branch.  The full block is Griffin's
recurrent block: two input projections (recurrent branch + GeLU gate
branch), temporal conv, RG-LRU, gated merge, output projection.

Training/prefill uses ``jax.lax.associative_scan`` (the recurrence is a
first-order linear scan — exactly parallelisable, TPU-native; this is the
recurrent-scan analogue of the paper's "linear latency growth" claim).
Decode carries (h, conv window) — O(1) state, so long_500k is natural.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.spec import ParamSpec

_C = 8.0  # Griffin's fixed temperature on the log-recurrence


def rglru_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv1d_width
    return {
        "wx": ParamSpec((d, w), ("embed", "state")),
        "wy": ParamSpec((d, w), ("embed", "state")),  # GeLU gate branch
        "conv": ParamSpec((cw, w), (None, "state"), "small_normal"),
        "conv_b": ParamSpec((w,), ("state",), "zeros"),
        "wa": ParamSpec((w, w), ("state", None), "small_normal"),
        "wi": ParamSpec((w, w), ("state", None), "small_normal"),
        "a_log": ParamSpec((w,), ("state",), "a_log"),
        "wout": ParamSpec((w, d), ("state", "embed")),
    }


def _gates(p: Dict, xb: jax.Array):
    """xb: (..., w) conv-mixed branch → (log_a_t, gated input)."""
    r = jax.nn.sigmoid(xb @ p["wa"]).astype(jnp.float32)
    i = jax.nn.sigmoid(xb @ p["wi"])
    log_a = -_C * r * jax.nn.softplus(p["a_log"])  # log a_t  (a_t ∈ (0,1))
    a2 = jnp.exp(2 * log_a)
    gated = (jnp.sqrt(jnp.maximum(1 - a2, 1e-12)).astype(xb.dtype) * i * xb)
    return log_a, gated


def rglru_train(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) → (B, S, d) via associative scan over time."""
    B, S, d = x.shape
    xb = x @ p["wx"]
    # temporal conv (causal, width cw)
    cw = p["conv"].shape[0]
    pad = jnp.pad(xb, ((0, 0), (cw - 1, 0), (0, 0)))
    xc = sum(pad[:, i:i + S] * p["conv"][i] for i in range(cw)) + p["conv_b"]

    log_a, gated = _gates(p, xc)

    # h_t = a_t h_{t-1} + b_t  — associative first-order scan
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b1 * jnp.exp(a2).astype(b1.dtype) + b2

    la, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    del la
    y = jax.nn.gelu(x @ p["wy"])
    return (h * y) @ p["wout"]


def rglru_init_state(B: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((B, w), dtype),
            "conv": jnp.zeros((B, cfg.conv1d_width - 1, w), dtype)}


def rglru_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    """One step.  x: (B, d)."""
    xb = x @ p["wx"]  # (B, w)
    hist = jnp.concatenate([state["conv"], xb[:, None]], axis=1)  # (B,cw,w)
    xc = jnp.einsum("bcw,cw->bw", hist, p["conv"]) + p["conv_b"]
    log_a, gated = _gates(p, xc)
    h = state["h"] * jnp.exp(log_a).astype(x.dtype) + gated
    y = jax.nn.gelu(x @ p["wy"])
    out = (h * y) @ p["wout"]
    return out, {"h": h, "conv": hist[:, 1:]}
