"""GQA attention layer: projections + RoPE around the core attention ops.

Three phases share the same parameters:
  train   — full-sequence causal (optionally windowed) attention;
  prefill — same, but also scatters K/V into the paged cache;
  decode  — one token via the paged kernel (or the contiguous baseline).

Cross-attention (VLM image layers, whisper enc→dec) reuses the projections
with externally-provided K/V and no causal mask.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import attention as core_attn
from repro.core import cache as kvcache
from repro.core.paging import PageState
from repro.distributed.sharding import logical_shard
from repro.models.layers import apply_rope
from repro.models.spec import ParamSpec


def attn_spec(cfg: ModelConfig) -> Dict:
    d, H, Hkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    return {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, hd, d), ("heads", None, "embed")),
    }


def _qkv(p: Dict, x: jax.Array, positions: Optional[jax.Array],
         theta: float) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"])
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"])
    if positions is not None:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    # seq dim annotated "attn_seq": None in the TP plan (heads carry
    # "model"), ("model",) under the ring plan (heads replicated) — without
    # it the constraint would force an all-gather of q/k/v over "model"
    # right before ring attention re-shards them (measured 1.5 GiB/layer)
    lead = ("attn_seq",) * (x.ndim - 2)
    q = logical_shard(q, "batch", *lead, "heads", None)
    k = logical_shard(k, "batch", *lead, "kv_heads", None)
    v = logical_shard(v, "batch", *lead, "kv_heads", None)
    return q, k, v


def kv_quant(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Quantize K/V for pool storage (int8 mode); identity otherwise."""
    if cfg.kv_dtype != "int8":
        return x
    q = jnp.round(x.astype(jnp.float32) / cfg.kv_scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def kv_pool_dtype(cfg: ModelConfig, dtype):
    return jnp.int8 if cfg.kv_dtype == "int8" else dtype


def _out(p: Dict, o: jax.Array) -> jax.Array:
    y = jnp.einsum("...hk,hkd->...d", o, p["wo"])
    seq = ("seq",) if y.ndim == 3 else ()
    return logical_shard(y, "batch", *seq, "act_embed")


# ---------------------------------------------------------------------------
def attn_train(p: Dict, x: jax.Array, cfg: ModelConfig, *, window: int = 0,
               lens: Optional[jax.Array] = None, causal: bool = True,
               impl: str = "jnp") -> jax.Array:
    """x: (B, S, d) → (B, S, d)."""
    B, S, _ = x.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q, k, v = _qkv(p, x, pos, cfg.rope_theta)
    o = core_attn.prefill_attention(q, k, v, window=window, lens=lens,
                                    causal=causal, impl=impl)
    return _out(p, o)


def attn_prefill(p: Dict, x: jax.Array, cfg: ModelConfig,
                 k_pages: jax.Array, v_pages: jax.Array, tables: jax.Array,
                 lens: jax.Array, *, window: int = 0, impl: str = "jnp"
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill: attend over the prompt AND write K/V into the paged pools.

    ``tables``: (B, n_kv_shards, pages_per_shard) — prefill pools are laid
    out per-data-shard (n_kv_shards == 1); a disaggregated deployment
    reshards pools between prefill and decode engines (DESIGN.md §4).

    Returns (out, k_pages', v_pages').
    """
    B, S, _ = x.shape
    pos = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q, k, v = _qkv(p, x, pos, cfg.rope_theta)
    from repro.distributed.collectives import write_prefill_sharded
    k_pages, v_pages = write_prefill_sharded(
        k_pages, v_pages, tables.reshape(B, -1), kv_quant(cfg, k),
        kv_quant(cfg, v), lens, window=window)
    o = core_attn.prefill_attention(q, k, v, window=window, lens=lens,
                                    impl=impl)
    return _out(p, o), k_pages, v_pages


def attn_prefill_chunked(p: Dict, x: jax.Array, cfg: ModelConfig,
                         k_pages: jax.Array, v_pages: jax.Array,
                         tables: jax.Array, q_start: jax.Array,
                         q_lens: jax.Array, *, window: int = 0,
                         impl: str = "jnp",
                         interpret: Optional[bool] = None,
                         pages_per_block: Optional[int] = None,
                         num_splits: Optional[int] = None,
                         combine_mode: Optional[str] = None,
                         backend: Optional[str] = None,
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked prefill: attend one prompt *chunk* resuming from the cached
    prefix, writing the chunk's K/V into the existing pages.

    ``x``: (B, C, d) chunk activations; ``q_start``: (B,) tokens already
    cached (the resume position — RoPE and masks use absolute positions
    ``q_start + i``); ``q_lens``: (B,) live tokens of this chunk (≤ C,
    batch padding beyond).  ``tables``: (B, n_kv_shards, pages_per_shard)
    with the rows the scheduler reserved chunk-by-chunk.

    Dense layers follow the decode contract — scatter first, then the
    prefix-aware paged attention reads prefix *and* chunk back through
    the block table (`core_attn.prefill_attention_paged`; ``impl=
    "pallas"`` runs the Q-block × KV-block kernel).  Sliding-window
    layers attend first over the intact ring prefix + fresh chunk K/V,
    then scatter (ring wraps would otherwise overwrite prefix slots the
    chunk still needs).

    Returns (out, k_pages', v_pages').
    """
    B, C, _ = x.shape
    pos = (q_start[:, None].astype(jnp.int32)
           + jnp.arange(C, dtype=jnp.int32)[None])
    q, k, v = _qkv(p, x, pos, cfg.rope_theta)
    kv_scale = cfg.kv_scale if cfg.kv_dtype == "int8" else 0.0
    t = tables.reshape(B, -1)
    if window > 0:
        o = core_attn.prefill_attention_windowed_chunk(
            q, k, v, k_pages, v_pages, t, q_start, q_lens,
            window=window, kv_scale=kv_scale)
        k_pages, v_pages = kvcache.write_layer_prefill_at(
            k_pages, v_pages, t, kv_quant(cfg, k), kv_quant(cfg, v),
            q_start, q_lens, window=window)
    else:
        k_pages, v_pages = kvcache.write_layer_prefill_at(
            k_pages, v_pages, t, kv_quant(cfg, k), kv_quant(cfg, v),
            q_start, q_lens)
        o = core_attn.prefill_attention_paged(
            q, k_pages, v_pages, t, q_start + q_lens, q_start,
            impl=impl, interpret=interpret, kv_scale=kv_scale,
            pages_per_block=pages_per_block, num_splits=num_splits,
            combine_mode=combine_mode, backend=backend)
    return _out(p, o), k_pages, v_pages


def attn_decode(p: Dict, x: jax.Array, cfg: ModelConfig,
                k_pages: jax.Array, v_pages: jax.Array, tables: jax.Array,
                positions: jax.Array, *, window: int = 0,
                impl: str = "ref", attn_ctx: Optional[Dict] = None,
                interpret: Optional[bool] = None,
                pages_per_block: Optional[int] = None,
                num_splits: Optional[int] = None,
                combine_mode: Optional[str] = None,
                backend: Optional[str] = None,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Decode one token.  x: (B, d); positions: (B,) 0-based position of the
    incoming token; tables: (B, n_kv_shards, pages_per_shard).  Appends K/V
    then attends over lens = positions+1 tokens.

    ``attn_ctx`` = {"scheme": local|tp|dp|kvp, "batch_axes": (...)} selects
    the distribution scheme (DESIGN.md §4); windowed layers degrade kvp→dp
    (bounded ring pools are replicated across "model", not striped).
    ``pages_per_block`` / ``num_splits`` tune the Pallas decode kernel's
    KV-block width and flash-decoding split-K factor; ``combine_mode``
    picks the split-K merge implementation, local and distributed alike
    ("pallas" = fused combine kernel, "jnp" = epilogue; None → auto);
    ``backend`` selects the kernel lowering ("tpu" | "gpu"; None → auto
    from the running platform).

    Returns (out, k_pages', v_pages').
    """
    from repro.distributed.collectives import (
        decode_attention_sharded, write_decode_sharded)

    ctx = attn_ctx or {}
    scheme = ctx.get("scheme", "local")
    if window > 0 and scheme == "kvp":
        scheme = "dp"
    batch_axes = tuple(ctx.get("batch_axes", ()))

    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(p, x, positions, cfg.rope_theta)  # (B, H/Hkv, hd)
    k_pages, v_pages = write_decode_sharded(
        k_pages, v_pages, tables, positions, kv_quant(cfg, k),
        kv_quant(cfg, v), window=window,
        scheme=scheme, batch_axes=batch_axes)
    q4 = q.reshape(B, Hkv, H // Hkv, hd)
    o4 = decode_attention_sharded(
        q4, k_pages, v_pages, tables, positions + 1, window=window,
        scheme=scheme, batch_axes=batch_axes, impl=impl, interpret=interpret,
        kv_scale=cfg.kv_scale if cfg.kv_dtype == "int8" else 0.0,
        pages_per_block=pages_per_block, num_splits=num_splits,
        combine_mode=combine_mode, backend=backend)
    return _out(p, o4.reshape(B, H, hd)), k_pages, v_pages


def attn_decode_contiguous(p: Dict, x: jax.Array, cfg: ModelConfig,
                           k_buf: jax.Array, v_buf: jax.Array,
                           positions: jax.Array, *, window: int = 0
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The paper's baseline path: max-length contiguous per-request buffers.

    k_buf/v_buf: (B, max_len, Hkv, hd).
    """
    q, k, v = _qkv(p, x, positions, cfg.rope_theta)
    B = x.shape[0]
    k_buf = k_buf.at[jnp.arange(B), positions].set(k)
    v_buf = v_buf.at[jnp.arange(B), positions].set(v)
    o = core_attn.decode_attention_contiguous(
        q, k_buf, v_buf, positions + 1, window=window)
    return _out(p, o), k_buf, v_buf


def cross_attn(p: Dict, x: jax.Array, k: jax.Array, v: jax.Array,
               cfg: ModelConfig) -> jax.Array:
    """Cross attention: q from x (B, S, d) or (B, d); k/v precomputed
    (B, T, Hkv, hd).  No positional rotation (keys carry none)."""
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"])
    squeeze = x.ndim == 2
    if squeeze:
        q = q[:, None]
    o = core_attn.prefill_attention(q, k, v, causal=False, impl="jnp")
    if squeeze:
        o = o[:, 0]
    return _out(p, o)


def cross_kv(p: Dict, ctx: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder/image context (B, T, d)."""
    k = jnp.einsum("btd,dhk->bthk", ctx, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", ctx, p["wv"])
    return k, v
