"""Mixture-of-Experts FFN: top-k routing with capacity-bounded gather
dispatch (sort-free, scatter/gather based — no dense all-experts compute, so
compiled FLOPs reflect *active* expert compute, and expert-parallel sharding
turns the dispatch into an all-to-all on the mesh).

Used by granite-moe (32e top-8) and olmoe (64e top-8).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_shard
from repro.models.spec import ParamSpec


def moe_spec(cfg: ModelConfig) -> Dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    return {
        "router": ParamSpec((d, E), ("embed", None)),
        "wg": ParamSpec((E, d, f), ("experts", "embed", None)),
        "wu": ParamSpec((E, d, f), ("experts", "embed", None)),
        "wd": ParamSpec((E, f, d), ("experts", None, "embed")),
    }


_FROM_CFG = object()


def apply_moe(p: Dict, x: jax.Array, cfg: ModelConfig,
              capacity_factor=_FROM_CFG
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) or (B, d).  Returns (out, aux_loss).

    ``capacity_factor=None`` → *dropless* (C = T): exact routing, used for
    inference and correctness tests (a token can contribute at most one of
    its k choices to any single expert, so C = T suffices).  Training uses a
    finite factor (Switch-style dropping; the aux loss balances load).
    """
    if capacity_factor is _FROM_CFG:
        capacity_factor = cfg.moe_capacity or None
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None, :]
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xf = x.reshape(T, d)

    logits = xf @ p["router"]  # (T, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- load-balance auxiliary loss (Switch/OLMoE style) ------------------
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E)
    ce = jnp.mean(one_hot_top1, axis=0)  # fraction routed (top-1)
    aux = E * jnp.sum(me * ce)

    # --- capacity-bounded dispatch -----------------------------------------
    if capacity_factor is None:
        C = T  # dropless
    else:
        C = max(1, int(T * k / E * capacity_factor))
    assign = idx.reshape(-1)  # (T*k,) expert of each (token, choice)
    onehot = jax.nn.one_hot(assign, E, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot  # rank within expert
    slot = jnp.sum(pos_in_e * onehot, axis=-1)  # (T*k,)
    ok = slot < C  # dropped tokens beyond capacity

    token_of = jnp.arange(T).repeat(k)  # (T*k,)
    # dispatch index buffer: (E, C) → token id (sentinel T = zero row).
    # dropped entries are routed to an OOB expert index and dropped; live
    # (assign, slot) pairs are unique by construction, so no write races.
    disp = jnp.full((E, C), T, jnp.int32)
    disp = disp.at[jnp.where(ok, assign, E), jnp.where(ok, slot, 0)].set(
        token_of, mode="drop")

    # gather with clamped indices: empty slots (sentinel T) read an
    # arbitrary row — their expert outputs are never combined (masked by
    # ``ok``), avoiding a padded full copy of xf per layer
    xe = xf[jnp.clip(disp, 0, T - 1)]  # (E, C, d) gather — no matmul FLOPs
    xe = logical_shard(xe, "experts", None, "act_embed")

    if cfg.activation == "silu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * \
            jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) * \
            jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])  # (E, C, d)
    ye = logical_shard(ye, "experts", None, "act_embed")

    # --- combine: weighted scatter-add back to tokens (f32 accumulation,
    # explicit — a bf16 buffer would silently promote via the f32 gates) ---
    gates_flat = gate_vals.reshape(-1)  # (T*k,) f32
    out = jnp.zeros((T + 1, d), jnp.float32)
    src_e = jnp.where(ok, assign, E)  # OOB → dropped
    src_c = jnp.where(ok, slot, 0)
    contrib = (ye[jnp.clip(src_e, 0, E - 1), src_c].astype(jnp.float32)
               * gates_flat[:, None])
    contrib = jnp.where(ok[:, None], contrib, 0.0)
    out = out.at[jnp.where(ok, token_of, T)].add(contrib, mode="drop")
    out = out[:T].reshape(B, S, d)
    if squeeze:
        out = out[:, 0]
    return out.astype(x.dtype), aux.astype(jnp.float32)
