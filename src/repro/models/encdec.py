"""Whisper-style encoder-decoder (arXiv:2212.04356).

The mel-spectrogram + conv frontend is STUBBED (harness carve-out):
``input_specs`` provides (B, n_frames, d_model) frame embeddings.  The
transformer itself is real: a bidirectional encoder and a causal decoder
whose every layer carries self-attention (paged KV cache at decode) +
cross-attention over encoder output (fixed-length KV, computed once at
prefill — the "fixed pages" case of the paper's allocator) + MLP.

Sinusoidal positions (no RoPE), LayerNorm, GELU (ungated).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn
from repro.models import layers, spec as pspec
from repro.models.spec import ParamSpec


def _enc_layer_spec(cfg: ModelConfig) -> Dict:
    return {"ln1": layers.norm_spec(cfg), "attn": attn.attn_spec(cfg),
            "ln2": layers.norm_spec(cfg), "mlp": layers.mlp_spec(cfg)}


def _dec_layer_spec(cfg: ModelConfig) -> Dict:
    return {"ln1": layers.norm_spec(cfg), "self_attn": attn.attn_spec(cfg),
            "lnx": layers.norm_spec(cfg), "cross_attn": attn.attn_spec(cfg),
            "ln2": layers.norm_spec(cfg), "mlp": layers.mlp_spec(cfg)}


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.activation == "gelu_ungated", "whisper uses ungated GELU"
        self.cfg = cfg
        self.n_attn_layers = cfg.n_layers  # decoder self-attn layers
        self.window = 0

    def param_spec(self) -> Dict:
        cfg = self.cfg
        return {
            "embed": layers.embed_spec(cfg),
            "enc": pspec.stack_specs(_enc_layer_spec(cfg),
                                     cfg.n_encoder_layers, "layers"),
            "dec": pspec.stack_specs(_dec_layer_spec(cfg), cfg.n_layers,
                                     "layers"),
            "ln_enc": layers.norm_spec(cfg),
            "ln_f": layers.norm_spec(cfg),
        }

    def init_params(self, rng, dtype=jnp.float32):
        return pspec.materialize(self.param_spec(), rng, dtype)

    def param_axes(self):
        return pspec.axes_tree(self.param_spec())

    def abstract_params(self, dtype=jnp.float32):
        return pspec.abstract(self.param_spec(), dtype)

    # ------------------------------------------------------------------
    def encode(self, params: Dict, frames: jax.Array,
               impl: str = "jnp") -> jax.Array:
        """frames: (B, F, d) stubbed conv-frontend output → (B, F, d)."""
        cfg = self.cfg
        F = frames.shape[1]
        x = frames + layers.sinusoidal_positions(F, cfg.d_model)[None]
        x = x.astype(frames.dtype)

        def body(x, p):
            h = layers.apply_norm(p["ln1"], x)
            x = x + attn.attn_train(p["attn"], h, cfg, causal=False, impl=impl)
            x = x + layers.apply_mlp(p["mlp"],
                                     layers.apply_norm(p["ln2"], x), cfg)
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc"],
                            unroll=cfg.scan_unroll or 1)
        return layers.apply_norm(params["ln_enc"], x)

    def forward(self, params: Dict, tokens: jax.Array,
                extra: Optional[Dict] = None, impl: str = "jnp") -> jax.Array:
        """Teacher-forced decode over (B, S) tokens with (B, F, d) frames."""
        cfg = self.cfg
        B, S = tokens.shape
        enc = self.encode(params, extra["frames"], impl)
        x = layers.embed_tokens(params["embed"], tokens)
        x = x + layers.sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)

        def body(x, p):
            h = layers.apply_norm(p["ln1"], x)
            x = x + attn.attn_train(p["self_attn"], h, cfg, impl=impl)
            h = layers.apply_norm(p["lnx"], x)
            ck, cv = attn.cross_kv(p["cross_attn"], enc)
            x = x + attn.cross_attn(p["cross_attn"], h, ck, cv, cfg)
            x = x + layers.apply_mlp(p["mlp"],
                                     layers.apply_norm(p["ln2"], x), cfg)
            return x, None

        x, _ = jax.lax.scan(body, x, params["dec"],
                            unroll=cfg.scan_unroll or 1)
        x = layers.apply_norm(params["ln_f"], x)
        return layers.unembed(params["embed"], x, cfg)

    def loss_fn(self, params: Dict, batch: Dict, impl: str = "jnp"):
        from repro.models.transformer import _xent
        logits = self.forward(params, batch["inputs"],
                              {"frames": batch["frames"]}, impl)
        loss = _xent(logits, batch["targets"], batch.get("mask"))
        return loss, {"ce": loss, "aux": jnp.float32(0.0)}

    # ------------------------------------------------------------------
    def init_decode_state(self, run: RunConfig, dtype=jnp.float32,
                          n_kv_shards: int = 1, abstract: bool = False
                          ) -> Dict:
        cfg = self.cfg
        B = run.global_batch
        ps = cfg.page_size
        pages_per_seq = -(-run.pages_per_seq // n_kv_shards) * n_kv_shards
        num_pages = B * pages_per_seq
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

        def arr(shape, dt):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dt)
            return jnp.zeros(shape, dt)

        pool = (cfg.n_layers, num_pages, ps, Hkv, hd)
        pool_dt = jnp.int8 if cfg.kv_dtype == "int8" else dtype
        return {
            "pos": arr((B,), jnp.int32),
            "k_pages": arr(pool, pool_dt),
            "v_pages": arr(pool, pool_dt),
            "tables": arr((B, n_kv_shards, pages_per_seq // n_kv_shards),
                          jnp.int32),
            "cross_k": arr((cfg.n_layers, B, cfg.n_audio_frames, Hkv, hd),
                           dtype),
            "cross_v": arr((cfg.n_layers, B, cfg.n_audio_frames, Hkv, hd),
                           dtype),
        }

    def prefill(self, params: Dict, tokens: jax.Array, state: Dict,
                lens: Optional[jax.Array] = None,
                extra: Optional[Dict] = None, impl: str = "jnp",
                attn_ctx: Optional[Dict] = None) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B, S = tokens.shape
        lens = lens if lens is not None else jnp.full((B,), S, jnp.int32)
        enc = self.encode(params, extra["frames"], impl)
        x = layers.embed_tokens(params["embed"], tokens)
        x = x + layers.sinusoidal_positions(S, cfg.d_model)[None].astype(x.dtype)

        st = dict(state)
        new_k, new_v, new_ck, new_cv = [], [], [], []
        for li in range(cfg.n_layers):
            p = jax.tree_util.tree_map(lambda a: a[li], params["dec"])
            h = layers.apply_norm(p["ln1"], x)
            o, kp, vp = attn.attn_prefill(
                p["self_attn"], h, cfg, st["k_pages"][li], st["v_pages"][li],
                st["tables"], lens, impl=impl)
            new_k.append(kp)
            new_v.append(vp)
            x = x + o
            h = layers.apply_norm(p["lnx"], x)
            ck, cv = attn.cross_kv(p["cross_attn"], enc)
            new_ck.append(ck)
            new_cv.append(cv)
            x = x + attn.cross_attn(p["cross_attn"], h, ck, cv, cfg)
            x = x + layers.apply_mlp(p["mlp"],
                                     layers.apply_norm(p["ln2"], x), cfg)

        st.update(k_pages=jnp.stack(new_k), v_pages=jnp.stack(new_v),
                  cross_k=jnp.stack(new_ck), cross_v=jnp.stack(new_cv),
                  pos=lens)
        x = layers.apply_norm(params["ln_f"], x)
        last = jnp.take_along_axis(
            x, jnp.maximum(lens - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        return layers.unembed(params["embed"], last, cfg), st

    def prefill_chunk(self, params: Dict, tokens: jax.Array, state: Dict,
                      q_start: jax.Array, q_lens: jax.Array,
                      extra: Optional[Dict] = None, impl: str = "jnp",
                      interpret: Optional[bool] = None,
                      pages_per_block: Optional[int] = None,
                      num_splits: Optional[int] = None,
                      combine_mode: Optional[str] = None,
                      backend: Optional[str] = None
                      ) -> Tuple[jax.Array, Dict]:
        """Chunked decoder prefill (same contract as
        `TransformerModel.prefill_chunk`): the chunk's self-attention
        resumes from the cached prefix pages at ``q_start``.  The audio
        encoder and per-layer cross-attention K/V depend only on the
        frames, and the gate is **per row**: only rows at chunk 0
        (``q_start == 0``) run the encoder — their frames are gathered
        into a smaller encode batch and their fresh cross-K/V scattered
        into the cached ``state["cross_k"/"cross_v"]`` stack; resume rows
        never pay the encoder again.  (The former batch-wide gate
        re-encoded the whole sub-batch whenever *any* row was at chunk 0
        — idempotent for resume rows, but O(B) encoder work per
        admission.)  Host-driven (eager) dispatch, hence the concrete
        numpy indices."""
        cfg = self.cfg
        B, C = tokens.shape
        firsts = np.flatnonzero(np.asarray(q_start) == 0)
        if "cross_k" not in state or firsts.size == B:
            cross_mode, first_rows = "full", None
            enc = self.encode(params, extra["frames"], impl)
        elif firsts.size == 0:
            cross_mode, first_rows, enc = "reuse", None, None
        else:
            cross_mode = "partial"
            first_rows = jnp.asarray(firsts)
            enc = self.encode(params, extra["frames"][first_rows], impl)
        pos = (q_start[:, None].astype(jnp.int32)
               + jnp.arange(C, dtype=jnp.int32)[None])
        x = layers.embed_tokens(params["embed"], tokens)
        x = x + layers.sinusoidal_at(pos, cfg.d_model).astype(x.dtype)

        st = dict(state)
        new_k, new_v, new_ck, new_cv = [], [], [], []
        for li in range(cfg.n_layers):
            p = jax.tree_util.tree_map(lambda a: a[li], params["dec"])
            h = layers.apply_norm(p["ln1"], x)
            o, kp, vp = attn.attn_prefill_chunked(
                p["self_attn"], h, cfg, st["k_pages"][li], st["v_pages"][li],
                st["tables"], q_start, q_lens, impl=impl,
                interpret=interpret, pages_per_block=pages_per_block,
                num_splits=num_splits, combine_mode=combine_mode,
                backend=backend)
            new_k.append(kp)
            new_v.append(vp)
            x = x + o
            h = layers.apply_norm(p["lnx"], x)
            if cross_mode == "reuse":
                ck, cv = state["cross_k"][li], state["cross_v"][li]
            elif cross_mode == "partial":
                # fresh cross-K/V for first-chunk rows only, scattered
                # into the cached stack; resume rows are untouched
                ck_new, cv_new = attn.cross_kv(p["cross_attn"], enc)
                ck = state["cross_k"][li].at[first_rows].set(ck_new)
                cv = state["cross_v"][li].at[first_rows].set(cv_new)
            else:
                ck, cv = attn.cross_kv(p["cross_attn"], enc)
            new_ck.append(ck)
            new_cv.append(cv)
            x = x + attn.cross_attn(p["cross_attn"], h, ck, cv, cfg)
            x = x + layers.apply_mlp(p["mlp"],
                                     layers.apply_norm(p["ln2"], x), cfg)

        st.update(k_pages=jnp.stack(new_k), v_pages=jnp.stack(new_v),
                  cross_k=jnp.stack(new_ck), cross_v=jnp.stack(new_cv),
                  pos=q_start + q_lens)
        x = layers.apply_norm(params["ln_f"], x)
        last = jnp.take_along_axis(
            x, jnp.maximum(q_lens - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        return layers.unembed(params["embed"], last, cfg), st

    def decode_step(self, params: Dict, tokens: jax.Array, state: Dict,
                    impl: str = "ref", attn_ctx: Optional[Dict] = None,
                    interpret: Optional[bool] = None,
                    pages_per_block: Optional[int] = None,
                    num_splits: Optional[int] = None,
                    combine_mode: Optional[str] = None,
                    backend: Optional[str] = None
                    ) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        B = tokens.shape[0]
        pos = state["pos"]
        x = layers.embed_tokens(params["embed"], tokens)
        # closed-form sinusoidal position (decode positions may exceed
        # whisper's native 448 in the assigned decode_32k shape)
        x = x + layers.sinusoidal_at(pos, cfg.d_model).astype(x.dtype)
        tables = state["tables"]

        def body(x, xs):
            p, kp, vp, ck, cv = (xs["p"], xs["kp"], xs["vp"], xs["ck"],
                                 xs["cv"])
            h = layers.apply_norm(p["ln1"], x)
            o, kp, vp = attn.attn_decode(
                p["self_attn"], h, cfg, kp, vp, tables, pos, impl=impl,
                attn_ctx=attn_ctx, interpret=interpret,
                pages_per_block=pages_per_block, num_splits=num_splits,
                combine_mode=combine_mode, backend=backend)
            x = x + o
            h = layers.apply_norm(p["lnx"], x)
            x = x + attn.cross_attn(p["cross_attn"], h, ck, cv, cfg)
            x = x + layers.apply_mlp(p["mlp"],
                                     layers.apply_norm(p["ln2"], x), cfg)
            return x, {"kp": kp, "vp": vp}

        xs = {"p": params["dec"], "kp": state["k_pages"],
              "vp": state["v_pages"], "ck": state["cross_k"],
              "cv": state["cross_v"]}
        x, ys = jax.lax.scan(body, x, xs, unroll=cfg.scan_unroll or 1)

        st = dict(state, k_pages=ys["kp"], v_pages=ys["vp"], pos=pos + 1)
        x = layers.apply_norm(params["ln_f"], x)
        return layers.unembed(params["embed"], x, cfg), st
