"""Parameter-spec system.

Model builders describe parameters as a pytree of ``ParamSpec`` leaves
(shape + logical sharding axes + init).  From one spec tree we derive:
  * initialised parameters        (``materialize``)
  * the logical-axes tree         (``axes_tree``)    → NamedShardings
  * ShapeDtypeStructs for dry-run (``abstract``)     → .lower() without RAM
keeping init, sharding, and dry-run shapes impossible to de-synchronise.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: float = 0.02


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def materialize(spec_tree, rng: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))

    def mk(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "a_log":  # RG-LRU Λ init: a ∈ [0.9, 0.999]
            u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
            return jnp.log(u / (1 - u)).astype(dtype)
        scale = spec.scale
        if spec.init == "small_normal":
            scale = spec.scale / np.sqrt(max(spec.shape[-1], 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale
                ).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def axes_tree(spec_tree):
    return jax.tree_util.tree_map(lambda s: tuple(s.axes), spec_tree,
                                  is_leaf=_is_spec)


def abstract(spec_tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree,
        is_leaf=_is_spec)


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = None):
    """Prepend a stacking (layer) dimension to every spec in the tree."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + tuple(s.shape), (axis_name,) + tuple(s.axes),
                            s.init, s.scale),
        spec_tree, is_leaf=_is_spec)
