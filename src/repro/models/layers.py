"""Shared neural-net building blocks (pure JAX, no framework deps)."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import logical_shard
from repro.errors import EngineConfigError
from repro.models.spec import ParamSpec


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def norm_spec(cfg: ModelConfig) -> Dict:
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((cfg.d_model,), ("embed",), "ones"),
                "bias": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    return {"scale": ParamSpec((cfg.d_model,), ("embed",), "ones")}


def apply_norm(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"] + p["bias"]).astype(x.dtype)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary / sinusoidal positions
# ---------------------------------------------------------------------------
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., H, D) with matching leading dims on positions (...,)."""
    if theta <= 0:
        return x
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]  # broadcast over heads
    cos = cos[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = np.arange(n)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, jnp.float32)


def sinusoidal_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embedding at dynamic positions (...,) → (..., d).

    Closed-form (no table) so decode positions are unbounded."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    inv = jnp.power(10000.0, -2.0 * dim / d)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------
def mlp_spec(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.activation in ("silu", "gelu"):  # gated (SwiGLU / GeGLU)
        return {"wg": ParamSpec((d, f), ("embed", "mlp")),
                "wu": ParamSpec((d, f), ("embed", "mlp")),
                "wd": ParamSpec((f, d), ("mlp", "embed"))}
    # relu2 (nemotron squared-ReLU) and gelu_ungated (whisper): 2 matrices
    return {"wu": ParamSpec((d, f), ("embed", "mlp")),
            "wd": ParamSpec((f, d), ("mlp", "embed"))}


def apply_mlp(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.activation == "silu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(x @ p["wg"]) * (x @ p["wu"])
    elif cfg.activation == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["wu"]))
    elif cfg.activation == "gelu_ungated":
        h = jax.nn.gelu(x @ p["wu"])
    else:
        raise EngineConfigError(
            f"unknown MLP activation {cfg.activation!r} "
            "(known: silu, gelu, relu2, gelu_ungated)",
            activation=cfg.activation)
    h = logical_shard(h, "batch", *(None,) * (h.ndim - 2), "mlp")
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def embed_spec(cfg: ModelConfig) -> Dict:
    out = {"tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                ("embed", "vocab"))
    return out


def embed_tokens(p: Dict, tokens: jax.Array) -> jax.Array:
    x = p["tok"][tokens]
    seq = ("seq",) if x.ndim == 3 else ()
    return logical_shard(x, "batch", *seq, "act_embed")


def unembed(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = x @ w
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    # vocab (not seq) carries the "model" axis here: the cross-entropy
    # logsumexp then psums a scalar per token instead of gathering the
    # (d_model × vocab) head per shard.
    seq = (None,) if logits.ndim == 3 else ()
    return logical_shard(logits, "batch", *seq, "vocab")
