"""Model registry + dry-run input specs.

``build_model(cfg)`` returns the family's model object (shared interface:
init_params / param_axes / abstract_params / forward / loss_fn / prefill /
decode_step / init_decode_state).

``input_specs(run)`` returns ShapeDtypeStruct stand-ins for every input the
lowered step function takes (the multi-pod dry-run contract): weak-type
correct, shardable, zero device allocation.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.errors import EngineConfigError
from repro.models.encdec import EncDecModel
from repro.models.transformer import TransformerModel


def build_model(cfg: ModelConfig):
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    if cfg.family in ("dense", "moe", "vlm", "rglru", "xlstm"):
        return TransformerModel(cfg)
    raise EngineConfigError(f"unknown family {cfg.family!r}",
                            family=cfg.family)


def input_specs(run: RunConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """Dry-run stand-ins for the *data* inputs of the step being lowered.

    train:   {"inputs", "targets"} (+ modality stubs)
    prefill: {"tokens", "lens"}    (+ modality stubs)
    decode:  {"tokens"}            (state comes from init_decode_state)
    """
    cfg = run.model
    B, S = run.global_batch, run.seq_len
    tok = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32)

    out: Dict[str, Any] = {}
    if run.kind == "train":
        out["inputs"] = tok((B, S))
        out["targets"] = tok((B, S))
    elif run.kind == "prefill":
        out["tokens"] = tok((B, S))
        out["lens"] = tok((B,))
    else:  # decode
        out["tokens"] = tok((B,))

    # modality frontend stubs (the one allowed carve-out)
    if cfg.family == "encdec" and run.kind in ("train", "prefill"):
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), dtype)
    if cfg.family == "vlm" and run.kind in ("train", "prefill"):
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_image_tokens, cfg.d_vision), dtype)
    return out
