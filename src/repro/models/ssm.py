"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

Attention-free — no KV cache, O(1) recurrent state per layer
(DESIGN.md §Arch-applicability: the paper's paged-KV technique does not
apply; decode cost is constant in context length, which is exactly the
regime long_500k probes).

mLSTM recurrence (heads h, key dim dk, value dim dv):
    C_t = f_t·C_{t-1} + i_t·(v_t k_tᵀ)      C: (h, dv, dk)
    n_t = f_t·n_{t-1} + i_t·k_t             n: (h, dk)
    y_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)
with exp input gate and sigmoid-ish forget gate, stabilised by the running
max m_t (log-space).  Training/prefill uses the *parallel quadratic form*
(decay matrix D in log space — the standard chunk-free TPU-friendly
formulation; matmul-shaped for the MXU); decode uses the recurrence.

sLSTM: true sequential recurrence (h_{t-1} feedback) — lax.scan over time.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.spec import ParamSpec


def _dims(cfg: ModelConfig) -> Tuple[int, int]:
    H = cfg.n_heads
    dh = cfg.d_model // H
    return H, dh


def mlstm_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H, dh = _dims(cfg)
    return {
        "wq": ParamSpec((d, H, dh), ("embed", "heads", None)),
        "wk": ParamSpec((d, H, dh), ("embed", "heads", None)),
        "wv": ParamSpec((d, H, dh), ("embed", "heads", None)),
        "wi": ParamSpec((d, H), ("embed", "heads"), "small_normal"),
        "wf": ParamSpec((d, H), ("embed", "heads"), "small_normal"),
        "bf": ParamSpec((H,), ("heads",), "ones"),
        "wo": ParamSpec((H, dh, d), ("heads", None, "embed")),
        "ogate": ParamSpec((d, H, dh), ("embed", "heads", None)),
    }


def mlstm_train(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Parallel (quadratic) form.  x: (B, S, d) → (B, S, d)."""
    B, S, d = x.shape
    H, dh = _dims(cfg)
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"]) / jnp.sqrt(dh).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    logi = (jnp.einsum("bsd,dh->bhs", x, p["wi"])).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bhs", x, p["wf"]).astype(jnp.float32)
        + p["bf"][None, :, None])

    # D_ij = exp( Σ_{l=j+1..i} logf_l + logi_j ), lower-triangular
    F = jnp.cumsum(logf, axis=-1)  # (B, H, S)
    logD = F[..., :, None] - F[..., None, :] + logi[..., None, :]
    mask = jnp.tril(jnp.ones((S, S), bool))
    logD = jnp.where(mask, logD, -jnp.inf)
    m = jnp.max(logD, axis=-1, keepdims=True)  # row-wise stabiliser
    m = jnp.maximum(m, 0.0)
    D = jnp.exp(logD - m)  # (B, H, S, S)

    s = jnp.einsum("bhsk,bhtk->bhst", q, k).astype(jnp.float32) * D
    n = jnp.maximum(jnp.abs(jnp.sum(s, axis=-1, keepdims=True)),
                    jnp.exp(-m))
    w = (s / n).astype(x.dtype)
    y = jnp.einsum("bhst,bhtk->bhsk", w, v)
    o = jax.nn.silu(jnp.einsum("bsd,dhk->bhsk", x, p["ogate"]))
    y = y * o
    return jnp.einsum("bhsk,hkd->bsd", y, p["wo"])


def mlstm_init_state(B: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    # recurrent accumulators are ALWAYS f32: the stabilised recurrence
    # multiplies by f32 gate factors every step (bf16 carries would both
    # drift and break scan carry-dtype invariance under bf16 activations)
    del dtype
    H, dh = _dims(cfg)
    return {
        "C": jnp.zeros((B, H, dh, dh), jnp.float32),
        "n": jnp.zeros((B, H, dh), jnp.float32),
        "m": jnp.full((B, H), -1e30, jnp.float32),
    }


def mlstm_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    """One step.  x: (B, d) → (B, d)."""
    B, d = x.shape
    H, dh = _dims(cfg)
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    k = jnp.einsum("bd,dhk->bhk", x, p["wk"]) / jnp.sqrt(dh).astype(x.dtype)
    v = jnp.einsum("bd,dhk->bhk", x, p["wv"])
    logi = jnp.einsum("bd,dh->bh", x, p["wi"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bd,dh->bh", x, p["wf"]).astype(jnp.float32) + p["bf"])

    m_new = jnp.maximum(logf + state["m"], logi)
    fe = jnp.exp(logf + state["m"] - m_new)[..., None]
    ie = jnp.exp(logi - m_new)[..., None]
    C = state["C"] * fe[..., None] + ie[..., None] * \
        jnp.einsum("bhv,bhk->bhvk", v, k).astype(jnp.float32)
    n = state["n"] * fe + ie * k.astype(jnp.float32)
    qdot = jnp.einsum("bhk,bhk->bh", n, q.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(qdot), jnp.exp(-m_new))[..., None]
    y = jnp.einsum("bhvk,bhk->bhv", C, q.astype(jnp.float32)) / denom
    o = jax.nn.silu(jnp.einsum("bd,dhk->bhk", x, p["ogate"]))
    out = jnp.einsum("bhk,hkd->bd", (y * o).astype(x.dtype), p["wo"])
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    H, dh = _dims(cfg)
    return {
        "wz": ParamSpec((d, H, dh), ("embed", "heads", None)),
        "wi": ParamSpec((d, H, dh), ("embed", "heads", None), "small_normal"),
        "wf": ParamSpec((d, H, dh), ("embed", "heads", None), "small_normal"),
        "wo_gate": ParamSpec((d, H, dh), ("embed", "heads", None)),
        # recurrent (block-diagonal per head) connections h_{t-1} → gates
        "rz": ParamSpec((H, dh, dh), ("heads", None, None), "small_normal"),
        "ri": ParamSpec((H, dh, dh), ("heads", None, None), "small_normal"),
        "rf": ParamSpec((H, dh, dh), ("heads", None, None), "small_normal"),
        "bf": ParamSpec((H, dh), ("heads", None), "ones"),
        "wo": ParamSpec((H, dh, d), ("heads", None, "embed")),
    }


def slstm_init_state(B: int, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    H, dh = _dims(cfg)
    zf = jnp.zeros((B, H, dh), jnp.float32)  # f32 accumulators (see mlstm)
    return {"c": zf, "n": zf, "h": jnp.zeros((B, H, dh), dtype),
            "m": jnp.full((B, H, dh), -1e30, jnp.float32)}


def _slstm_cell(p: Dict, state: Dict, zx, ix, fx, ox):
    """Inputs are pre-projected (B, H, dh) slices for this timestep."""
    h_prev = state["h"]
    z = jnp.tanh(zx + jnp.einsum("bhk,hkj->bhj", h_prev, p["rz"]))
    logi = (ix + jnp.einsum("bhk,hkj->bhj", h_prev, p["ri"])
            ).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        (fx + jnp.einsum("bhk,hkj->bhj", h_prev, p["rf"])
         ).astype(jnp.float32) + p["bf"])
    o = jax.nn.sigmoid(ox)

    m_new = jnp.maximum(logf + state["m"], logi)
    fe = jnp.exp(logf + state["m"] - m_new)
    ie = jnp.exp(logi - m_new)
    c = state["c"] * fe + ie * z.astype(jnp.float32)
    n = state["n"] * fe + ie
    h = o * (c / jnp.maximum(n, 1e-6)).astype(z.dtype)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_train(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Sequential scan over time (true recurrence).  x: (B, S, d)."""
    B, S, d = x.shape
    zx = jnp.einsum("bsd,dhk->sbhk", x, p["wz"])
    ix = jnp.einsum("bsd,dhk->sbhk", x, p["wi"])
    fx = jnp.einsum("bsd,dhk->sbhk", x, p["wf"])
    ox = jnp.einsum("bsd,dhk->sbhk", x, p["wo_gate"])

    def step(state, inp):
        state = _slstm_cell(p, state, *inp)
        return state, state["h"]

    _, hs = jax.lax.scan(step, slstm_init_state(B, cfg, x.dtype),
                         (zx, ix, fx, ox))
    return jnp.einsum("sbhk,hkd->bsd", hs, p["wo"])


def slstm_decode(p: Dict, x: jax.Array, state: Dict, cfg: ModelConfig
                 ) -> Tuple[jax.Array, Dict]:
    zx = jnp.einsum("bd,dhk->bhk", x, p["wz"])
    ix = jnp.einsum("bd,dhk->bhk", x, p["wi"])
    fx = jnp.einsum("bd,dhk->bhk", x, p["wf"])
    ox = jnp.einsum("bd,dhk->bhk", x, p["wo_gate"])
    state = _slstm_cell(p, state, zx, ix, fx, ox)
    return jnp.einsum("bhk,hkd->bd", state["h"], p["wo"]), state
