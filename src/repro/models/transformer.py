"""Composable decoder-only transformer covering five families.

A model is a *pattern* of layer codes tiled over ``n_layers``:

    'A' global attention    'W' sliding-window attention
    'R' RG-LRU recurrent    'M' mLSTM    'S' sLSTM
    'C' cross-attention (VLM image layers)

The pattern unit (e.g. "RRW" for RecurrentGemma, "CAAAA" for
Llama-3.2-Vision) is scanned as a *group*: parameters are stacked
(n_groups, ...) per unit position, so a 126-layer model compiles one group
body (key for CPU dry-run compile time and for the XLA cost-analysis
correction in the roofline harness).  Layers past ``n_groups·len(unit)``
(e.g. RecurrentGemma's trailing "RR") run unrolled.

Three entry points share the parameters:
    forward      — teacher-forced full sequence (training)
    prefill      — forward + scatter K/V into the paged cache
    decode_step  — one token against the paged cache / recurrent state

The paged-KV decode state is a plain dict pytree, so it jits, shards, and
dry-runs as ShapeDtypeStructs without special casing.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig
from repro.distributed.sharding import logical_shard
from repro.errors import EngineConfigError, UnsupportedFeature
from repro.models import attention as attn
from repro.models import layers, moe, rglru, spec as pspec, ssm
from repro.models.spec import ParamSpec

ATTN_CODES = "AW"
REC_CODES = "RMS"


# ---------------------------------------------------------------------------
# spec construction
# ---------------------------------------------------------------------------
def _ffn_spec(cfg: ModelConfig) -> Dict:
    if cfg.is_moe:
        return {"ln2": layers.norm_spec(cfg), "moe": moe.moe_spec(cfg)}
    if cfg.d_ff > 0:
        return {"ln2": layers.norm_spec(cfg), "mlp": layers.mlp_spec(cfg)}
    return {}


def layer_spec(code: str, cfg: ModelConfig) -> Dict:
    out: Dict[str, Any] = {"ln1": layers.norm_spec(cfg)}
    if code in ATTN_CODES:
        out["attn"] = attn.attn_spec(cfg)
    elif code == "C":
        out["attn"] = attn.attn_spec(cfg)
        out["gate"] = ParamSpec((), (), "zeros")
    elif code == "R":
        out["rec"] = rglru.rglru_spec(cfg)
    elif code == "M":
        out["rec"] = ssm.mlstm_spec(cfg)
    elif code == "S":
        out["rec"] = ssm.slstm_spec(cfg)
    else:
        raise EngineConfigError(f"unknown layer code {code!r} "
                                "(known: A W C R M S)", code=code)
    out.update(_ffn_spec(cfg))
    return out


class TransformerModel:
    """dense | moe | vlm | rglru | xlstm families."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.unit = cfg.layer_pattern
        codes = cfg.pattern()
        self.n_groups = cfg.n_layers // len(self.unit)
        self.rem_codes = codes[self.n_groups * len(self.unit):]
        # windowed iff the run's attention layers are 'W'
        self.window = cfg.window if "W" in self.unit + self.rem_codes else 0
        self.attn_per_unit = sum(c in ATTN_CODES for c in self.unit)
        self.cross_per_unit = sum(c == "C" for c in self.unit)
        self.n_attn_layers = sum(c in ATTN_CODES for c in codes)
        self.n_cross_layers = sum(c == "C" for c in codes)

    # -- spec / params ----------------------------------------------------
    def param_spec(self) -> Dict:
        cfg = self.cfg
        out: Dict[str, Any] = {"embed": layers.embed_spec(cfg),
                               "ln_f": layers.norm_spec(cfg)}
        if cfg.family == "vlm":
            out["vision_proj"] = ParamSpec((cfg.d_vision, cfg.d_model),
                                           (None, "embed"))
        groups = {}
        for j, code in enumerate(self.unit):
            groups[f"{j}{code}"] = pspec.stack_specs(
                layer_spec(code, cfg), self.n_groups, "layers")
        out["groups"] = groups
        out["rem"] = {f"{j}{code}": layer_spec(code, cfg)
                      for j, code in enumerate(self.rem_codes)}
        return out

    def init_params(self, rng: jax.Array, dtype=jnp.float32):
        return pspec.materialize(self.param_spec(), rng, dtype)

    def param_axes(self):
        return pspec.axes_tree(self.param_spec())

    def abstract_params(self, dtype=jnp.float32):
        return pspec.abstract(self.param_spec(), dtype)

    # -- layer application --------------------------------------------------
    def _apply_ffn(self, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        aux = jnp.float32(0.0)
        if "moe" in p:
            from repro.distributed import ep
            fn = (ep.apply_moe_ep if cfg.moe_ep and ep.ep_available(cfg)
                  else moe.apply_moe)
            h, aux = fn(p["moe"], layers.apply_norm(p["ln2"], x), cfg)
            x = x + h
        elif "mlp" in p:
            x = x + layers.apply_mlp(p["mlp"], layers.apply_norm(p["ln2"], x), cfg)
        return x, aux

    def _train_layer(self, code: str, p: Dict, x: jax.Array,
                     extra: Optional[Dict], impl: str
                     ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        h = layers.apply_norm(p["ln1"], x)
        if code == "A":
            x = x + attn.attn_train(p["attn"], h, cfg, impl=impl)
        elif code == "W":
            x = x + attn.attn_train(p["attn"], h, cfg, window=cfg.window,
                                    impl=impl)
        elif code == "C":
            img = extra["image_embeds"]
            k, v = attn.cross_kv(p["attn"], img)
            x = x + jnp.tanh(p["gate"]) * attn.cross_attn(p["attn"], h, k, v, cfg)
        elif code == "R":
            x = x + rglru.rglru_train(p["rec"], h, cfg)
        elif code == "M":
            x = x + ssm.mlstm_train(p["rec"], h, cfg)
        elif code == "S":
            x = x + ssm.slstm_train(p["rec"], h, cfg)
        x = logical_shard(x, "batch", "seq", "act_embed")
        return self._apply_ffn(p, x)

    # -- forward (training) -------------------------------------------------
    def forward(self, params: Dict, tokens: jax.Array,
                extra: Optional[Dict] = None, impl: str = "jnp") -> jax.Array:
        """tokens: (B, S) → logits (B, S, V)."""
        cfg = self.cfg
        extra = self._project_extra(params, extra)
        x = layers.embed_tokens(params["embed"], tokens)

        def unit_body(x, gp):
            aux = jnp.float32(0.0)
            for j, code in enumerate(self.unit):
                x, a = self._train_layer(code, gp[f"{j}{code}"], x, extra, impl)
                aux += a
            return x, aux

        if self.n_groups > 0:
            body = unit_body
            if cfg.remat != "none":
                body = jax.checkpoint(unit_body)

            def scan_body(carry, gp):
                x, aux = carry
                x, a = body(x, gp)
                return (x, aux + a), None

            (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.float32(0.0)),
                                       params["groups"],
                                       unroll=cfg.scan_unroll or 1)
        else:
            aux = jnp.float32(0.0)
        for j, code in enumerate(self.rem_codes):
            x, a = self._train_layer(code, params["rem"][f"{j}{code}"], x,
                                     extra, impl)
            aux += a

        x = layers.apply_norm(params["ln_f"], x)
        logits = layers.unembed(params["embed"], x, cfg)
        self._last_aux = aux  # router balance loss, consumed by loss_fn
        return logits

    def loss_fn(self, params: Dict, batch: Dict, impl: str = "jnp"
                ) -> Tuple[jax.Array, Dict]:
        """batch: {"inputs": (B,S), "targets": (B,S), "mask"?, extras...}."""
        cfg = self.cfg
        extra = {k: v for k, v in batch.items()
                 if k not in ("inputs", "targets", "mask")}
        logits = self.forward(params, batch["inputs"], extra or None, impl)
        loss = _xent(logits, batch["targets"], batch.get("mask"))
        aux = getattr(self, "_last_aux", jnp.float32(0.0))
        total = loss + cfg.router_aux_coef * aux
        return total, {"ce": loss, "aux": aux}

    # -- decode state ---------------------------------------------------------
    def init_decode_state(self, run: RunConfig, dtype=jnp.float32,
                          n_kv_shards: int = 1, abstract: bool = False
                          ) -> Dict:
        """Build (or shape out, for the dry-run) the serving-side state."""
        cfg = self.cfg
        B = run.global_batch
        ps = cfg.page_size
        if self.window > 0:
            pages_per_seq = -(-self.window // ps) + 1
        else:
            pages_per_seq = run.pages_per_seq
        pages_per_seq = -(-pages_per_seq // n_kv_shards) * n_kv_shards
        num_pages = B * pages_per_seq
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim

        def arr(shape, dt):
            if abstract:
                return jax.ShapeDtypeStruct(shape, dt)
            return jnp.zeros(shape, dt)

        st: Dict[str, Any] = {"pos": arr((B,), jnp.int32)}
        if self.n_attn_layers:
            pool = (self.n_attn_layers, num_pages, ps, Hkv, hd)
            pool_dt = jnp.int8 if cfg.kv_dtype == "int8" else dtype
            st["k_pages"] = arr(pool, pool_dt)
            st["v_pages"] = arr(pool, pool_dt)
            st["tables"] = arr((B, n_kv_shards, pages_per_seq // n_kv_shards),
                               jnp.int32)
        if self.n_cross_layers:
            ck = (self.n_cross_layers, B, cfg.n_image_tokens, Hkv, hd)
            st["cross_k"] = arr(ck, dtype)
            st["cross_v"] = arr(ck, dtype)
        rec: Dict[str, Any] = {}
        codes = cfg.pattern()
        for code, init in (("R", rglru.rglru_init_state),
                           ("M", ssm.mlstm_init_state),
                           ("S", ssm.slstm_init_state)):
            n = sum(c == code for c in codes)
            if n:
                one = init(B, cfg, dtype)
                rec[code] = jax.tree_util.tree_map(
                    lambda a: arr((n,) + a.shape, a.dtype), one)
        if rec:
            st["rec"] = rec
        return st

    # -- prefill / decode -----------------------------------------------------
    def _project_extra(self, params, extra):
        if extra and "image_embeds" in extra and "vision_proj" in params:
            img = extra["image_embeds"] @ params["vision_proj"]
            extra = dict(extra, image_embeds=img)
        return extra

    def _split_stacks(self, st: Dict):
        """Split per-layer stacks into (scanned-groups part, remainder part)."""
        def split(key, per_unit):
            if key not in st or per_unit == 0:
                return None, None
            n_scanned = self.n_groups * per_unit
            a = st[key]
            main = a[:n_scanned].reshape((self.n_groups, per_unit) + a.shape[1:])
            return main, a[n_scanned:]

        return split

    def prefill(self, params: Dict, tokens: jax.Array, state: Dict,
                lens: Optional[jax.Array] = None,
                extra: Optional[Dict] = None, impl: str = "jnp",
                attn_ctx: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict]:
        """tokens: (B, S) prompts (right-padded).  Returns (last-token
        logits (B, V), updated state).  state["tables"] must already map
        pages (the engine reserves before calling)."""
        cfg = self.cfg
        B, S = tokens.shape
        lens = lens if lens is not None else jnp.full((B,), S, jnp.int32)
        extra = self._project_extra(params, extra)
        x = layers.embed_tokens(params["embed"], tokens)

        st = dict(state)
        ai, ci = 0, 0
        new_k, new_v, new_ck, new_cv = [], [], [], []
        new_rec: Dict[str, list] = {"R": [], "M": [], "S": []}

        codes = cfg.pattern()
        # prefill runs layers unrolled: the per-layer cache update pattern
        # differs (pools are indexed per attention layer), and prefill is
        # lowered once per shape — compile cost is acceptable even at 126
        # layers because each layer body is identical HLO.
        layer_params = self._per_layer_params(params)
        for li, code in enumerate(codes):
            p = layer_params[li]
            h = layers.apply_norm(p["ln1"], x)
            if code in ATTN_CODES:
                w = cfg.window if code == "W" else 0
                o, kp, vp = attn.attn_prefill(
                    p["attn"], h, cfg, st["k_pages"][ai], st["v_pages"][ai],
                    st["tables"], lens, window=w, impl=impl)
                new_k.append(kp)
                new_v.append(vp)
                ai += 1
                x = x + o
            elif code == "C":
                img = extra["image_embeds"]
                ck, cv = attn.cross_kv(p["attn"], img)
                new_ck.append(ck)
                new_cv.append(cv)
                ci += 1
                x = x + jnp.tanh(p["gate"]) * attn.cross_attn(
                    p["attn"], h, ck, cv, cfg)
            elif code in REC_CODES:
                x = x + self._prefill_rec(code, p["rec"], h, new_rec)
            x, _ = self._apply_ffn(p, x)

        if self.n_attn_layers:
            st["k_pages"] = jnp.stack(new_k)
            st["v_pages"] = jnp.stack(new_v)
        if self.n_cross_layers:
            st["cross_k"] = jnp.stack(new_ck)
            st["cross_v"] = jnp.stack(new_cv)
        if any(v for v in new_rec.values()):
            st["rec"] = {c: jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_rec[c])
                for c in new_rec if new_rec[c]}
        st["pos"] = lens

        x = layers.apply_norm(params["ln_f"], x)
        last = jnp.take_along_axis(
            x, jnp.maximum(lens - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        logits = layers.unembed(params["embed"], last, cfg)
        return logits, st

    def prefill_chunk(self, params: Dict, tokens: jax.Array, state: Dict,
                      q_start: jax.Array, q_lens: jax.Array,
                      extra: Optional[Dict] = None, impl: str = "jnp",
                      interpret: Optional[bool] = None,
                      pages_per_block: Optional[int] = None,
                      num_splits: Optional[int] = None,
                      combine_mode: Optional[str] = None,
                      backend: Optional[str] = None
                      ) -> Tuple[jax.Array, Dict]:
        """Chunked prefill: one prompt *chunk* per sequence, resuming from
        the cached prefix.

        ``tokens``: (B, C) chunk tokens (right-padded); ``q_start``: (B,)
        tokens already cached (the resume position — positions, masks and
        the K/V scatter all use absolute ``q_start + i``); ``q_lens``:
        (B,) live tokens of this chunk.  ``state["tables"]`` must already
        map pages covering ``q_start + q_lens`` tokens (the scheduler
        reserves chunk-by-chunk).  Returns the logits of each chunk's
        last live token (the next-token logits when this is the final
        chunk) and the updated state.  ``prefill(tokens, lens)`` is the
        single-chunk special case (``q_start = 0``, ``q_lens = lens``).

        Recurrent codes (R/M/S) are not chunkable — their prefill state
        replay assumes the whole prompt; the engine gates them out.
        """
        cfg = self.cfg
        codes = cfg.pattern()
        if any(c in REC_CODES for c in codes):
            raise UnsupportedFeature(
                "chunked prefill does not support recurrent layers "
                f"(pattern {cfg.layer_pattern!r}): carrying recurrent "
                "state across chunks is an open ROADMAP item",
                pattern=cfg.layer_pattern)
        B, C = tokens.shape
        # cross-attention K/V depend only on the image context, and only
        # rows at chunk 0 need them computed — resume rows reuse their
        # cached state["cross_k"/"cross_v"] rows untouched.  The gate is
        # *per row*: project just the first-chunk rows' context and
        # scatter their fresh K/V into the cached stack.  (The former
        # batch-wide gate re-projected every row whenever any row was at
        # chunk 0 — idempotent for resume rows, but O(B) vision-encoder
        # work per admission instead of O(first-chunk rows).)
        # Host-driven (the engine calls this eagerly), hence the
        # concrete numpy indices.
        cross_mode, first_rows, proj = "reuse", None, None
        if self.n_cross_layers:
            firsts = np.flatnonzero(np.asarray(q_start) == 0)
            if "cross_k" not in state or firsts.size == B:
                cross_mode = "full"
            elif firsts.size == 0:
                cross_mode = "reuse"
            else:
                cross_mode = "partial"
                first_rows = jnp.asarray(firsts)
            if cross_mode != "reuse":
                sub = extra
                if cross_mode == "partial":
                    sub = dict(extra,
                               image_embeds=extra["image_embeds"][first_rows])
                proj = self._project_extra(params, sub)
        x = layers.embed_tokens(params["embed"], tokens)

        st = dict(state)
        ai = ci = 0
        new_k, new_v, new_ck, new_cv = [], [], [], []
        layer_params = self._per_layer_params(params)
        for li, code in enumerate(codes):
            p = layer_params[li]
            h = layers.apply_norm(p["ln1"], x)
            if code in ATTN_CODES:
                w = cfg.window if code == "W" else 0
                o, kp, vp = attn.attn_prefill_chunked(
                    p["attn"], h, cfg, st["k_pages"][ai], st["v_pages"][ai],
                    st["tables"], q_start, q_lens, window=w, impl=impl,
                    interpret=interpret, pages_per_block=pages_per_block,
                    num_splits=num_splits, combine_mode=combine_mode,
                    backend=backend)
                new_k.append(kp)
                new_v.append(vp)
                ai += 1
                x = x + o
            elif code == "C":
                if cross_mode == "reuse":
                    ck, cv = st["cross_k"][ci], st["cross_v"][ci]
                elif cross_mode == "partial":
                    # fresh K/V for first-chunk rows only, scattered into
                    # the cached stack; resume rows' rows are untouched
                    ck_new, cv_new = attn.cross_kv(p["attn"],
                                                   proj["image_embeds"])
                    ck = st["cross_k"][ci].at[first_rows].set(ck_new)
                    cv = st["cross_v"][ci].at[first_rows].set(cv_new)
                else:
                    ck, cv = attn.cross_kv(p["attn"], proj["image_embeds"])
                new_ck.append(ck)
                new_cv.append(cv)
                ci += 1
                x = x + jnp.tanh(p["gate"]) * attn.cross_attn(
                    p["attn"], h, ck, cv, cfg)
            x, _ = self._apply_ffn(p, x)

        if self.n_attn_layers:
            st["k_pages"] = jnp.stack(new_k)
            st["v_pages"] = jnp.stack(new_v)
        if self.n_cross_layers:
            st["cross_k"] = jnp.stack(new_ck)
            st["cross_v"] = jnp.stack(new_cv)
        st["pos"] = q_start + q_lens

        x = layers.apply_norm(params["ln_f"], x)
        last = jnp.take_along_axis(
            x, jnp.maximum(q_lens - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        logits = layers.unembed(params["embed"], last, cfg)
        return logits, st

    def prefill_scanned(self, params: Dict, tokens: jax.Array, state: Dict,
                        lens: Optional[jax.Array] = None,
                        extra: Optional[Dict] = None, impl: str = "jnp",
                        attn_ctx: Optional[Dict] = None
                        ) -> Tuple[jax.Array, Dict]:
        """Prefill with the unit-group scan (one compiled body for all
        groups) — the path the multi-pod dry-run lowers, so 126-layer models
        compile in one-body time.  Numerically identical to ``prefill``
        (asserted in tests)."""
        cfg = self.cfg
        B, S = tokens.shape
        lens = lens if lens is not None else jnp.full((B,), S, jnp.int32)
        extra = self._project_extra(params, extra)
        x = layers.embed_tokens(params["embed"], tokens)
        tables = state.get("tables")
        if tables is not None:
            tables = tables.reshape(B, -1)

        split = self._split_stacks(state)
        kp_m, kp_r = split("k_pages", self.attn_per_unit)
        vp_m, vp_r = split("v_pages", self.attn_per_unit)

        def apply_code(code, p, x, caches):
            h = layers.apply_norm(p["ln1"], x)
            if code in ATTN_CODES:
                w = cfg.window if code == "W" else 0
                o, kp, vp = attn.attn_prefill(
                    p["attn"], h, cfg, caches["kp"], caches["vp"],
                    tables, lens, window=w, impl=impl)
                caches["kp"], caches["vp"] = kp, vp
                x = x + o
            elif code == "C":
                img = extra["image_embeds"]
                ck, cv = attn.cross_kv(p["attn"], img)
                caches["ck"], caches["cv"] = ck, cv
                x = x + jnp.tanh(p["gate"]) * attn.cross_attn(
                    p["attn"], h, ck, cv, cfg)
            elif code in REC_CODES:
                holder: Dict[str, list] = {code: []}
                x = x + self._prefill_rec(code, p["rec"], h, holder)
                caches["rec"] = holder[code][0]
            x, _ = self._apply_ffn(p, x)
            return x

        def unit_body(x, xs):
            gp = xs["params"]
            ai = ci = 0
            ys: Dict[str, Any] = {}
            rec_ys: Dict[str, list] = {}
            kps, vps, cks, cvs = [], [], [], []
            for j, code in enumerate(self.unit):
                caches: Dict[str, Any] = {}
                if code in ATTN_CODES:
                    caches["kp"], caches["vp"] = xs["kp"][ai], xs["vp"][ai]
                x = apply_code(code, gp[f"{j}{code}"], x, caches)
                if code in ATTN_CODES:
                    kps.append(caches["kp"])
                    vps.append(caches["vp"])
                    ai += 1
                elif code == "C":
                    cks.append(caches["ck"])
                    cvs.append(caches["cv"])
                elif code in REC_CODES:
                    rec_ys.setdefault(code, []).append(caches["rec"])
            if kps:
                ys["kp"], ys["vp"] = jnp.stack(kps), jnp.stack(vps)
            if cks:
                ys["ck"], ys["cv"] = jnp.stack(cks), jnp.stack(cvs)
            if rec_ys:
                ys["rec"] = {c: jax.tree_util.tree_map(
                    lambda *t: jnp.stack(t), *rec_ys[c]) for c in rec_ys}
            return x, ys

        if self.n_groups > 0:
            xs: Dict[str, Any] = {"params": params["groups"]}
            if kp_m is not None:
                xs["kp"], xs["vp"] = kp_m, vp_m
            x, ys = jax.lax.scan(unit_body, x, xs,
                                 unroll=cfg.scan_unroll or 1)
        else:
            ys = {}

        # remainder layers, unrolled
        rem: Dict[str, Any] = {"kp": [], "vp": [], "ck": [], "cv": [],
                               "rec": {}}
        ai = 0
        for j, code in enumerate(self.rem_codes):
            p = params["rem"][f"{j}{code}"]
            caches: Dict[str, Any] = {}
            if code in ATTN_CODES:
                caches["kp"], caches["vp"] = kp_r[ai], vp_r[ai]
            x = apply_code(code, p, x, caches)
            if code in ATTN_CODES:
                rem["kp"].append(caches["kp"])
                rem["vp"].append(caches["vp"])
                ai += 1
            elif code == "C":
                rem["ck"].append(caches["ck"])
                rem["cv"].append(caches["cv"])
            elif code in REC_CODES:
                rem["rec"].setdefault(code, []).append(caches["rec"])

        st = dict(state)

        def merge(key, ys_key, rem_list, per_unit):
            if per_unit == 0 and not rem_list:
                return
            parts = []
            if self.n_groups > 0 and per_unit > 0:
                a = ys[ys_key]
                parts.append(a.reshape((-1,) + a.shape[2:]))
            if rem_list:
                parts.append(jnp.stack(rem_list))
            st[key] = parts[0] if len(parts) == 1 else jnp.concatenate(parts, 0)

        merge("k_pages", "kp", rem["kp"], self.attn_per_unit)
        merge("v_pages", "vp", rem["vp"], self.attn_per_unit)
        merge("cross_k", "ck", rem["ck"], self.cross_per_unit)
        merge("cross_v", "cv", rem["cv"], self.cross_per_unit)
        rec_codes = set(ys.get("rec", {})) | set(rem["rec"])
        if rec_codes:
            out_rec = {}
            for c in rec_codes:
                parts = []
                if c in ys.get("rec", {}):
                    parts.append(jax.tree_util.tree_map(
                        lambda t: t.reshape((-1,) + t.shape[2:]), ys["rec"][c]))
                if rem["rec"].get(c):
                    parts.append(jax.tree_util.tree_map(
                        lambda *t: jnp.stack(t), *rem["rec"][c]))
                out_rec[c] = parts[0] if len(parts) == 1 else \
                    jax.tree_util.tree_map(
                        lambda a, b: jnp.concatenate([a, b], 0), *parts)
            st["rec"] = out_rec
        st["pos"] = lens

        x = layers.apply_norm(params["ln_f"], x)
        last = jnp.take_along_axis(
            x, jnp.maximum(lens - 1, 0)[:, None, None].astype(jnp.int32),
            axis=1)[:, 0]
        logits = layers.unembed(params["embed"], last, cfg)
        return logits, st

    def _prefill_rec(self, code, p, h, new_rec):
        """Run a recurrent layer over the prompt and capture final state."""
        cfg = self.cfg
        B, S, _ = h.shape
        if code == "R":
            out = rglru.rglru_train(p, h, cfg)
            # reconstruct final state by replaying the last conv window + h_T:
            # cheaper: rerun decode on last steps?  Exact final state:
            # h_T from the scan — recompute via associative scan outputs.
            # For simplicity we recompute states with a short replay below.
            final = self._rglru_final_state(p, h, cfg)
        elif code == "M":
            out = ssm.mlstm_train(p, h, cfg)
            final = self._mlstm_final_state(p, h, cfg)
        else:
            out, final = self._slstm_with_state(p, h, cfg)
        new_rec[code].append(final)
        return out

    def _rglru_final_state(self, p, h, cfg):
        B, S, _ = h.shape
        xb = h @ p["wx"]
        cw = p["conv"].shape[0]
        pad = jnp.pad(xb, ((0, 0), (cw - 1, 0), (0, 0)))
        xc = sum(pad[:, i:i + S] * p["conv"][i] for i in range(cw)) + p["conv_b"]
        log_a, gated = rglru._gates(p, xc)

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 + a2, b1 * jnp.exp(a2).astype(b1.dtype) + b2

        _, hs = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
        return {"h": hs[:, -1], "conv": pad[:, S:S + cw - 1]
                if cw > 1 else jnp.zeros((B, 0, xb.shape[-1]), xb.dtype)}

    def _mlstm_final_state(self, p, h, cfg):
        """Exact final (C, n, m) via a scan over time (prefill-only cost)."""
        B = h.shape[0]

        def step(state, xt):
            _, state = ssm.mlstm_decode(p, xt, state, cfg)
            return state, None

        init = ssm.mlstm_init_state(B, cfg, h.dtype)
        state, _ = jax.lax.scan(step, init, h.transpose(1, 0, 2))
        return state

    def _slstm_with_state(self, p, h, cfg):
        B, S, _ = h.shape
        zx = jnp.einsum("bsd,dhk->sbhk", h, p["wz"])
        ix = jnp.einsum("bsd,dhk->sbhk", h, p["wi"])
        fx = jnp.einsum("bsd,dhk->sbhk", h, p["wf"])
        ox = jnp.einsum("bsd,dhk->sbhk", h, p["wo_gate"])

        def step(state, inp):
            state = ssm._slstm_cell(p, state, *inp)
            return state, state["h"]

        state, hs = jax.lax.scan(step, ssm.slstm_init_state(B, cfg, h.dtype),
                                 (zx, ix, fx, ox))
        return jnp.einsum("sbhk,hkd->bsd", hs, p["wo"]), state

    def _per_layer_params(self, params: Dict):
        """List of per-layer param trees in layer order (unstacked views)."""
        out = []
        for g in range(self.n_groups):
            for j, code in enumerate(self.unit):
                out.append(jax.tree_util.tree_map(
                    lambda a: a[g], params["groups"][f"{j}{code}"]))
        for j, code in enumerate(self.rem_codes):
            out.append(params["rem"][f"{j}{code}"])
        return out

    def decode_step(self, params: Dict, tokens: jax.Array, state: Dict,
                    impl: str = "ref", attn_ctx: Optional[Dict] = None,
                    interpret: Optional[bool] = None,
                    pages_per_block: Optional[int] = None,
                    num_splits: Optional[int] = None,
                    combine_mode: Optional[str] = None,
                    backend: Optional[str] = None
                    ) -> Tuple[jax.Array, Dict]:
        """tokens: (B,) → (logits (B, V), state').  Scanned over groups.

        The full stacked caches travel through the scan as *carry* and are
        updated in place with ``dynamic_update_slice``: XLA keeps one buffer
        for a while-loop carry, so the KV pools are never double-buffered
        (xs/ys would cost 2× pool bytes) and loop-invariant-input rewrites
        (e.g. the CPU backend's hoisted bf16→f32 convert of a whole pool)
        cannot apply.  With jit donation the pools are fully in-place across
        the serving loop — the paper's "global KV cache" contract.
        """
        cfg = self.cfg
        B = tokens.shape[0]
        pos = state["pos"]
        x = layers.embed_tokens(params["embed"], tokens)
        tables = state.get("tables")
        rec = state.get("rec", {})
        per_unit_rec = {c: sum(cc == c for cc in self.unit) for c in rec}

        # carry caches: the state arrays themselves (full stacks)
        ca: Dict[str, Any] = {}
        for key in ("k_pages", "v_pages", "cross_k", "cross_v"):
            if key in state:
                ca[key] = state[key]
        if rec:
            ca["rec"] = rec

        def idx_in(tree, i):
            return jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                       keepdims=False), tree)

        def upd_in(tree, sub, i):
            # barrier: stops the CPU float-normalization pass from merging a
            # (legalized-to-f32) scatter with this update into one f32 chain
            # that would shadow the whole carried pool in f32 (no-op on TPU).
            return jax.tree_util.tree_map(
                lambda a, s: jax.lax.dynamic_update_index_in_dim(
                    a, jax.lax.optimization_barrier(s), i, 0),
                tree, sub)

        def apply_code(code, p, x, caches):
            h = layers.apply_norm(p["ln1"], x)
            if code in ATTN_CODES:
                w = cfg.window if code == "W" else 0
                kp, vp = caches["kp"], caches["vp"]
                o, kp, vp = attn.attn_decode(
                    p["attn"], h, cfg, kp, vp, tables, pos, window=w,
                    impl=impl, attn_ctx=attn_ctx, interpret=interpret,
                    pages_per_block=pages_per_block, num_splits=num_splits,
                    combine_mode=combine_mode, backend=backend)
                caches["kp"], caches["vp"] = kp, vp
                x = x + o
            elif code == "C":
                x = x + jnp.tanh(p["gate"]) * attn.cross_attn(
                    p["attn"], h, caches["ck"], caches["cv"], cfg)
            elif code == "R":
                o, caches["rec"] = rglru.rglru_decode(p["rec"], h, caches["rec"], cfg)
                x = x + o
            elif code == "M":
                o, caches["rec"] = ssm.mlstm_decode(p["rec"], h, caches["rec"], cfg)
                x = x + o
            elif code == "S":
                o, caches["rec"] = ssm.slstm_decode(p["rec"], h, caches["rec"], cfg)
                x = x + o
            x, _ = self._apply_ffn(p, x)
            return x

        def run_unit(x, ca, gp, attn_base, cross_base, rec_base):
            """Apply one unit; bases are layer offsets into the stacks."""
            ai = ci = 0
            rci = {c: 0 for c in rec}
            for j, code in enumerate(self.unit):
                caches: Dict[str, Any] = {}
                if code in ATTN_CODES:
                    li = attn_base + ai
                    caches["kp"] = idx_in(ca["k_pages"], li)
                    caches["vp"] = idx_in(ca["v_pages"], li)
                elif code == "C":
                    li = cross_base + ci
                    caches["ck"] = idx_in(ca["cross_k"], li)
                    caches["cv"] = idx_in(ca["cross_v"], li)
                elif code in REC_CODES:
                    li = rec_base[code] + rci[code]
                    caches["rec"] = idx_in(ca["rec"][code], li)
                x = apply_code(code, gp[f"{j}{code}"], x, caches)
                if code in ATTN_CODES:
                    ca["k_pages"] = upd_in(ca["k_pages"], caches["kp"],
                                           attn_base + ai)
                    ca["v_pages"] = upd_in(ca["v_pages"], caches["vp"],
                                           attn_base + ai)
                    ai += 1
                elif code == "C":
                    ca["cross_k"] = upd_in(ca["cross_k"], caches["ck"],
                                           cross_base + ci)
                    ca["cross_v"] = upd_in(ca["cross_v"], caches["cv"],
                                           cross_base + ci)
                    ci += 1
                elif code in REC_CODES:
                    ca["rec"] = dict(ca["rec"])
                    ca["rec"][code] = upd_in(
                        ca["rec"][code], caches["rec"],
                        rec_base[code] + rci[code])
                    rci[code] += 1
            return x, ca

        if self.n_groups > 0:
            def scan_body(carry, xs):
                x, ca = carry
                g = xs["g"]
                rec_base = {c: g * per_unit_rec[c] for c in rec}
                x, ca = run_unit(x, ca, xs["params"],
                                 g * self.attn_per_unit,
                                 g * self.cross_per_unit, rec_base)
                return (x, ca), None

            (x, ca), _ = jax.lax.scan(
                scan_body, (x, ca),
                {"params": params["groups"],
                 "g": jnp.arange(self.n_groups, dtype=jnp.int32)},
                unroll=cfg.scan_unroll or 1)

        # remainder layers (unrolled, static indices)
        ai = ci = 0
        rci = {c: 0 for c in rec}
        for j, code in enumerate(self.rem_codes):
            p = params["rem"][f"{j}{code}"]
            caches = {}
            if code in ATTN_CODES:
                li = self.n_groups * self.attn_per_unit + ai
                caches["kp"] = idx_in(ca["k_pages"], li)
                caches["vp"] = idx_in(ca["v_pages"], li)
            elif code == "C":
                li = self.n_groups * self.cross_per_unit + ci
                caches["ck"] = idx_in(ca["cross_k"], li)
                caches["cv"] = idx_in(ca["cross_v"], li)
            elif code in REC_CODES:
                li = self.n_groups * per_unit_rec[code] + rci[code]
                caches["rec"] = idx_in(ca["rec"][code], li)
            x = apply_code(code, p, x, caches)
            if code in ATTN_CODES:
                li = self.n_groups * self.attn_per_unit + ai
                ca["k_pages"] = upd_in(ca["k_pages"], caches["kp"], li)
                ca["v_pages"] = upd_in(ca["v_pages"], caches["vp"], li)
                ai += 1
            elif code == "C":
                li = self.n_groups * self.cross_per_unit + ci
                ca["cross_k"] = upd_in(ca["cross_k"], caches["ck"], li)
                ca["cross_v"] = upd_in(ca["cross_v"], caches["cv"], li)
                ci += 1
            elif code in REC_CODES:
                li = self.n_groups * per_unit_rec[code] + rci[code]
                ca["rec"] = dict(ca["rec"])
                ca["rec"][code] = upd_in(ca["rec"][code], caches["rec"], li)
                rci[code] += 1

        new_state = dict(state)
        new_state.update(ca)
        new_state["pos"] = pos + 1
        x = layers.apply_norm(params["ln_f"], x)
        logits = layers.unembed(params["embed"], x, cfg)
        return logits, new_state


def _xent(logits: jax.Array, targets: jax.Array,
          mask: Optional[jax.Array] = None) -> jax.Array:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
