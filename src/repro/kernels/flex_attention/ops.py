"""Public op: flex attention (prefill / training path).

Dispatches between the Pallas kernel and the jnp oracle; builds (or accepts
a cached) BlockMask.  This op + the paged decode op together are the paper's
"fused attention kernel" surface.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flex
from repro.kernels.flex_attention.flex_attention import flex_attention_kernel
from repro.kernels.flex_attention.ref import flex_attention_ref


def flex_attention(
    q: jax.Array,  # (B, H, Q, D)
    k: jax.Array,  # (B, Hkv, K, D)
    v: jax.Array,
    *,
    mask_mod: flex.MaskMod = flex.causal_mask,
    score_mod: Optional[flex.ScoreMod] = None,
    block_mask: Optional[flex.BlockMask] = None,
    scale: Optional[float] = None,
    window: int = 0,
    impl: str = "pallas",
    q_block: int = 128,
    kv_block: int = 128,
    interpret: Optional[bool] = None,  # None → auto (interpret iff not TPU)
) -> jax.Array:
    B, H, Q, D = q.shape
    K = k.shape[2]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(D))

    if impl == "ref":
        return flex_attention_ref(q, k, v, mask_mod=mask_mod,
                                  score_mod=score_mod, scale=scale)

    q_block = min(q_block, Q)
    kv_block = min(kv_block, K)
    if block_mask is None:
        # analytic fast path for the two structural masks we know; generic
        # mods go through the streaming builder (never materialises QxK)
        if mask_mod is flex.causal_mask:
            block_mask = flex.causal_block_mask(Q, K, q_block, kv_block)
        elif window > 0:
            block_mask = flex.causal_block_mask(Q, K, q_block, kv_block,
                                                window=window)
        else:
            # aux-carrying mods may be batch-dependent (padding/document
            # masks) → build a per-batch block mask, like FlexAttention's
            # create_block_mask(B=...)
            batched = isinstance(mask_mod, flex.AuxMod)
            block_mask = flex.build_block_mask(
                mask_mod, Q, K, q_block, kv_block, B=B if batched else None)

    pad_q = -Q % block_mask.q_block
    pad_k = -K % block_mask.kv_block
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out = flex_attention_kernel(
        q, k, v, block_mask, scale=scale, mask_mod=mask_mod,
        score_mod=score_mod, q_len=Q, kv_len=K, interpret=interpret)
    return out[:, :, :Q]
