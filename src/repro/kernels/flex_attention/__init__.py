from repro.kernels.flex_attention.ops import flex_attention
from repro.kernels.flex_attention.ref import flex_attention_ref

__all__ = ["flex_attention", "flex_attention_ref"]
