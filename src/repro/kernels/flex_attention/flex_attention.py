"""Pallas TPU kernel: flash-style attention with FlexAttention semantics.

The paper pairs the paged allocator with PyTorch FlexAttention: a JIT-fused
kernel whose sparsity/masking comes from user hooks.  This is the TPU
equivalent: a tiled online-softmax attention kernel whose

  * *block sparsity* comes from a precompiled ``BlockMask``
    (``kv_indices`` is a scalar-prefetch operand — the same indirection
    trick as the paged decode kernel: the grid only visits live KV tiles);
  * *element masking* comes from a traced ``mask_mod`` evaluated on tile
    index iotas — skipped entirely on tiles flagged ``is_full``;
  * *score shaping* comes from a traced ``score_mod`` (softcap, ALiBi, ...).

Grid: (B, H, num_q_blocks, max_kv_blocks) — kv innermost; accumulators in
VMEM scratch. GQA is handled by the k/v index_map (h → h // group).

Block shapes: q/o (1,1,q_blk,D), k/v (1,1,kv_blk,D) — q_blk=kv_blk=128 by
default so the (128,128)·(128,D) tile products run on full MXU tiles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import flex
from repro.kernels import resolve_interpret

NEG_INF = -1e30


def _flex_kernel(
    # scalar prefetch: block mask + aux tensors (FlexAttention "bias" trick)
    kv_num_blocks_ref,  # (nq,)
    kv_indices_ref,  # (nq, max_kv)
    is_full_ref,  # (nq, max_kv) int32
    *refs,  # *aux_refs (n_mask_aux + n_score_aux), q, k, v, o, m, l, acc
    scale: float,
    mask_fn,
    score_fn,
    n_mask_aux: int,
    n_score_aux: int,
    q_blk: int,
    kv_blk: int,
    q_len: int,
    kv_len: int,
):
    aux_refs = refs[: n_mask_aux + n_score_aux]
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs[
        n_mask_aux + n_score_aux:]
    mask_aux = tuple(r[...] for r in aux_refs[:n_mask_aux])
    score_aux = tuple(r[...] for r in aux_refs[n_mask_aux:])

    def mask_mod(b, h, q, k):
        return mask_fn(b, h, q, k, *mask_aux)

    score_mod = None
    if score_fn is not None:
        def score_mod(s, b, h, q, k):
            return score_fn(s, b, h, q, k, *score_aux)

    b = pl.program_id(0)
    h = pl.program_id(1)
    qb = pl.program_id(2)
    j = pl.program_id(3)
    n_j = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if kv_indices_ref.ndim == 3:  # batched block mask
        kb = kv_indices_ref[b, qb, j]
        live = j < kv_num_blocks_ref[b, qb]
        full = is_full_ref[b, qb, j] > 0
    else:
        kb = kv_indices_ref[qb, j]
        live = j < kv_num_blocks_ref[qb]
        full = is_full_ref[qb, j] > 0

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (q_blk, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (kv_blk, D)
        v = v_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        qi = qb * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
        ki = kb * kv_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
        if score_mod is not None:
            s = score_mod(s, b, h, qi, ki)
        mask = jnp.where(full, jnp.ones_like(s, bool), mask_mod(b, h, qi, ki))
        mask &= (qi < q_len) & (ki < kv_len)  # block-padding validity
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.where(mask, jnp.exp(s - m_new), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_j - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flex_attention_kernel(
    q: jax.Array,  # (B, H, Q, D)
    k: jax.Array,  # (B, Hkv, K, D)
    v: jax.Array,
    block_mask: flex.BlockMask,
    *,
    scale: float,
    mask_mod=flex.causal_mask,
    score_mod=None,
    q_len: int = 0,  # true (pre-padding) lengths; 0 = no padding
    kv_len: int = 0,
    interpret: Optional[bool] = None,
) -> jax.Array:
    B, H, Q, D = q.shape
    Hkv, K = k.shape[1], k.shape[2]
    q_len = q_len or Q
    kv_len = kv_len or K
    G = H // Hkv
    q_blk, kv_blk = block_mask.q_block, block_mask.kv_block
    assert Q % q_blk == 0 and K % kv_blk == 0, "wrapper must pad to blocks"
    nq = Q // q_blk
    max_kv = block_mask.kv_indices.shape[1]

    # unpack aux tensors out of AuxMod wrappers (→ scalar-prefetch operands)
    if isinstance(mask_mod, flex.AuxMod):
        mask_fn, mask_aux = mask_mod.fn, mask_mod.aux
    else:
        mask_fn, mask_aux = (lambda b, h, q, k: mask_mod(b, h, q, k)), ()
    if score_mod is None:
        score_fn, score_aux = None, ()
    elif isinstance(score_mod, flex.AuxMod):
        score_fn, score_aux = score_mod.fn, score_mod.aux
    else:
        score_fn, score_aux = (
            lambda s, b, h, q, k: score_mod(s, b, h, q, k)), ()
    n_aux = len(mask_aux) + len(score_aux)
    n_prefetch = 3 + n_aux

    def q_map(b, h, qb, j, *pref):
        return (b, h, qb, 0)

    def kv_map(b, h, qb, j, nb, idx, *pref):
        if idx.ndim == 3:
            return (b, h // G, idx[b, qb, j], 0)
        return (b, h // G, idx[qb, j], 0)

    kernel = functools.partial(
        _flex_kernel, scale=scale, mask_fn=mask_fn, score_fn=score_fn,
        n_mask_aux=len(mask_aux), n_score_aux=len(score_aux),
        q_blk=q_blk, kv_blk=kv_blk, q_len=q_len, kv_len=kv_len)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_prefetch,
            grid=(B, H, nq, max_kv),
            in_specs=[
                pl.BlockSpec((1, 1, q_blk, D), q_map),
                pl.BlockSpec((1, 1, kv_blk, D), kv_map),
                pl.BlockSpec((1, 1, kv_blk, D), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, q_blk, D), q_map),
            scratch_shapes=[
                pltpu.VMEM((q_blk, 1), jnp.float32),
                pltpu.VMEM((q_blk, 1), jnp.float32),
                pltpu.VMEM((q_blk, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Q, D), q.dtype),
        interpret=resolve_interpret(interpret),
    )(block_mask.kv_num_blocks, block_mask.kv_indices,
      block_mask.is_full.astype(jnp.int32), *mask_aux, *score_aux, q, k, v)
