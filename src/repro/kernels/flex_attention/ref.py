"""Pure-jnp oracle for the flex prefill attention kernel.

Materialises the mask mod over the full (Q, K) index space and runs dense
softmax attention with the score mod applied — numerically what the fused
kernel must reproduce (FlexAttention semantics).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import flex


def flex_attention_ref(
    q: jax.Array,  # (B, H, Q, D)
    k: jax.Array,  # (B, Hkv, K, D)
    v: jax.Array,  # (B, Hkv, K, D)
    *,
    mask_mod: flex.MaskMod = flex.causal_mask,
    score_mod: Optional[flex.ScoreMod] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, H, Q, D = q.shape
    Hkv, K = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)

    qg = q.reshape(B, Hkv, G, Q, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32))

    bi = jnp.arange(B)[:, None, None, None]
    hi = jnp.arange(H).reshape(Hkv, G)[None, :, :, None, None]
    qi = jnp.arange(Q)[None, None, None, :, None]
    ki = jnp.arange(K)[None, None, None, None, :]
    if score_mod is not None:
        s = score_mod(s, bi[..., None], hi, qi, ki)
    m = mask_mod(bi[..., None], hi, qi, ki)
    s = jnp.where(m, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return out.reshape(B, H, Q, D).astype(q.dtype)
