"""Pallas TPU kernel: fused paged decode attention (blocked + split-K).

TPU adaptation of the paper's FlexAttention-fused PagedAttention (§III-B).
On GPU the fused kernel gathers scattered KV inside the attention loop
(see the sibling Triton lowering, `paged_attention_gpu.py`, which shares
this module's `decode_partition`, partial contract, and combine); on TPU
random gathers inside a kernel are slow, so the *grid* walks the page
list and the block table is a **scalar-prefetch operand**: the page→HBM
translation happens in the BlockSpec ``index_map``, so the Pallas pipeline's
DMA engine streams exactly the live pages HBM→VMEM, double-buffered, with no
gather materialisation (DESIGN.md §2, A1).

Design (v2: multi-page KV blocks + flash-decoding split-K)
==========================================================

Grid layout
-----------
::

    grid = (batch, kv_heads, num_splits, blocks_per_split)

Each grid step processes one **KV block** of ``pages_per_block`` physical
pages (= ``pages_per_block * page_size`` KV tokens, MXU-aligned when the
product is a multiple of 128).  The split-K axis partitions the page list
into ``num_splits`` contiguous ranges of ``blocks_per_split`` blocks each;
every ``(b, h, s)`` slot runs an independent online softmax over its range
and emits an un-normalised partial ``(m, l, acc)``.  The partials merge
with the numerically-stable flash-decoding correction — the same math
`ref.combine_partials_ref` documents::

    m* = max_s m_s          l* = Σ_s l_s · exp(m_s − m*)
    o  = Σ_s acc_s · exp(m_s − m*) / max(l*, ε)

Two-kernel pipeline & megacore semantics (v3)
---------------------------------------------
The merge runs as the second kernel of a fused two-kernel Pallas
pipeline (``combine_mode="pallas"``, the default whenever split-K is
active): `combine_partials_pallas` walks a ``(batch, kv_head)`` grid and
reduces the whole split axis on-chip per step — max-shift in f32, f32
accumulation, a single output cast — so the partials never round-trip
through an XLA epilogue.  ``combine_mode="jnp"`` keeps the plain jnp
epilogue (`_combine_partials_jnp`); both modes are bit-compatible within
1e-5 and the conformance suite (`tests/test_combine_conformance.py`)
gates them against `ref.combine_partials_ref`.

Both kernels carry ``dimension_semantics``: the decode kernel marks
``(batch, kv_head, split)`` as ``"parallel"`` (the block axis stays
``"arbitrary"`` — its online softmax accumulates in scratch across
steps), and the combine kernel marks ``(batch, kv_head)`` parallel.  On
megacore TPUs Mosaic may therefore place different splits of the *same*
sequence on different cores — the whole point of flash-decoding split-K
for batch=1 long-context decode; without the annotation the grid is
serialised and split-K only ever helped occupancy across batch.
``interpret=None`` auto-resolution (off-TPU ⇒ interpret mode) applies to
both kernels, so the pipeline is testable on CPU CI.

Scattered pages per block
-------------------------
A BlockSpec fetches one contiguous block per operand, so a multi-page block
of *scattered* pages cannot come from a single index_map.  Instead the
k/v pools are passed ``pages_per_block`` times, each copy with its own
index_map reading column ``j`` of the **2-D table slice**
``tables3d[b, s·blocks_per_split + blk, j]``: the pipeline still streams
each scattered page HBM→VMEM as its own (double-buffered) DMA, but the
compute concatenates the ``pages_per_block`` VMEM tiles into one
``(pages_per_block · page_size, head_dim)`` tile so the two matmuls
(``q·Kᵀ`` and ``p·V``) hit the MXU at full width.

Dead entries / ragged lengths
-----------------------------
Table ranks are clamped to the last *live* page of each sequence before
the kernel launches (``min(slot, ceil(len/page) − 1)``): a wholly dead
block therefore indexes the same pages as the previous step, and the
Pallas pipeline skips the re-fetch (a DMA is issued only when an
operand's block index changes between consecutive steps) — pages past
``lens[b]`` are never streamed.  Compute for dead blocks is skipped with
``pl.when``; per-token masking inside a partially-live block uses the
logical position of each page slot.  A fully-empty split emits
``(NEG_INF, 0, 0)`` and drops out of the combine exactly.

VMEM working set per grid step (f32 words unless noted)
-------------------------------------------------------
::

    q        G · D                    (storage dtype)
    k, v     2 · pages_per_block · page_size · D   (storage dtype)
    scores   G · pages_per_block · page_size
    scratch  G · (2 + D)             (m, l, acc — persist across blocks)
    partials G · (2 + D) per (b, h, s) output block

The sliding-window variant masks by ring-slot position (bounded ring
cache, see ``ref.ring_slot_positions``); softcap and int8 ``kv_scale``
dequantisation are applied per block inside the kernel, in both the
blocked and split-K paths.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.errors import EngineConfigError
from repro.kernels import resolve_interpret
# the pure-int partition law lives with the declared launch contracts
# (stdlib-only module) so replint's shape interpreter can load it by path;
# re-exported here — every caller keeps importing it from this module
from repro.kernels.paged_attention.contracts import decode_partition  # noqa: F401

NEG_INF = -1e30

# Megacore grid semantics (single source — the conformance suite asserts
# these).  Decode grid (batch, kv_head, split, block): every (b, h, s)
# slot is an independent online softmax, so the first three axes may run
# on different TPU cores; the block axis accumulates in scratch and must
# stay sequential.  Combine grid (batch, kv_head): fully parallel.
DECODE_DIM_SEMANTICS = ("parallel", "parallel", "parallel", "arbitrary")
COMBINE_DIM_SEMANTICS = ("parallel", "parallel")
# Chunked-prefill grid (batch, kv_head, q_block, split, kv_block): every
# (b, h, nq, s) slot is an independent online softmax over its KV range,
# so the first four axes parallelise; the kv-block axis accumulates in
# scratch and stays sequential.
PREFILL_DIM_SEMANTICS = ("parallel", "parallel", "parallel", "parallel",
                         "arbitrary")


COMBINE_MODES = ("jnp", "pallas")


def resolve_combine_mode(mode: Optional[str], num_splits: int) -> str:
    """``None``/"auto" → "pallas" when split-K is active, else "jnp".

    A single split needs no cross-split correction — the jnp epilogue is
    one squeeze + normalise and a kernel launch would be pure overhead.
    Explicit modes pass through (validated).
    """
    if mode is None or mode == "auto":
        return "pallas" if num_splits > 1 else "jnp"
    if mode not in COMBINE_MODES:
        raise EngineConfigError(f"combine_mode must be one of "
                                f"{COMBINE_MODES} or None/'auto', "
                                f"got {mode!r}", combine_mode=mode)
    return mode


def _combine_partials_jnp(m: jax.Array, l: jax.Array, acc: jax.Array,
                          dtype=jnp.float32) -> jax.Array:
    """jnp epilogue combine (the v2 path, kept as oracle-adjacent fallback)."""
    m_g = jnp.max(m, axis=2, keepdims=True)  # (B, Hkv, 1, G)
    corr = jnp.exp(m - m_g)
    l_g = jnp.sum(l * corr, axis=2)  # (B, Hkv, G)
    o = jnp.sum(acc * corr[..., None], axis=2)  # (B, Hkv, G, D)
    return (o / jnp.maximum(l_g, 1e-30)[..., None]).astype(dtype)


def _combine_kernel(m_ref, l_ref, acc_ref, o_ref):
    """Reduce the split axis of one (b, h) slot on-chip.

    Blocks: m/l (1, 1, S, G), acc (1, 1, S, G, D), out (1, 1, G, D).
    Max-shift merge in f32; an all-dead slot (every m == NEG_INF, l == 0)
    yields exact zeros via the ε-clamped denominator.
    """
    m = m_ref[0, 0]  # (S, G) f32
    l = l_ref[0, 0]
    acc = acc_ref[0, 0]  # (S, G, D) f32
    m_g = jnp.max(m, axis=0, keepdims=True)  # (1, G)
    corr = jnp.exp(m - m_g)  # (S, G)
    l_g = jnp.sum(l * corr, axis=0)  # (G,)
    o = jnp.sum(acc * corr[..., None], axis=0)  # (G, D)
    o_ref[0, 0] = (o / jnp.maximum(l_g, 1e-30)[:, None]).astype(o_ref.dtype)


def combine_partials_pallas(m: jax.Array, l: jax.Array, acc: jax.Array,
                            dtype=jnp.float32,
                            interpret: Optional[bool] = None) -> jax.Array:
    """Fused split-K combine: one tiny Pallas kernel per (batch, kv_head).

    m, l: (B, Hkv, S, G); acc: (B, Hkv, S, G, D) — f32 (cast if not).
    Returns (B, Hkv, G, D) in ``dtype``.  Both grid axes are marked
    ``"parallel"`` — every (b, h) reduction is independent, so megacore
    TPUs split the grid across cores.
    """
    B, Hkv, S, G = m.shape
    D = acc.shape[-1]
    part_spec = pl.BlockSpec((1, 1, S, G), lambda b, h: (b, h, 0, 0))
    return pl.pallas_call(
        _combine_kernel,
        grid=(B, Hkv),
        in_specs=[
            part_spec,
            part_spec,
            pl.BlockSpec((1, 1, S, G, D), lambda b, h: (b, h, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=COMBINE_DIM_SEMANTICS),
        interpret=resolve_interpret(interpret),
    )(m.astype(jnp.float32), l.astype(jnp.float32), acc.astype(jnp.float32))


def combine_partials(m: jax.Array, l: jax.Array, acc: jax.Array,
                     dtype=jnp.float32, mode: Optional[str] = None,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Merge split-K partials over the split axis (flash-decoding).

    m, l: (B, Hkv, S, G); acc: (B, Hkv, S, G, D) — all f32.
    Returns (B, Hkv, G, D) in ``dtype``.  ``mode`` picks the fused Pallas
    combine kernel or the jnp epilogue (None → auto by split count).
    """
    mode = resolve_combine_mode(mode, m.shape[2])
    if mode == "pallas":
        return combine_partials_pallas(m, l, acc, dtype=dtype,
                                       interpret=interpret)
    return _combine_partials_jnp(m, l, acc, dtype=dtype)


def _decode_kernel(
    *refs,
    pages_per_block: int,
    blocks_per_split: int,
    scale: float,
    window: int,
    softcap: float,
    kv_scale: float = 0.0,
):
    # positional layout: 2 scalar-prefetch, 1 + 2·ppb inputs, 3 outputs,
    # 3 scratch (see pallas_call below)
    ppb = pages_per_block
    tables_ref, lens_ref, q_ref = refs[0], refs[1], refs[2]
    k_refs = refs[3:3 + ppb]  # each (1, P, 1, D)
    v_refs = refs[3 + ppb:3 + 2 * ppb]
    m_out, l_out, acc_out = refs[3 + 2 * ppb:6 + 2 * ppb]
    m_ref, l_ref, acc_ref = refs[6 + 2 * ppb:]

    b = pl.program_id(0)
    s = pl.program_id(2)
    blk = pl.program_id(3)
    page_size = k_refs[0].shape[1]

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    L = lens_ref[b]
    block_rank = s * blocks_per_split + blk  # global KV-block index
    first_page = block_rank * ppb
    slot = jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)

    lives = []
    if window > 0:
        ring = -(-window // page_size) + 1
        cur_page = jnp.maximum(L - 1, 0) // page_size
        for j in range(ppb):
            pg = first_page + j
            # ring slot → logical position (see ref.ring_slot_positions)
            lpage = cur_page - ((cur_page - pg) % ring)
            pos = lpage * page_size + slot
            pos = jnp.where(pos >= L, pos - ring * page_size, pos)
            lives.append((pos >= 0) & (pos < L) & (pos >= L - window)
                         & (pg < ring))
        block_live = first_page < ring
    else:
        for j in range(ppb):
            pos = (first_page + j) * page_size + slot
            lives.append(pos < L)
        block_live = first_page * page_size < L
    live = jnp.concatenate(lives)  # (ppb·P,)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
        k = jnp.concatenate([r[0, :, 0, :] for r in k_refs], axis=0)
        v = jnp.concatenate([r[0, :, 0, :] for r in v_refs], axis=0)
        k = k.astype(jnp.float32)  # (ppb·P, D)
        v = v.astype(jnp.float32)
        if kv_scale > 0:  # int8 pages: dequantize the VMEM tile in-register
            k = k * kv_scale
            v = v * kv_scale

        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        if softcap > 0:
            s_ = softcap * jnp.tanh(s_ / softcap)
        s_ = jnp.where(live[None, :], s_, NEG_INF)  # (G, ppb·P)

        m_prev = m_ref[...]  # (G, 1)
        m_cur = jnp.max(s_, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.where(live[None, :], jnp.exp(s_ - m_new), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(blk == blocks_per_split - 1)
    def _emit_partial():
        m_out[0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0] = l_ref[...][:, 0]
        acc_out[0, 0, 0] = acc_ref[...]


def _blocked_tables(block_tables: jax.Array, lens: jax.Array, *,
                    num_pages: int, page_size: int, window: int,
                    padded_pages: int, pages_per_block: int) -> jax.Array:
    """(B, max_pages) table → rank-clamped (B, n_blocks, ppb) table slice.

    Dense path: slot ranks are clamped to the last live page of each row,
    so every dead entry repeats an already-streamed page and its DMA is
    elided by the pipeline (same block index as the previous step).
    Windowed path: every ring slot may be live, so only pad-clamp.
    """
    B, max_pages = block_tables.shape
    safe = jnp.clip(block_tables, 0, num_pages - 1).astype(jnp.int32)
    rank = jnp.arange(padded_pages, dtype=jnp.int32)[None, :]
    if window > 0:
        rank = jnp.broadcast_to(jnp.minimum(rank, max_pages - 1),
                                (B, padded_pages))
    else:
        n_live = jnp.maximum(-(-lens // page_size), 1).astype(jnp.int32)
        rank = jnp.minimum(rank, n_live[:, None] - 1)
    flat = jnp.take_along_axis(safe, rank, axis=1)
    return flat.reshape(B, padded_pages // pages_per_block, pages_per_block)


def paged_attention_kernel(
    q: jax.Array,  # (B, n_kv, G, D) — q heads grouped by kv head
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32 (may contain -1)
    lens: jax.Array,  # (B,)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
    combine_mode: Optional[str] = None,
) -> jax.Array:
    m, l, acc = paged_attention_partials(
        q, k_pages, v_pages, block_tables, lens, scale=scale, window=window,
        softcap=softcap, interpret=interpret, kv_scale=kv_scale,
        pages_per_block=pages_per_block, num_splits=num_splits)
    return combine_partials(m, l, acc, dtype=q.dtype, mode=combine_mode,
                            interpret=interpret)


def paged_attention_partials(
    q: jax.Array,  # (B, n_kv, G, D)
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-K partials: ((B,n_kv,S,G) m, (B,n_kv,S,G) l, (B,n_kv,S,G,D) acc)."""
    B, n_kv, G, D = q.shape
    num_pages, page_size, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    ppb, _, S, bps = decode_partition(max_pages, pages_per_block, num_splits)
    padded_pages = S * bps * ppb

    tables3d = _blocked_tables(
        block_tables, lens, num_pages=num_pages, page_size=page_size,
        window=window, padded_pages=padded_pages, pages_per_block=ppb)

    def q_map(b, h, s, blk, tables, lens):
        return (b, h, 0, 0)

    def part_map(b, h, s, blk, tables, lens):
        return (b, h, s, 0)

    def acc_map(b, h, s, blk, tables, lens):
        return (b, h, s, 0, 0)

    def kv_map(b, h, s, blk, tables, lens, *, j):
        del lens
        return (tables[b, s * bps + blk, j], 0, h, 0)

    kv_spec = lambda j: pl.BlockSpec((1, page_size, 1, D),
                                     functools.partial(kv_map, j=j))

    kernel = functools.partial(
        _decode_kernel, pages_per_block=ppb, blocks_per_split=bps,
        scale=scale, window=window, softcap=softcap, kv_scale=kv_scale)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_kv, S, bps),
            in_specs=(
                [pl.BlockSpec((1, 1, G, D), q_map)]
                + [kv_spec(j) for j in range(ppb)]       # k pages of a block
                + [kv_spec(j) for j in range(ppb)]       # v pages of a block
            ),
            out_specs=[
                pl.BlockSpec((1, 1, 1, G), part_map),
                pl.BlockSpec((1, 1, 1, G), part_map),
                pl.BlockSpec((1, 1, 1, G, D), acc_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=DECODE_DIM_SEMANTICS),
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, S, G), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, S, G), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, S, G, D), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(tables3d, lens.astype(jnp.int32), q,
      *([k_pages] * ppb), *([v_pages] * ppb))


def _prefill_kernel(
    *refs,
    pages_per_block: int,
    blocks_per_split: int,
    q_block: int,
    group: int,
    scale: float,
    softcap: float,
    kv_scale: float = 0.0,
):
    """Chunked-prefill kernel body: one Q-block of ``q_block·G`` rows per
    (b, h, nq, s) slot, online-softmax over its split's KV blocks.

    Positional layout mirrors `_decode_kernel` with one extra scalar
    prefetch (``q_start``) and the q-block grid axis: 3 scalar-prefetch,
    1 + 2·ppb inputs, 3 outputs, 3 scratch.
    """
    ppb = pages_per_block
    tables_ref, lens_ref, qstart_ref = refs[0], refs[1], refs[2]
    q_ref = refs[3]
    k_refs = refs[4:4 + ppb]  # each (1, P, 1, D)
    v_refs = refs[4 + ppb:4 + 2 * ppb]
    m_out, l_out, acc_out = refs[4 + 2 * ppb:7 + 2 * ppb]
    m_ref, l_ref, acc_ref = refs[7 + 2 * ppb:]

    b = pl.program_id(0)
    nq = pl.program_id(2)
    s = pl.program_id(3)
    blk = pl.program_id(4)
    page_size = k_refs[0].shape[1]
    R = q_block * group  # rows: r = chunk-token·G + head-group

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    L = lens_ref[b]  # kv_lens: cached tokens incl. the chunk
    q0 = qstart_ref[b]  # absolute position of chunk token 0
    block_rank = s * blocks_per_split + blk
    first_page = block_rank * ppb
    slot = jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)

    kvpos = jnp.concatenate(
        [(first_page + j) * page_size + slot for j in range(ppb)])
    live_kv = kvpos < L  # (ppb·P,)
    row = jax.lax.broadcasted_iota(jnp.int32, (R,), 0)
    qpos = q0 + nq * q_block + row // group  # (R,) absolute q positions
    # causal upper bound for the whole Q-block: KV blocks wholly past the
    # block's last query never contribute — skip their compute (their DMAs
    # are already elided by the rank clamp in `_blocked_tables`).
    qpos_max = q0 + nq * q_block + q_block - 1
    block_live = (first_page * page_size < L) & \
        (first_page * page_size <= qpos_max)

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32) * scale  # (R, D)
        k = jnp.concatenate([r[0, :, 0, :] for r in k_refs], axis=0)
        v = jnp.concatenate([r[0, :, 0, :] for r in v_refs], axis=0)
        k = k.astype(jnp.float32)  # (ppb·P, D)
        v = v.astype(jnp.float32)
        if kv_scale > 0:
            k = k * kv_scale
            v = v * kv_scale

        s_ = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        if softcap > 0:
            s_ = softcap * jnp.tanh(s_ / softcap)
        live = live_kv[None, :] & (kvpos[None, :] <= qpos[:, None])
        s_ = jnp.where(live, s_, NEG_INF)  # (R, ppb·P)

        m_prev = m_ref[...]  # (R, 1)
        m_cur = jnp.max(s_, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.where(live, jnp.exp(s_ - m_new), 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(blk == blocks_per_split - 1)
    def _emit_partial():
        m_out[0, 0, 0, 0] = m_ref[...][:, 0]
        l_out[0, 0, 0, 0] = l_ref[...][:, 0]
        acc_out[0, 0, 0, 0] = acc_ref[...]


def _prefill_q_blocks(q: jax.Array, n_kv: int, q_block: int
                      ) -> Tuple[jax.Array, int]:
    """(B, C, H, D) chunk queries → (B, n_kv, NQ, q_block·G, D) row blocks.

    Row ``r`` of a block is chunk token ``r // G``, head group ``r % G``
    — the layout both prefill lowerings and the partials oracle share.
    """
    B, C, H, D = q.shape
    G = H // n_kv
    nq = -(-C // q_block)
    qpad = jnp.pad(q, ((0, 0), (0, nq * q_block - C), (0, 0), (0, 0)))
    qb = qpad.reshape(B, nq, q_block, n_kv, G, D).transpose(0, 3, 1, 2, 4, 5)
    return qb.reshape(B, n_kv, nq, q_block * G, D), nq


def combine_prefill_partials(m: jax.Array, l: jax.Array, acc: jax.Array,
                             C: int, q_block: int, *, dtype=jnp.float32,
                             mode: Optional[str] = None,
                             interpret: Optional[bool] = None) -> jax.Array:
    """Merge chunked-prefill split-K partials through the *decode* combine.

    m, l: (B, Hkv, NQ, S, R); acc: (B, Hkv, NQ, S, R, D) with
    ``R = q_block·G``.  The q-block axis folds into the batch axis so
    `combine_partials` (jnp epilogue or the fused Pallas kernel) applies
    unchanged — one combine implementation across decode and prefill.
    Returns (B, C, H, D).
    """
    B, n_kv, NQ, S, R = m.shape
    D = acc.shape[-1]
    G = R // q_block
    m2 = m.transpose(0, 2, 1, 3, 4).reshape(B * NQ, n_kv, S, R)
    l2 = l.transpose(0, 2, 1, 3, 4).reshape(B * NQ, n_kv, S, R)
    acc2 = acc.transpose(0, 2, 1, 3, 4, 5).reshape(B * NQ, n_kv, S, R, D)
    o = combine_partials(m2, l2, acc2, dtype=dtype, mode=mode,
                         interpret=interpret)  # (B·NQ, n_kv, R, D)
    o = o.reshape(B, NQ, n_kv, q_block, G, D).transpose(0, 1, 3, 2, 4, 5)
    return o.reshape(B, NQ * q_block, n_kv * G, D)[:, :C]


def paged_prefill_partials(
    q: jax.Array,  # (B, C, n_heads, D) — one prompt chunk per sequence
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32 (may contain -1)
    kv_lens: jax.Array,  # (B,) cached tokens incl. the chunk
    q_start: jax.Array,  # (B,) absolute position of chunk token 0
    *,
    scale: float,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
    q_block: int = 1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill split-K partials (TPU lowering).

    Q-block × cached-KV-block grid: ``(B, n_kv, NQ, num_splits, bps)``,
    sharing `decode_partition`'s page ranges and the decode kernel's
    ``(m, l, acc)`` partial contract with the GQA row axis widened to
    ``q_block·G`` rows.  Returns ((B,Hkv,NQ,S,R) m, (B,Hkv,NQ,S,R) l,
    (B,Hkv,NQ,S,R,D) acc) — f32, shaped for `combine_prefill_partials`.
    """
    B, C, n_heads, D = q.shape
    num_pages, page_size, n_kv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = n_heads // n_kv

    ppb, _, S, bps = decode_partition(max_pages, pages_per_block, num_splits)
    padded_pages = S * bps * ppb
    qb5, NQ = _prefill_q_blocks(q, n_kv, q_block)
    R = q_block * G

    tables3d = _blocked_tables(
        block_tables, kv_lens, num_pages=num_pages, page_size=page_size,
        window=0, padded_pages=padded_pages, pages_per_block=ppb)

    def q_map(b, h, nq, s, blk, tables, lens, qstart):
        return (b, h, nq, 0, 0)

    def part_map(b, h, nq, s, blk, tables, lens, qstart):
        return (b, h, nq, s, 0)

    def acc_map(b, h, nq, s, blk, tables, lens, qstart):
        return (b, h, nq, s, 0, 0)

    def kv_map(b, h, nq, s, blk, tables, lens, qstart, *, j):
        del lens, qstart
        return (tables[b, s * bps + blk, j], 0, h, 0)

    kv_spec = lambda j: pl.BlockSpec((1, page_size, 1, D),
                                     functools.partial(kv_map, j=j))

    kernel = functools.partial(
        _prefill_kernel, pages_per_block=ppb, blocks_per_split=bps,
        q_block=q_block, group=G, scale=scale, softcap=softcap,
        kv_scale=kv_scale)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, n_kv, NQ, S, bps),
            in_specs=(
                [pl.BlockSpec((1, 1, 1, R, D), q_map)]
                + [kv_spec(j) for j in range(ppb)]
                + [kv_spec(j) for j in range(ppb)]
            ),
            out_specs=[
                pl.BlockSpec((1, 1, 1, 1, R), part_map),
                pl.BlockSpec((1, 1, 1, 1, R), part_map),
                pl.BlockSpec((1, 1, 1, 1, R, D), acc_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, D), jnp.float32),
            ],
        ),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=PREFILL_DIM_SEMANTICS),
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, NQ, S, R), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, NQ, S, R), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, NQ, S, R, D), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(tables3d, kv_lens.astype(jnp.int32), q_start.astype(jnp.int32), qb5,
      *([k_pages] * ppb), *([v_pages] * ppb))


def paged_prefill_kernel(
    q: jax.Array,  # (B, C, n_heads, D)
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    kv_lens: jax.Array,
    q_start: jax.Array,
    *,
    scale: float,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
    q_block: int = 1,
    combine_mode: Optional[str] = None,
) -> jax.Array:
    """Full chunked-prefill attention (TPU): partials + shared combine."""
    m, l, acc = paged_prefill_partials(
        q, k_pages, v_pages, block_tables, kv_lens, q_start, scale=scale,
        softcap=softcap, interpret=interpret, kv_scale=kv_scale,
        pages_per_block=pages_per_block, num_splits=num_splits,
        q_block=q_block)
    return combine_prefill_partials(m, l, acc, q.shape[1], q_block,
                                    dtype=q.dtype, mode=combine_mode,
                                    interpret=interpret)


def decode_grid_steps(max_pages: int, *, pages_per_block: int = 1,
                      num_splits: int = 1) -> int:
    """Grid steps per (batch, kv_head) pair — the kernel-launch-overhead
    metric `benchmarks/fig4_decode.py` reports (one-page baseline =
    ``max_pages``)."""
    _, _, S, bps = decode_partition(max_pages, pages_per_block, num_splits)
    return S * bps
