"""Pallas TPU kernel: fused paged decode attention.

TPU adaptation of the paper's FlexAttention-fused PagedAttention (§III-B).
On GPU the fused kernel gathers scattered KV through `mask_mod` indexing;
on TPU random gathers inside a kernel are slow, so instead the *grid* walks
pages and the block table is a **scalar-prefetch operand**: the page→HBM
translation happens in the BlockSpec ``index_map``, so the Pallas pipeline's
DMA engine streams exactly the live pages HBM→VMEM, double-buffered, with no
gather materialisation (DESIGN.md §2, A1).  Because physical pages are
scattered, each grid step fetches exactly one page (the pipeline still
overlaps the next page's DMA with this page's compute).

Grid: (batch, kv_heads, max_pages)  — pages innermost so the online-softmax
accumulators for one (b, h) persist in VMEM scratch across page steps.

Block shapes (VMEM working set, MXU-aligned when head_dim is 128):
  q    : (1, 1, q_per_kv, head_dim)   — the decode token's q-head group
  k/v  : (1, page_size, 1, head_dim)  — one physical page
  out  : (1, 1, q_per_kv, head_dim)

Pages whose first token is past the sequence length are skipped with
``pl.when`` (no FLOPs; the DMA for their duplicate-clamped page still lands
but is O(page) — the wrapper clamps dead table entries to page 0).
The sliding-window variant masks by ring-slot position (bounded cache).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    # scalar prefetch
    tables_ref,  # (B, max_pages) int32 (clamped to valid page ids)
    lens_ref,  # (B,) int32
    # inputs
    q_ref,  # (1, 1, G, D)
    k_ref,  # (1, P, 1, D)
    v_ref,  # (1, P, 1, D)
    # outputs
    o_ref,  # (1, 1, G, D)
    # scratch
    m_ref,  # (G, 1) f32
    l_ref,  # (G, 1) f32
    acc_ref,  # (G, D) f32
    *,
    scale: float,
    window: int,
    softcap: float,
    kv_scale: float = 0.0,
):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pb = pl.num_programs(2)
    page_size = k_ref.shape[1]
    D = q_ref.shape[3]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    L = lens_ref[b]
    slot = jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)
    if window > 0:
        ring = -(-window // page_size) + 1
        # ring slot → logical position (see ref.ring_slot_positions)
        cur_page = jnp.maximum(L - 1, 0) // page_size
        lpage = cur_page - ((cur_page - p) % ring)
        pos = lpage * page_size + slot
        pos = jnp.where(pos >= L, pos - ring * page_size, pos)
        live = (pos >= 0) & (pos < L) & (pos >= L - window)
        page_live = p < ring
    else:
        pos = p * page_size + slot
        live = pos < L
        page_live = p * page_size < L

    @pl.when(page_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (P, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if kv_scale > 0:  # int8 pages: dequantize the VMEM tile in-register
            k = k * kv_scale
            v = v * kv_scale

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, P)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(live[None, :], s, NEG_INF)

        m_prev = m_ref[...]  # (G, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.where(live[None, :], jnp.exp(s - m_new), 0.0)  # (G, P)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(p == n_pb - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_attention_kernel(
    q: jax.Array,  # (B, n_kv, G, D) — q heads grouped by kv head
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32 (may contain -1)
    lens: jax.Array,  # (B,)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
    kv_scale: float = 0.0,
) -> jax.Array:
    B, n_kv, G, D = q.shape
    num_pages, page_size, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    tables = jnp.clip(block_tables, 0, num_pages - 1).astype(jnp.int32)

    def q_map(b, h, p, tables, lens):
        return (b, h, 0, 0)

    def kv_map(b, h, p, tables, lens):
        del lens
        return (tables[b, p], 0, h, 0)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               softcap=softcap, kv_scale=kv_scale)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, n_kv, max_pages),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), q_map),
                pl.BlockSpec((1, page_size, 1, D), kv_map),
                pl.BlockSpec((1, page_size, 1, D), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D), q_map),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_kv, G, D), q.dtype),
        interpret=interpret,
    )(tables, lens.astype(jnp.int32), q, k_pages, v_pages)
