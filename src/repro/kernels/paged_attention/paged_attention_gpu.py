"""Pallas GPU kernel: fused paged decode attention (Triton lowering).

GPU counterpart of the TPU decode kernel (`paged_attention.py`) — the
paper's actual deployment target: a FlexAttention-style fused kernel that
*gathers scattered KV data* inside the attention loop (§III-B).  Where the
TPU lowering must route the page→HBM translation through BlockSpec
``index_map``s so Mosaic's DMA pipeline streams pages into VMEM, the
Triton lowering gathers *inside* the kernel: the block table is a plain
device array and each KV block's pages are fetched with dynamically
indexed ``tl.load``s (Pallas ref indexing by a traced page id), exactly
how GPU PagedAttention kernels address non-contiguous physical blocks.

Design (mirrors the TPU kernel's v2 contract)
=============================================

Grid layout
-----------
::

    grid = (batch, kv_heads, num_splits)

One CUDA block per (b, h, s) slot.  There is no grid axis for KV blocks:
each slot walks its ``blocks_per_split`` KV blocks with an in-kernel
``fori_loop``, gathering ``pages_per_block`` scattered pages per step via
block-table indexed loads and folding them into an online softmax held in
registers.  All three grid axes are embarrassingly parallel — the GPU
analogue of the TPU kernel's megacore ``dimension_semantics``: different
splits of the *same* sequence land on different SMs, which is the whole
point of flash-decoding split-K for batch=1 long-context decode.

Partition & partial contract
----------------------------
`decode_partition` is shared with the TPU kernel, so both backends put
bit-identical page ranges in each split, and every ``(b, h, s)`` slot
emits the same un-normalised ``(m, l, acc)`` partial that
`ref.paged_attention_partials_ref` specifies.  The split-K merge is the
*same* `combine_partials` the TPU pipeline uses — jnp epilogue or the
fused Pallas combine kernel — completely unchanged, which is what lets
`tests/test_combine_conformance.py` gate both backends with one oracle.

Dead entries / ragged lengths
-----------------------------
Table ranks are pre-clamped on the host (`_blocked_tables`, shared): a
dead slot re-reads an already-live page, so gathers never touch pages
past ``lens[b]`` and no load needs a mask.  On the dense path the
``fori_loop`` trip count is clamped to the split's *live* block count —
wholly-dead padding blocks are never gathered or scored (the GPU
analogue of the TPU kernel's ``pl.when`` + elided DMAs) and a fully-empty
split does zero trips, emitting the ``(NEG_INF, 0, 0)`` init partial
that drops out of the combine exactly.  Per-token liveness masks a
partially-live block's scores to ``NEG_INF`` — all identical in effect
to the TPU kernel.

Matmul shapes
-------------
``tl.dot`` needs M ≥ 16 but GQA groups are small (G ∈ 1..8), so scores
and the p·V contraction use a broadcast multiply-reduce when G < 16 (the
same trick as jax's GPU decode-attention kernel) and a real MMA otherwise.

Validation
----------
Off-GPU the kernel runs through the Pallas interpreter (CPU CI exercises
the full ppb × splits × variant conformance sweep); on a real GPU it
compiles through ``plgpu.TritonCompilerParams``.  Real-GPU
``interpret=False`` validation is an open ROADMAP item, mirroring the
TPU-hardware one.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import triton as plgpu

from repro.kernels import resolve_interpret
from repro.kernels.paged_attention.paged_attention import (
    NEG_INF, _blocked_tables, _prefill_q_blocks, combine_partials,
    combine_prefill_partials, decode_partition)

# Triton launch shape: warps per CTA / software pipeline depth for the
# gather+dot loop.  Modest defaults — one (G, ppb·P) tile per CTA is a
# small working set; deeper pipelining mostly hides the scattered loads.
_NUM_WARPS = 4
_NUM_STAGES = 2


def _dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) in f32.  tl.dot requires M ≥ 16; GQA decode has
    M = G ∈ 1..8, so small M uses a broadcast multiply-reduce (VPU-ish)
    instead of an MMA — identical math, no Triton shape constraint."""
    if a.shape[0] < 16:
        return jnp.sum(a[:, :, None] * b[None, :, :], axis=1)
    return jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _decode_kernel_gpu(
    tables_ref,  # (B, n_blocks, ppb) int32 — rank-clamped table slice
    lens_ref,  # (B,) int32
    q_ref,  # (1, 1, G, D) block for this (b, h)
    k_ref,  # (num_pages, P, n_kv, D) — whole pool, gathered in-kernel
    v_ref,
    m_out,  # (1, 1, 1, G)
    l_out,  # (1, 1, 1, G)
    acc_out,  # (1, 1, 1, G, D)
    *,
    pages_per_block: int,
    blocks_per_split: int,
    scale: float,
    window: int,
    softcap: float,
    kv_scale: float,
):
    ppb = pages_per_block
    b = pl.program_id(0)
    h = pl.program_id(1)
    s = pl.program_id(2)
    page_size = k_ref.shape[1]
    G, D = q_ref.shape[2], q_ref.shape[3]

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, D)
    L = lens_ref[b]
    slot = jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)
    if window > 0:
        ring = -(-window // page_size) + 1
        cur_page = jnp.maximum(L - 1, 0) // page_size
        # bounded ring: any slot may be live — walk the whole split
        n_trips = blocks_per_split
    else:
        # dead-block skip (the GPU analogue of the TPU kernel's pl.when +
        # DMA elision): only the blocks covering ceil(L / page_size) live
        # pages are walked; a split wholly past the live range does zero
        # trips and emits the init (NEG_INF, 0, 0) partial.
        n_live_blocks = ((L + page_size - 1) // page_size + ppb - 1) // ppb
        n_trips = jnp.clip(n_live_blocks - s * blocks_per_split, 0,
                           blocks_per_split)

    def body(blk, carry):
        m_prev, l_prev, acc_prev = carry  # (G, 1), (G, 1), (G, D)
        block_rank = s * blocks_per_split + blk
        first_page = block_rank * ppb
        ks, vs, lives = [], [], []
        for j in range(ppb):
            pg = first_page + j
            if window > 0:
                # ring slot → logical position (see ref.ring_slot_positions)
                lpage = cur_page - ((cur_page - pg) % ring)
                pos = lpage * page_size + slot
                pos = jnp.where(pos >= L, pos - ring * page_size, pos)
                lives.append((pos >= 0) & (pos < L) & (pos >= L - window)
                             & (pg < ring))
            else:
                pos = pg * page_size + slot
                lives.append(pos < L)
            # the paged gather: one dynamically indexed load per scattered
            # page — the table entry computes the tl.load base pointer
            page = tables_ref[b, block_rank, j]
            ks.append(k_ref[page, :, h, :])  # (P, D)
            vs.append(v_ref[page, :, h, :])
        live = jnp.concatenate(lives)  # (ppb·P,)
        k = jnp.concatenate(ks, axis=0).astype(jnp.float32)
        v = jnp.concatenate(vs, axis=0).astype(jnp.float32)
        if kv_scale > 0:  # int8 pages: dequantize the gathered tile
            k = k * kv_scale
            v = v * kv_scale

        s_ = _dot(q, k.T)  # (G, ppb·P)
        if softcap > 0:
            s_ = softcap * jnp.tanh(s_ / softcap)
        s_ = jnp.where(live[None, :], s_, NEG_INF)

        m_cur = jnp.max(s_, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.where(live[None, :], jnp.exp(s_ - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(pexp, axis=1, keepdims=True)
        acc_new = acc_prev * alpha + _dot(pexp, v)
        return m_new, l_new, acc_new

    init = (jnp.full((G, 1), NEG_INF, jnp.float32),
            jnp.zeros((G, 1), jnp.float32),
            jnp.zeros((G, D), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, n_trips, body, init)
    m_out[0, 0, 0] = m[:, 0]
    l_out[0, 0, 0] = l[:, 0]
    acc_out[0, 0, 0] = acc


def paged_attention_partials_gpu(
    q: jax.Array,  # (B, n_kv, G, D)
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-K partials, same contract as the TPU kernel's
    `paged_attention_partials`: ((B,n_kv,S,G) m, (B,n_kv,S,G) l,
    (B,n_kv,S,G,D) acc) — f32."""
    B, n_kv, G, D = q.shape
    num_pages, page_size, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]

    ppb, _, S, bps = decode_partition(max_pages, pages_per_block, num_splits)
    padded_pages = S * bps * ppb

    tables3d = _blocked_tables(
        block_tables, lens, num_pages=num_pages, page_size=page_size,
        window=window, padded_pages=padded_pages, pages_per_block=ppb)

    kernel = functools.partial(
        _decode_kernel_gpu, pages_per_block=ppb, blocks_per_split=bps,
        scale=scale, window=window, softcap=softcap, kv_scale=kv_scale)

    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda b, h, s: (0,) * arr.ndim)
    return pl.pallas_call(
        kernel,
        grid=(B, n_kv, S),
        in_specs=[
            whole(tables3d),
            whole(lens),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            whole(k_pages),  # pools stay in GMEM; gathered per table entry
            whole(v_pages),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, G), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, 1, G, D), lambda b, h, s: (b, h, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, S, G), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, S, G), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, S, G, D), jnp.float32),
        ],
        compiler_params=plgpu.TritonCompilerParams(
            num_warps=_NUM_WARPS, num_stages=_NUM_STAGES),
        interpret=resolve_interpret(interpret, backend="gpu"),
    )(tables3d, lens.astype(jnp.int32), q, k_pages, v_pages)


def _prefill_kernel_gpu(
    tables_ref,  # (B, n_blocks, ppb) int32 — rank-clamped table slice
    lens_ref,  # (B,) int32 — kv_lens (cached tokens incl. the chunk)
    qstart_ref,  # (B,) int32 — absolute position of chunk token 0
    q_ref,  # (1, 1, 1, R, D) block for this (b, h, nq)
    k_ref,  # (num_pages, P, n_kv, D) — whole pool, gathered in-kernel
    v_ref,
    m_out,  # (1, 1, 1, 1, R)
    l_out,
    acc_out,  # (1, 1, 1, 1, R, D)
    *,
    pages_per_block: int,
    blocks_per_split: int,
    q_block: int,
    group: int,
    scale: float,
    softcap: float,
    kv_scale: float,
):
    """Chunked-prefill GPU body: one CTA per (b, h, nq, s) slot, in-kernel
    ``fori_loop`` over the split's KV blocks with block-table gathers —
    the decode kernel's structure with a ``q_block·G``-row score tile and
    a causal trip-count clamp (blocks wholly past the Q-block's last
    query are never gathered)."""
    ppb = pages_per_block
    b = pl.program_id(0)
    h = pl.program_id(1)
    nq = pl.program_id(2)
    s = pl.program_id(3)
    page_size = k_ref.shape[1]
    R, D = q_ref.shape[3], q_ref.shape[4]

    q = q_ref[0, 0, 0].astype(jnp.float32) * scale  # (R, D)
    L = lens_ref[b]
    q0 = qstart_ref[b]
    slot = jax.lax.broadcasted_iota(jnp.int32, (page_size,), 0)
    row = jax.lax.broadcasted_iota(jnp.int32, (R,), 0)
    qpos = q0 + nq * q_block + row // group  # (R,)
    qpos_max = q0 + nq * q_block + q_block - 1
    # live + causal block bound: only blocks covering tokens < min(L,
    # qpos_max+1) contribute — the rest do zero trips (init partial).
    kv_hi = jnp.minimum(L, qpos_max + 1)
    n_live_blocks = ((kv_hi + page_size - 1) // page_size + ppb - 1) // ppb
    n_trips = jnp.clip(n_live_blocks - s * blocks_per_split, 0,
                       blocks_per_split)

    def body(blk, carry):
        m_prev, l_prev, acc_prev = carry  # (R, 1), (R, 1), (R, D)
        block_rank = s * blocks_per_split + blk
        first_page = block_rank * ppb
        ks, vs, poss = [], [], []
        for j in range(ppb):
            pg = first_page + j
            poss.append(pg * page_size + slot)
            page = tables_ref[b, block_rank, j]
            ks.append(k_ref[page, :, h, :])  # (P, D)
            vs.append(v_ref[page, :, h, :])
        kvpos = jnp.concatenate(poss)  # (ppb·P,)
        k = jnp.concatenate(ks, axis=0).astype(jnp.float32)
        v = jnp.concatenate(vs, axis=0).astype(jnp.float32)
        if kv_scale > 0:
            k = k * kv_scale
            v = v * kv_scale

        s_ = _dot(q, k.T)  # (R, ppb·P)
        if softcap > 0:
            s_ = softcap * jnp.tanh(s_ / softcap)
        live = (kvpos < L)[None, :] & (kvpos[None, :] <= qpos[:, None])
        s_ = jnp.where(live, s_, NEG_INF)

        m_cur = jnp.max(s_, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.where(live, jnp.exp(s_ - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(pexp, axis=1, keepdims=True)
        acc_new = acc_prev * alpha + _dot(pexp, v)
        return m_new, l_new, acc_new

    init = (jnp.full((R, 1), NEG_INF, jnp.float32),
            jnp.zeros((R, 1), jnp.float32),
            jnp.zeros((R, D), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, n_trips, body, init)
    m_out[0, 0, 0, 0] = m[:, 0]
    l_out[0, 0, 0, 0] = l[:, 0]
    acc_out[0, 0, 0, 0] = acc


def paged_prefill_partials_gpu(
    q: jax.Array,  # (B, C, n_heads, D)
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    kv_lens: jax.Array,  # (B,)
    q_start: jax.Array,  # (B,)
    *,
    scale: float,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
    q_block: int = 1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked-prefill split-K partials (Triton lowering) — identical
    contract to the TPU `paged_prefill_partials`; gated by the same
    `ref.paged_prefill_ref` oracle."""
    B, C, n_heads, D = q.shape
    num_pages, page_size, n_kv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    G = n_heads // n_kv

    ppb, _, S, bps = decode_partition(max_pages, pages_per_block, num_splits)
    padded_pages = S * bps * ppb
    qb5, NQ = _prefill_q_blocks(q, n_kv, q_block)
    R = q_block * G

    tables3d = _blocked_tables(
        block_tables, kv_lens, num_pages=num_pages, page_size=page_size,
        window=0, padded_pages=padded_pages, pages_per_block=ppb)

    kernel = functools.partial(
        _prefill_kernel_gpu, pages_per_block=ppb, blocks_per_split=bps,
        q_block=q_block, group=G, scale=scale, softcap=softcap,
        kv_scale=kv_scale)

    whole = lambda arr: pl.BlockSpec(arr.shape,
                                     lambda b, h, nq, s: (0,) * arr.ndim)
    return pl.pallas_call(
        kernel,
        grid=(B, n_kv, NQ, S),
        in_specs=[
            whole(tables3d),
            whole(kv_lens),
            whole(q_start),
            pl.BlockSpec((1, 1, 1, R, D), lambda b, h, nq, s: (b, h, nq, 0, 0)),
            whole(k_pages),
            whole(v_pages),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, 1, R), lambda b, h, nq, s: (b, h, nq, s, 0)),
            pl.BlockSpec((1, 1, 1, 1, R), lambda b, h, nq, s: (b, h, nq, s, 0)),
            pl.BlockSpec((1, 1, 1, 1, R, D),
                         lambda b, h, nq, s: (b, h, nq, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, NQ, S, R), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, NQ, S, R), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, NQ, S, R, D), jnp.float32),
        ],
        compiler_params=plgpu.TritonCompilerParams(
            num_warps=_NUM_WARPS, num_stages=_NUM_STAGES),
        interpret=resolve_interpret(interpret, backend="gpu"),
    )(tables3d, kv_lens.astype(jnp.int32), q_start.astype(jnp.int32), qb5,
      k_pages, v_pages)


def paged_prefill_kernel_gpu(
    q: jax.Array,  # (B, C, n_heads, D)
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    kv_lens: jax.Array,
    q_start: jax.Array,
    *,
    scale: float,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
    q_block: int = 1,
    combine_mode: Optional[str] = None,
) -> jax.Array:
    """Full chunked-prefill attention (GPU): Triton partials + the shared
    split-K combine (backend-independent, same oracle)."""
    m, l, acc = paged_prefill_partials_gpu(
        q, k_pages, v_pages, block_tables, kv_lens, q_start, scale=scale,
        softcap=softcap, interpret=interpret, kv_scale=kv_scale,
        pages_per_block=pages_per_block, num_splits=num_splits,
        q_block=q_block)
    return combine_prefill_partials(m, l, acc, q.shape[1], q_block,
                                    dtype=q.dtype, mode=combine_mode,
                                    interpret=interpret)


def paged_attention_kernel_gpu(
    q: jax.Array,  # (B, n_kv, G, D) — q heads grouped by kv head
    k_pages: jax.Array,  # (num_pages, P, n_kv, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32 (may contain -1)
    lens: jax.Array,  # (B,)
    *,
    scale: float,
    window: int = 0,
    softcap: float = 0.0,
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: int = 1,
    num_splits: int = 1,
    combine_mode: Optional[str] = None,
) -> jax.Array:
    """Full GPU decode: Triton partials + the shared split-K combine."""
    m, l, acc = paged_attention_partials_gpu(
        q, k_pages, v_pages, block_tables, lens, scale=scale, window=window,
        softcap=softcap, interpret=interpret, kv_scale=kv_scale,
        pages_per_block=pages_per_block, num_splits=num_splits)
    # the combine contract is backend-independent — same kernel/epilogue,
    # same oracle (`ref.combine_partials_ref`), zero GPU-specific code
    return combine_partials(m, l, acc, dtype=q.dtype, mode=combine_mode,
                            interpret=interpret)
