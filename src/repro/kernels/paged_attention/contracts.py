"""Declared launch contracts for every Pallas kernel in ``repro.kernels``.

This module is the *checkable* half of the kernel documentation: each
``pallas_call`` site in ``src/repro/kernels/`` (TPU and Triton decode,
prefill, combine, and the flex prefill kernel) declares its grid symbols,
operand shapes/dtypes, scalar-prefetch layout, output contract and the
value range of every prefetch table here — and ``replint``'s ``shapes``
rule abstractly interprets the site's BlockSpecs/index_maps against the
declaration for a set of concrete sample partitions.  Facts that used to
live in comments ("(B, n_kv, S, G) f32", "tables are clamped to
[0, num_pages-1]") are now data a checker consumes.

Deliberately **stdlib-only** (no jax): the checker loads this file by
path, so importing it must cost nothing.  ``decode_partition`` — the pure
integer partition law both backends share — lives here for the same
reason and is re-exported by ``paged_attention.py``.

Contract schema (one dict per site, keyed by the *enclosing function
name* of the ``pallas_call``)::

    "site_name": {
        "backend": "tpu" | "gpu",
        "grid": ("B", "n_kv", ...),      # axis symbols, for documentation
        "num_scalar_prefetch": int | symbol,
        "operands": [                     # call-operand order, prefetch first
            {"name": "tables3d",          # the site-local variable name
             "shape": ("B", "NB", "ppb"), # symbols/ints, or a sample key
             "dtype": "int32",            #   whose value is a shape tuple
             "repeat": "ppb",             # operand appears sample[repeat]×
             "value_range": (0, "NPm1")}, # int contents (inclusive bounds)
            ...],
        "outputs": [{"shape": (...), "dtype": "float32"}, ...],
        "partial_group": "decode-partials" | None,   # (m, l, acc) family
        "consumes": {"group": ..., "operands": (...)} | None,
        "samples": [ {symbol: int, ...}, ... ],      # concrete bindings
    }

Sample symbols must use the **site-local variable names** — the checker
evaluates the site's actual AST expressions (block shapes, grids,
index_maps, factory lambdas) under the sample binding, so the contract
only holds if the code and the declaration agree.  Exactly one sample per
contract sets ``"_parity": True``: members of a ``partial_group`` are
compared under their parity samples (TPU ≡ GPU partial-contract parity),
consumers (``consumes``) must ingest exactly the group's partial shapes
(the decode/prefill → combine handoff), and every partial must be f32.

To extend: add the contract dict alongside the new ``pallas_call``'s
function, reusing ``decode_partition`` for derived symbols, and give it a
parity sample if it emits or consumes split-K partials.  A site in
``src/repro/kernels/`` with no entry here is itself a finding.
"""

from __future__ import annotations

from typing import Dict, List, Tuple


def decode_partition(max_pages: int, pages_per_block: int = 1,
                     num_splits: int = 1) -> Tuple[int, int, int, int]:
    """Clamp knobs and derive the kernel's split/block partition.

    Returns ``(pages_per_block, n_blocks, num_splits, blocks_per_split)``.
    Single source of the partition law — the kernel grid, the auto-tuner
    (`ops.choose_decode_params`), the grid-step accounting
    (`decode_grid_steps`), the split-K oracle
    (`ref.paged_attention_partials_ref`) and the declared contracts below
    must all agree bit-for-bit on which pages land in which split.
    """
    max_pages = max(1, int(max_pages))
    ppb = max(1, min(int(pages_per_block), max_pages))
    n_blocks = -(-max_pages // ppb)
    ns = max(1, min(int(num_splits), n_blocks))
    bps = -(-n_blocks // ns)  # last split may cover padding blocks
    return ppb, n_blocks, ns, bps


# ---------------------------------------------------------------------------
# sample partitions — every boundary of the partition law gets a binding
# ---------------------------------------------------------------------------
# (max_pages, pages_per_block, num_splits, is_parity_sample)
_DECODE_CASES = [
    (4, 2, 2, True),    # even split — the canonical parity configuration
    (1, 1, 1, False),   # minimal: one page, one block, one split
    (7, 2, 3, False),   # ragged: blocks pad the page axis, splits pad blocks
    (5, 3, 8, False),   # num_splits clamped down to n_blocks
    (8, 4, 1, False),   # single split, wide block
]


def _decode_samples() -> List[Dict]:
    out = []
    for mp, pb, ns, parity in _DECODE_CASES:
        ppb, _, s, bps = decode_partition(mp, pb, ns)
        out.append({
            "B": 2, "n_kv": 2, "G": 4, "D": 8,
            "page_size": 4, "num_pages": 16, "NPm1": 15,
            "ppb": ppb, "S": s, "bps": bps, "NB": s * bps,
            "_parity": parity,
        })
    return out


def _prefill_samples() -> List[Dict]:
    # (max_pages, pages_per_block, num_splits, q_block, parity); the
    # parity sample uses q_block=1 so R == G and the q-block axis folds
    # onto the decode partial contract exactly.
    cases = [
        (4, 2, 2, 1, True),
        (7, 2, 3, 2, False),
        (1, 1, 1, 1, False),
        (8, 4, 2, 4, False),
    ]
    out = []
    for mp, pb, ns, q_block, parity in cases:
        ppb, _, s, bps = decode_partition(mp, pb, ns)
        g = 4
        out.append({
            "B": 2, "n_kv": 2, "G": g, "D": 8,
            "page_size": 4, "num_pages": 16, "NPm1": 15,
            "ppb": ppb, "S": s, "bps": bps, "NB": s * bps,
            # NQ deliberately differs from every other axis extent so a
            # fold along the wrong axis cannot alias into a clean check
            "NQ": 3, "R": q_block * g, "q_block": q_block,
            "_parity": parity,
        })
    return out


def _kv_pool(n_kv: str = "n_kv") -> Dict:
    return {"name": "k_pages",
            "shape": ("num_pages", "page_size", n_kv, "D"),
            "dtype": "float32"}


# ---------------------------------------------------------------------------
# the contract table — one entry per pallas_call site in src/repro/kernels/
# ---------------------------------------------------------------------------
_DECODE_OUTPUTS = [
    {"shape": ("B", "n_kv", "S", "G"), "dtype": "float32"},        # m
    {"shape": ("B", "n_kv", "S", "G"), "dtype": "float32"},        # l
    {"shape": ("B", "n_kv", "S", "G", "D"), "dtype": "float32"},   # acc
]
_PREFILL_OUTPUTS = [
    {"shape": ("B", "n_kv", "NQ", "S", "R"), "dtype": "float32"},
    {"shape": ("B", "n_kv", "NQ", "S", "R"), "dtype": "float32"},
    {"shape": ("B", "n_kv", "NQ", "S", "R", "D"), "dtype": "float32"},
]
_TABLES3D = {"name": "tables3d", "shape": ("B", "NB", "ppb"),
             "dtype": "int32", "value_range": (0, "NPm1")}

CONTRACTS: Dict[str, Dict] = {
    # -- TPU decode: scalar-prefetch block tables, ppb pages per grid step
    "paged_attention_partials": {
        "backend": "tpu",
        "grid": ("B", "n_kv", "S", "bps"),
        "num_scalar_prefetch": 2,
        "operands": [
            dict(_TABLES3D),
            {"name": "lens", "shape": ("B",), "dtype": "int32"},
            {"name": "q", "shape": ("B", "n_kv", "G", "D"),
             "dtype": "float32"},
            dict(_kv_pool(), repeat="ppb"),
            dict(_kv_pool(), name="v_pages", repeat="ppb"),
        ],
        "outputs": _DECODE_OUTPUTS,
        "partial_group": "decode-partials",
        "samples": _decode_samples(),
    },
    # -- Triton decode: whole-array pools, in-kernel table gathers
    "paged_attention_partials_gpu": {
        "backend": "gpu",
        "grid": ("B", "n_kv", "S"),
        "num_scalar_prefetch": 0,
        "operands": [
            dict(_TABLES3D),
            {"name": "lens", "shape": ("B",), "dtype": "int32"},
            {"name": "q", "shape": ("B", "n_kv", "G", "D"),
             "dtype": "float32"},
            dict(_kv_pool()),
            dict(_kv_pool(), name="v_pages"),
        ],
        "outputs": _DECODE_OUTPUTS,
        "partial_group": "decode-partials",
        "samples": _decode_samples(),
    },
    # -- TPU chunked prefill: decode grid + q-block axis, R = q_block·G rows
    "paged_prefill_partials": {
        "backend": "tpu",
        "grid": ("B", "n_kv", "NQ", "S", "bps"),
        "num_scalar_prefetch": 3,
        "operands": [
            dict(_TABLES3D),
            {"name": "kv_lens", "shape": ("B",), "dtype": "int32"},
            {"name": "q_start", "shape": ("B",), "dtype": "int32"},
            {"name": "qb5", "shape": ("B", "n_kv", "NQ", "R", "D"),
             "dtype": "float32"},
            dict(_kv_pool(), repeat="ppb"),
            dict(_kv_pool(), name="v_pages", repeat="ppb"),
        ],
        "outputs": _PREFILL_OUTPUTS,
        "partial_group": "prefill-partials",
        "samples": _prefill_samples(),
    },
    # -- Triton chunked prefill: identical partial contract to the TPU one
    "paged_prefill_partials_gpu": {
        "backend": "gpu",
        "grid": ("B", "n_kv", "NQ", "S"),
        "num_scalar_prefetch": 0,
        "operands": [
            dict(_TABLES3D),
            {"name": "kv_lens", "shape": ("B",), "dtype": "int32"},
            {"name": "q_start", "shape": ("B",), "dtype": "int32"},
            {"name": "qb5", "shape": ("B", "n_kv", "NQ", "R", "D"),
             "dtype": "float32"},
            dict(_kv_pool()),
            dict(_kv_pool(), name="v_pages"),
        ],
        "outputs": _PREFILL_OUTPUTS,
        "partial_group": "prefill-partials",
        "samples": _prefill_samples(),
    },
    # -- fused split-K combine: ingests exactly the decode partial contract
    "combine_partials_pallas": {
        "backend": "tpu",
        "grid": ("B", "Hkv"),
        "num_scalar_prefetch": 0,
        "operands": [
            {"name": "m", "shape": ("B", "Hkv", "S", "G"),
             "dtype": "float32"},
            {"name": "l", "shape": ("B", "Hkv", "S", "G"),
             "dtype": "float32"},
            {"name": "acc", "shape": ("B", "Hkv", "S", "G", "D"),
             "dtype": "float32"},
        ],
        "outputs": [{"shape": ("B", "Hkv", "G", "D"), "dtype": "float32"}],
        "partial_group": None,
        "consumes": {"group": "decode-partials",
                     "operands": ("m", "l", "acc")},
        "samples": [
            {"B": 2, "Hkv": 2, "S": 2, "G": 4, "D": 8,
             "dtype": "float32", "_parity": True},
            {"B": 1, "Hkv": 1, "S": 1, "G": 8, "D": 8,
             "dtype": "float32"},
            {"B": 3, "Hkv": 2, "S": 4, "G": 2, "D": 16,
             "dtype": "float32"},
        ],
    },
    # -- flex prefill: BlockMask-driven KV tile skipping (aux-free samples;
    #    aux scalar-prefetch operands ride behind *pref and are opaque to
    #    the shape checker)
    "flex_attention_kernel": {
        "backend": "tpu",
        "grid": ("B", "H", "nq", "max_kv"),
        "num_scalar_prefetch": "n_prefetch",
        "operands": [
            {"name": "kv_num_blocks", "shape": "kv_num_blocks_shape",
             "dtype": "int32"},
            {"name": "kv_indices", "shape": "kv_indices_shape",
             "dtype": "int32", "value_range": (0, "KBm1")},
            {"name": "is_full", "shape": "is_full_shape", "dtype": "int32"},
            {"name": "q", "shape": ("B", "H", "Q", "D"),
             "dtype": "float32"},
            {"name": "k", "shape": ("B", "Hkv", "K", "D"),
             "dtype": "float32"},
            {"name": "v", "shape": ("B", "Hkv", "K", "D"),
             "dtype": "float32"},
        ],
        "outputs": [{"shape": ("B", "H", "Q", "D"), "dtype": "float32"}],
        "partial_group": None,
        "samples": [
            # unbatched block mask (kv_indices rank 2)
            {"B": 2, "H": 4, "Q": 16, "D": 8, "Hkv": 2, "K": 16, "G": 2,
             "q_blk": 8, "kv_blk": 8, "nq": 2, "max_kv": 2,
             "n_prefetch": 3, "KBm1": 1,
             "kv_num_blocks_shape": (2,), "kv_indices_shape": (2, 2),
             "is_full_shape": (2, 2), "_parity": False},
            # batched block mask (kv_indices rank 3)
            {"B": 2, "H": 8, "Q": 32, "D": 8, "Hkv": 4, "K": 32, "G": 2,
             "q_blk": 8, "kv_blk": 16, "nq": 4, "max_kv": 2,
             "n_prefetch": 3, "KBm1": 1,
             "kv_num_blocks_shape": (2, 4), "kv_indices_shape": (2, 4, 2),
             "is_full_shape": (2, 4, 2)},
        ],
    },
}

# partial families: members must agree under their parity samples, and a
# group may fold onto another (the prefill q-block axis folds into the
# batch axis before the shared combine — `combine_prefill_partials`).
PARTIAL_GROUPS: Dict[str, Dict] = {
    "decode-partials": {},
    "prefill-partials": {"folds_into": "decode-partials", "fold_axis": 2},
}
