"""Pure-jnp oracle for the paged decode-attention kernel.

Implements Alg.1 GATHER + standard masked attention: materialise each
sequence's K/V from its pages, then softmax(q·Kᵀ)·V.  This is the
"numerical equivalence" baseline the paper validates against (§IV-B3).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def ring_slot_positions(lens: jax.Array, page_size: int, ring: int,
                        n_slots: int) -> jax.Array:
    """Logical position held by each ring slot for a sliding-window cache.

    Slot s = (page j, offset o) holds the *latest* position p with
    (p // page_size) % ring == j and p % page_size == o and p < len.
    Returns (B, n_slots) positions (may exceed len-1 → dead, mask upstream).
    """
    s = jnp.arange(n_slots)
    j = s // page_size  # ring page index
    o = s % page_size
    L = lens[:, None]
    # latest page index l with l % ring == j and l*ps + o < L
    cur_page = jnp.maximum(L - 1, 0) // page_size
    # candidate page: largest l <= cur_page with l ≡ j (mod ring)
    l = cur_page - ((cur_page - j) % ring)
    pos = l * page_size + o
    # if that position is >= L, the slot's live token is one ring earlier
    pos = jnp.where(pos >= L, pos - ring * page_size, pos)
    return pos  # negative ⇒ slot never written


def paged_attention_ref(
    q: jax.Array,  # (B, n_heads, head_dim) — one query token per sequence
    k_pages: jax.Array,  # (num_pages, page_size, n_kv_heads, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32, NULL = -1
    lens: jax.Array,  # (B,) int32 — cached tokens incl. the current one
    *,
    scale: Optional[float] = None,
    window: int = 0,  # >0 ⇒ sliding-window over a ring of pages
    softcap: float = 0.0,
    kv_scale: float = 0.0,  # >0: int8 pools, dequantize gathered slices
) -> jax.Array:
    B, n_heads, head_dim = q.shape
    num_pages, page_size, n_kv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    S = max_pages * page_size
    scale = scale if scale is not None else 1.0 / np.sqrt(head_dim)

    safe = jnp.clip(block_tables, 0, num_pages - 1)
    # barrier: pin dtype converts to the gathered slice, not the pool
    # (see core/attention.py — CPU float-normalization artifact)
    k = jax.lax.optimization_barrier(k_pages[safe].reshape(B, S, n_kv, head_dim))
    v = jax.lax.optimization_barrier(v_pages[safe].reshape(B, S, n_kv, head_dim))
    if kv_scale > 0:
        k = (k.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v = (v.astype(jnp.float32) * kv_scale).astype(q.dtype)

    if window > 0:
        ring = -(-window // page_size) + 1
        pos = ring_slot_positions(lens, page_size, ring, S)  # (B, S)
        live = (pos >= 0) & (pos < lens[:, None]) & (pos >= lens[:, None] - window)
    else:
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        live = pos < lens[:, None]
    live &= (block_tables >= 0)[:, :, None].repeat(page_size, 2).reshape(B, S)

    g = n_heads // n_kv
    qg = q.reshape(B, n_kv, g, head_dim) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(q.dtype))
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(live[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(q.dtype))
    return out.reshape(B, n_heads, head_dim)
