"""Pure-jnp oracle for the paged decode-attention kernel.

Implements Alg.1 GATHER + standard masked attention: materialise each
sequence's K/V from its pages, then softmax(q·Kᵀ)·V.  This is the
"numerical equivalence" baseline the paper validates against (§IV-B3).

Also provides the split-K oracle pair used to validate the flash-decoding
path of the blocked kernel: ``paged_attention_partials_ref`` computes the
per-partition un-normalised ``(m, l, acc)`` softmax partials over a
contiguous range of pages, and ``combine_partials_ref`` merges them with
the numerically-stable correction — the reference for the kernel-side
combine in ``paged_attention.combine_partials``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def ring_slot_positions(lens: jax.Array, page_size: int, ring: int,
                        n_slots: int) -> jax.Array:
    """Logical position held by each ring slot for a sliding-window cache.

    Slot s = (page j, offset o) holds the *latest* position p with
    (p // page_size) % ring == j and p % page_size == o and p < len.
    Returns (B, n_slots) positions (may exceed len-1 → dead, mask upstream).
    """
    s = jnp.arange(n_slots)
    j = s // page_size  # ring page index
    o = s % page_size
    L = lens[:, None]
    # latest page index l with l % ring == j and l*ps + o < L
    cur_page = jnp.maximum(L - 1, 0) // page_size
    # candidate page: largest l <= cur_page with l ≡ j (mod ring)
    l = cur_page - ((cur_page - j) % ring)
    pos = l * page_size + o
    # if that position is >= L, the slot's live token is one ring earlier
    pos = jnp.where(pos >= L, pos - ring * page_size, pos)
    return pos  # negative ⇒ slot never written


def paged_attention_ref(
    q: jax.Array,  # (B, n_heads, head_dim) — one query token per sequence
    k_pages: jax.Array,  # (num_pages, page_size, n_kv_heads, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32, NULL = -1
    lens: jax.Array,  # (B,) int32 — cached tokens incl. the current one
    *,
    scale: Optional[float] = None,
    window: int = 0,  # >0 ⇒ sliding-window over a ring of pages
    softcap: float = 0.0,
    kv_scale: float = 0.0,  # >0: int8 pools, dequantize gathered slices
) -> jax.Array:
    B, n_heads, head_dim = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(head_dim)
    scores, live, v = _gathered_scores(
        q, k_pages, v_pages, block_tables, lens, scale=scale, window=window,
        softcap=softcap, kv_scale=kv_scale)
    scores = jnp.where(live[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(B, n_heads, head_dim).astype(q.dtype)


def _gathered_scores(q, k_pages, v_pages, block_tables, lens, *,
                     scale, window, softcap, kv_scale):
    """Shared prologue of the full oracle AND the split-K partials oracle
    (both must validate the same gather/mask/softcap semantics): gathered
    K/V, softcapped f32 scores, live mask.

    Returns (scores (B,Hkv,G,S) f32, live (B,S), v (B,S,Hkv,D)).
    """
    B, n_heads, head_dim = q.shape
    num_pages, page_size, n_kv, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    S = max_pages * page_size

    safe = jnp.clip(block_tables, 0, num_pages - 1)
    k = jax.lax.optimization_barrier(k_pages[safe].reshape(B, S, n_kv, head_dim))
    v = jax.lax.optimization_barrier(v_pages[safe].reshape(B, S, n_kv, head_dim))
    if kv_scale > 0:
        k = (k.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v = (v.astype(jnp.float32) * kv_scale).astype(q.dtype)

    if window > 0:
        ring = -(-window // page_size) + 1
        pos = ring_slot_positions(lens, page_size, ring, S)
        live = (pos >= 0) & (pos < lens[:, None]) & (pos >= lens[:, None] - window)
        # mixed dense/windowed tables are wider than the ring — slots past
        # it belong to the dense layers' pages, never this layer's ring
        # (the Pallas kernels mask the same way: ``pg < ring``)
        live &= (jnp.arange(S) // page_size < ring)[None, :]
    else:
        pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        live = pos < lens[:, None]
    live &= (block_tables >= 0)[:, :, None].repeat(page_size, 2).reshape(B, S)

    g = n_heads // n_kv
    qg = q.reshape(B, n_kv, g, head_dim) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(q.dtype)
                        ).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    return scores, live, v


def paged_prefill_ref(
    q: jax.Array,  # (B, C, n_heads, head_dim) — one prompt *chunk* per seq
    k_pages: jax.Array,  # (num_pages, page_size, n_kv, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages) int32, NULL = -1
    kv_lens: jax.Array,  # (B,) — cached tokens incl. the current chunk
    q_start: jax.Array,  # (B,) — absolute position of chunk token 0
    *,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    kv_scale: float = 0.0,
) -> jax.Array:
    """Oracle for *chunked paged prefill*: a chunk of ``C`` query tokens
    attends causally over the sequence's paged KV cache.

    Contract (write-then-attend, mirroring the decode path): the chunk's
    K/V have already been scattered into the pages, so the cache holds
    ``kv_lens[b]`` tokens and query token ``i`` sits at absolute position
    ``q_start[b] + i``.  It attends over cached positions ``<= q_start+i``
    — the prefix written by earlier chunks *and* the causal part of its
    own chunk, all read back through the block table (Alg.1 GATHER).
    Rows past the live chunk (``q_start + i >= kv_lens``) are padding;
    their output is unspecified (finite, ignored by callers).

    ``q_start == 0`` and ``kv_lens == C`` is whole-prompt prefill;
    ``C == 1`` degenerates to `paged_attention_ref` at ``lens=kv_lens``.
    Sliding-window (ring-paged) layers are handled by the jnp fallback in
    `core.attention` — the ring overwrites make "read the chunk back from
    pages" ill-defined there.
    """
    B, C, n_heads, head_dim = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(head_dim)
    num_pages, page_size, n_kv, _ = k_pages.shape
    S = block_tables.shape[1] * page_size
    g = n_heads // n_kv

    safe = jnp.clip(block_tables, 0, num_pages - 1)
    k = jax.lax.optimization_barrier(k_pages[safe].reshape(B, S, n_kv, head_dim))
    v = jax.lax.optimization_barrier(v_pages[safe].reshape(B, S, n_kv, head_dim))
    if kv_scale > 0:
        k = (k.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v = (v.astype(jnp.float32) * kv_scale).astype(q.dtype)

    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    live_kv = pos < kv_lens[:, None]
    live_kv &= (block_tables >= 0)[:, :, None].repeat(page_size, 2).reshape(B, S)
    qpos = q_start[:, None] + jnp.arange(C)[None, :]  # (B, C)
    causal = pos[:, None, :] <= qpos[:, :, None]  # (B, C, S)
    live = live_kv[:, None, :] & causal

    qg = q.reshape(B, C, n_kv, g, head_dim) * scale
    scores = jnp.einsum("bckgd,bskd->bkgcs", qg, k.astype(q.dtype)
                        ).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    scores = jnp.where(live[:, None, None, :, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w)  # fully-masked rows (padding)
    out = jnp.einsum("bkgcs,bskd->bckgd", w, v.astype(jnp.float32))
    return out.reshape(B, C, n_heads, head_dim).astype(q.dtype)


def paged_prefill_partials_ref(
    q: jax.Array,  # (B, C, n_heads, head_dim)
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    kv_lens: jax.Array,  # (B,)
    q_start: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    kv_scale: float = 0.0,
    num_splits: int = 1,
    pages_per_block: int = 1,
    q_block: int = 1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-K oracle for the chunked-prefill kernels: per-(q-block, split)
    un-normalised ``(m, l, acc)`` partials over the same KV-block ranges
    `decode_partition` assigns — the identical partial contract the decode
    kernels emit, with the GQA row axis widened to ``q_block·G`` rows
    (row ``r`` = chunk token ``r // G``, head group ``r % G``).

    Returns (m, l, acc) shaped ((B,Hkv,NQ,S,R), (B,Hkv,NQ,S,R),
    (B,Hkv,NQ,S,R,D)) with ``NQ = ceil(C / q_block)``, ``R = q_block·G``
    — f32, directly mergeable by ``combine_partials`` over axis S after
    folding NQ into the batch axis.
    """
    NEG_INF = -1e30
    B, C, n_heads, head_dim = q.shape
    n_kv = k_pages.shape[2]
    page_size = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    S_tok = max_pages * page_size
    scale = float(scale if scale is not None else 1.0 / np.sqrt(head_dim))
    g = n_heads // n_kv

    from repro.kernels.paged_attention.paged_attention import decode_partition
    ppb, _, ns, bps = decode_partition(max_pages, pages_per_block, num_splits)
    chunk = bps * ppb * page_size
    qb = max(1, min(int(q_block), C))
    nq = -(-C // qb)
    Cp = nq * qb

    qpad = jnp.pad(q, ((0, 0), (0, Cp - C), (0, 0), (0, 0)))
    qg = qpad.reshape(B, nq, qb, n_kv, g, head_dim) * scale
    safe = jnp.clip(block_tables, 0, k_pages.shape[0] - 1)
    k = k_pages[safe].reshape(B, S_tok, n_kv, head_dim)
    v = v_pages[safe].reshape(B, S_tok, n_kv, head_dim)
    if kv_scale > 0:
        k = (k.astype(jnp.float32) * kv_scale).astype(q.dtype)
        v = (v.astype(jnp.float32) * kv_scale).astype(q.dtype)
    pos = jnp.broadcast_to(jnp.arange(S_tok)[None, :], (B, S_tok))
    live_kv = pos < kv_lens[:, None]
    live_kv &= (block_tables >= 0)[:, :, None].repeat(page_size, 2
                                                      ).reshape(B, S_tok)
    qpos = q_start[:, None] + jnp.arange(Cp)[None, :]  # (B, Cp)

    # (B, n_kv, nq, qb, g, S) scores, rows r = t·G + g as the kernels emit
    scores = jnp.einsum("bntkgd,bskd->bkntgs", qg, k.astype(q.dtype)
                        ).astype(jnp.float32)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    live = (live_kv[:, None, :] & (pos[:, None, :] <= qpos[:, :, None])
            ).reshape(B, nq, qb, S_tok)  # (B, nq, qb, S)
    live = live[:, None, :, :, None, :]  # (B, 1, nq, qb, 1, S)

    ms, ls, accs = [], [], []
    for s in range(ns):
        lo, hi = s * chunk, min((s + 1) * chunk, S_tok)
        if lo >= hi:
            shape = (B, n_kv, nq, qb * g)
            ms.append(jnp.full(shape, NEG_INF, jnp.float32))
            ls.append(jnp.zeros(shape, jnp.float32))
            accs.append(jnp.zeros(shape + (head_dim,), jnp.float32))
            continue
        sl = jnp.where(live[..., lo:hi], scores[..., lo:hi], NEG_INF)
        m = jnp.max(sl, axis=-1)
        m = jnp.where(m > NEG_INF / 2, m, NEG_INF)
        p = jnp.where(live[..., lo:hi], jnp.exp(sl - m[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bkntgs,bskd->bkntgd", p,
                         v[:, lo:hi].astype(jnp.float32))
        ms.append(m.reshape(B, n_kv, nq, qb * g))
        ls.append(l.reshape(B, n_kv, nq, qb * g))
        accs.append(acc.reshape(B, n_kv, nq, qb * g, head_dim))
    m = jnp.stack(ms, axis=3)  # (B, Hkv, NQ, S, R)
    l = jnp.stack(ls, axis=3)
    acc = jnp.stack(accs, axis=3)  # (B, Hkv, NQ, S, R, D)
    return m, l, acc


def paged_attention_partials_ref(
    q: jax.Array,  # (B, n_heads, head_dim)
    k_pages: jax.Array,  # (num_pages, page_size, n_kv, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    kv_scale: float = 0.0,
    num_splits: int = 1,
    pages_per_block: int = 1,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-K oracle: per-partition un-normalised softmax partials.

    The page list is cut at KV-*block* granularity into ``num_splits``
    contiguous ranges — the identical partitioning the kernel's split-K
    grid axis uses (blocks of ``pages_per_block`` pages, then
    ``ceil(n_blocks / num_splits)`` blocks per split), so per-split
    partials are directly comparable.  The last partition may be ragged
    and a wholly-dead partition yields (NEG_INF, 0, 0), which drops out
    of the combine exactly.

    Returns (m, l, acc) with GQA-grouped shapes
    ((B,Hkv,S,G), (B,Hkv,S,G), (B,Hkv,S,G,D)) — f32.
    """
    NEG_INF = -1e30
    B, n_heads, head_dim = q.shape
    n_kv = k_pages.shape[2]
    page_size = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    S_tok = max_pages * page_size
    scale = float(scale if scale is not None else 1.0 / np.sqrt(head_dim))

    scores, live, v = _gathered_scores(
        q, k_pages, v_pages, block_tables, lens, scale=scale, window=window,
        softcap=softcap, kv_scale=kv_scale)

    from repro.kernels.paged_attention.paged_attention import decode_partition
    ppb, _, ns, bps = decode_partition(max_pages, pages_per_block, num_splits)
    chunk = bps * ppb * page_size

    g = n_heads // n_kv
    ms, ls, accs = [], [], []
    for s in range(ns):
        lo, hi = s * chunk, min((s + 1) * chunk, S_tok)
        if lo >= hi:  # split made of padding blocks only — dead partition
            ms.append(jnp.full((B, n_kv, g), NEG_INF, jnp.float32))
            ls.append(jnp.zeros((B, n_kv, g), jnp.float32))
            accs.append(jnp.zeros((B, n_kv, g, head_dim), jnp.float32))
            continue
        sl = scores[..., lo:hi]
        lv = live[:, None, None, lo:hi]
        sl = jnp.where(lv, sl, NEG_INF)
        m = jnp.max(sl, axis=-1)
        m = jnp.where(m > NEG_INF / 2, m, NEG_INF)  # wholly-dead partition
        p = jnp.where(lv, jnp.exp(sl - m[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        acc = jnp.einsum("bkgs,bskd->bkgd", p,
                         v[:, lo:hi].astype(jnp.float32))
        ms.append(m)
        ls.append(l)
        accs.append(acc)
    m = jnp.stack(ms, axis=2)  # (B, Hkv, ns, G)
    l = jnp.stack(ls, axis=2)
    acc = jnp.stack(accs, axis=2)  # (B, Hkv, ns, G, D)
    return m, l, acc


def combine_partials_ref(m: jax.Array, l: jax.Array, acc: jax.Array
                         ) -> jax.Array:
    """Reference flash-decoding combine over the split axis (axis=2).

    m, l: (B, Hkv, S, G); acc: (B, Hkv, S, G, D).  Returns (B, H, D) f32.
    """
    m_g = jnp.max(m, axis=2, keepdims=True)
    corr = jnp.exp(m - m_g)
    l_g = jnp.sum(l * corr, axis=2)
    o = jnp.sum(acc * corr[..., None], axis=2)
    o = o / jnp.maximum(l_g, 1e-30)[..., None]
    B, n_kv, g, D = o.shape
    return o.reshape(B, n_kv * g, D)
