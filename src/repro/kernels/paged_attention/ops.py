"""Public op: paged decode attention (kernel or oracle, GQA-aware,
multi-backend).

`paged_attention(...)` is the drop-in attention-over-pages op the rest of
the framework calls.  ``impl="pallas"`` runs the blocked/split-K Pallas
kernel; ``impl="ref"`` runs the pure-jnp oracle (also the dry-run
lowering path — see DESIGN.md §7).

Backends (``backend`` knob; ``None`` → auto from ``jax.default_backend()``,
CPU hosts fall back to the TPU lowering in interpret mode):

  * ``"tpu"`` — `paged_attention.py`: the page→HBM translation happens in
    scalar-prefetched BlockSpec index_maps so Mosaic's pipeline streams
    scattered pages HBM→VMEM, double-buffered; megacore
    ``dimension_semantics`` parallelise (batch, kv_head, split).
  * ``"gpu"`` — `paged_attention_gpu.py`: the Triton lowering
    (``plgpu.TritonCompilerParams``) gathers pages *inside* the kernel
    with block-table indexed ``tl.load``s, one CTA per (batch, kv_head,
    split) grid slot.

Both lowerings share `decode_partition` (bit-identical split ranges),
emit the same ``(m, l, acc)`` partial contract, and merge through the
same `combine_partials` — so `ref.paged_attention_partials_ref` /
`ref.combine_partials_ref` and the conformance suite gate the two
backends identically (interpret mode off-target, compiled on real
hardware; ``interpret=None`` auto-resolves per backend).

``pages_per_block`` / ``num_splits`` control the kernel's KV-block width
and flash-decoding split-K factor; ``combine_mode`` picks the split-K
merge implementation ("pallas" = fused on-chip combine kernel, "jnp" =
XLA epilogue).  ``None`` invokes `choose_decode_params`, the auto-tuning
heuristic keyed on ``(max_pages · page_size, page_size, head_dim)`` and
the backend (MXU-width block targets on TPU, warp-width on GPU), which
also resolves the combine mode (fused kernel whenever split-K is active).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import resolve_backend
from repro.kernels.paged_attention.paged_attention import (
    decode_partition, paged_attention_kernel, paged_prefill_kernel,
    resolve_combine_mode)
from repro.kernels.paged_attention.paged_attention_gpu import (
    paged_attention_kernel_gpu, paged_prefill_kernel_gpu)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_prefill_ref)

# KV tokens per grid step the MXU digests at full width (TPU lowering).
_TARGET_BLOCK_TOKENS = 128
# Per-step K+V VMEM budget (bytes, f32-equivalent) — bounds pages_per_block
# for large head_dim so the double-buffered working set stays comfortable.
_KV_VMEM_BUDGET = 1 << 20
# Flash-decoding split sizing: keep >= this many blocks per split so the
# combine overhead stays negligible, and never exceed _MAX_SPLITS slots.
_MIN_BLOCKS_PER_SPLIT = 4
_MAX_SPLITS = 8

# GPU lowering targets warp-width tiles, not MXU width: a (G, 64) score
# tile keeps two warps of lanes busy per tl.dot step without blowing the
# per-CTA register/SMEM budget the gathered K+V block occupies.
_TARGET_BLOCK_TOKENS_GPU = 64
# K+V bytes per in-flight block (f32-equivalent) — sized to stay well
# inside one SM's shared-memory/register file with double-buffered stages.
_KV_SMEM_BUDGET = 1 << 16
# Split-K is cheaper on GPU (SMs >> TPU cores, combine is one tiny kernel)
# so split earlier and wider: occupancy beats per-split combine overhead.
_MIN_BLOCKS_PER_SPLIT_GPU = 2
_MAX_SPLITS_GPU = 16


def choose_decode_params(
    max_pages: int,
    page_size: int,
    head_dim: int,
    pages_per_block: Optional[int] = None,
    num_splits: Optional[int] = None,
    combine_mode: Optional[str] = None,
    backend: Optional[str] = None,
) -> Tuple[int, int, str]:
    """Auto-tune (pages_per_block, num_splits, combine_mode) per backend.

    Heuristic, keyed on the sequence capacity ``max_pages · page_size``,
    the page size, the head dim, and the target backend:

      * block width targets ``_TARGET_BLOCK_TOKENS`` KV tokens per grid
        step on TPU (MXU-aligned for page sizes ≤ 128) and the smaller
        warp-width ``_TARGET_BLOCK_TOKENS_GPU`` on GPU, capped so the
        K+V block working set stays under the backend's per-step budget
        (VMEM on TPU, SMEM/registers on GPU);
      * split-K grows with the block count (longer sequences → more
        parallel grid slots) but keeps ≥ the backend's minimum blocks
        per split and ≤ its split cap — GPU splits earlier and wider
        (SM occupancy is the scarce resource), short sequences decode
        in a single split with zero combine overhead;
      * the combine runs as the fused Pallas kernel whenever split-K is
        active (> 1 split after clamping) and as the trivial jnp epilogue
        otherwise — a single-split "combine" is just a normalise.  On the
        GPU backend the auto mode resolves to "jnp" even under split-K:
        the fused combine is a TPU lowering, so on a real GPU it would
        fall back to the Pallas *interpreter* on the hot decode path —
        the XLA epilogue is strictly better there (a Triton combine is a
        ROADMAP item).  An explicit ``combine_mode="pallas"`` still
        passes through (that is what the CPU conformance suite runs).

    Explicit values pass through (clamped / validated).
    """
    gpu = resolve_backend(backend) == "gpu"
    target_tokens = _TARGET_BLOCK_TOKENS_GPU if gpu else _TARGET_BLOCK_TOKENS
    kv_budget = _KV_SMEM_BUDGET if gpu else _KV_VMEM_BUDGET
    min_bps = _MIN_BLOCKS_PER_SPLIT_GPU if gpu else _MIN_BLOCKS_PER_SPLIT
    max_splits = _MAX_SPLITS_GPU if gpu else _MAX_SPLITS
    if pages_per_block is None:
        target = max(1, target_tokens // max(1, int(page_size)))
        vmem_cap = max(1, kv_budget // (2 * 4 * int(page_size)
                                        * max(1, int(head_dim))))
        pages_per_block = min(target, vmem_cap)
    # first pass derives n_blocks to *choose* num_splits; the second call
    # below forwards the chosen value
    # replint: disable=knob-threading -- two-phase knob derivation
    ppb, n_blocks, _, _ = decode_partition(max_pages, pages_per_block)
    if num_splits is None:
        num_splits = min(max(1, n_blocks // min_bps), max_splits)
    _, _, ns, _ = decode_partition(max_pages, ppb, num_splits)
    if gpu and combine_mode in (None, "auto"):
        return ppb, ns, "jnp"
    return ppb, ns, resolve_combine_mode(combine_mode, ns)


# Chunked-prefill Q-block sizing: target this many score-tile rows
# (q_block·G) per grid step — MXU-height on TPU; the GPU lowering reuses
# the same target (its CTA walks the KV blocks in-kernel either way).
_TARGET_Q_ROWS = 128


def choose_prefill_params(
    max_pages: int,
    page_size: int,
    head_dim: int,
    chunk: int,
    group: int,
    pages_per_block: Optional[int] = None,
    num_splits: Optional[int] = None,
    combine_mode: Optional[str] = None,
    q_block: Optional[int] = None,
    backend: Optional[str] = None,
) -> Tuple[int, int, str, int]:
    """Auto-tune ``(pages_per_block, num_splits, combine_mode, q_block)``
    for the chunked-prefill kernels.

    KV-block width reuses the decode heuristic (`choose_decode_params`).
    Split-K defaults to **1**: the Q-block axis already multiplies the
    grid by ``ceil(chunk / q_block)``, so extra splits only pay combine
    overhead unless the caller asks for them (the conformance suite
    does).  ``q_block`` targets ``_TARGET_Q_ROWS`` score-tile rows and is
    clamped to the chunk.
    """
    ppb, ns, cm = choose_decode_params(
        max_pages, page_size, head_dim, pages_per_block,
        1 if num_splits is None else num_splits, combine_mode,
        backend=backend)
    if q_block is None:
        q_block = max(1, _TARGET_Q_ROWS // max(1, int(group)))
    q_block = max(1, min(int(q_block), int(chunk)))
    return ppb, ns, cm, q_block


@functools.partial(
    jax.jit,
    static_argnames=("scale", "softcap", "impl", "interpret", "kv_scale",
                     "pages_per_block", "num_splits", "combine_mode",
                     "backend", "q_block"),
)
def paged_prefill(
    q: jax.Array,  # (B, C, n_heads, head_dim) — one prompt chunk per seq
    k_pages: jax.Array,  # (num_pages, page_size, n_kv_heads, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    kv_lens: jax.Array,  # (B,) cached tokens incl. the chunk
    q_start: jax.Array,  # (B,) absolute position of chunk token 0
    *,
    scale: Optional[float] = None,
    softcap: float = 0.0,
    impl: str = "pallas",
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,
    pages_per_block: Optional[int] = None,
    num_splits: Optional[int] = None,
    combine_mode: Optional[str] = None,
    backend: Optional[str] = None,  # "tpu" | "gpu" | None → auto
    q_block: Optional[int] = None,  # Q rows per grid step (None → auto)
) -> jax.Array:
    """Chunked paged prefill: ``C`` query tokens per sequence attend
    causally over the sequence's paged KV cache (prefix pages written by
    earlier chunks + the chunk's own causal part, all read through the
    block table).  The write-then-attend counterpart of
    `paged_attention`; see `ref.paged_prefill_ref` for the contract.
    """
    B, C, n_heads, head_dim = q.shape
    n_kv = k_pages.shape[2]
    page_size = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(head_dim))

    if impl == "ref":
        return paged_prefill_ref(
            q, k_pages, v_pages, block_tables, kv_lens, q_start,
            scale=scale, softcap=softcap, kv_scale=kv_scale)

    backend = resolve_backend(backend)
    ppb, ns, cm, qb = choose_prefill_params(
        max_pages, page_size, head_dim, C, n_heads // n_kv,
        pages_per_block, num_splits, combine_mode, q_block, backend=backend)
    kernel = (paged_prefill_kernel_gpu if backend == "gpu"
              else paged_prefill_kernel)
    return kernel(
        q, k_pages, v_pages, block_tables, kv_lens, q_start,
        scale=scale, softcap=softcap, interpret=interpret,
        kv_scale=kv_scale, pages_per_block=ppb, num_splits=ns,
        q_block=qb, combine_mode=cm)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "softcap", "impl", "interpret",
                     "kv_scale", "pages_per_block", "num_splits",
                     "combine_mode", "backend"),
)
def paged_attention(
    q: jax.Array,  # (B, n_heads, head_dim)
    k_pages: jax.Array,  # (num_pages, page_size, n_kv_heads, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    impl: str = "pallas",
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,  # >0: int8 pools, dequantized on the fly
    pages_per_block: Optional[int] = None,  # None → auto-tuned
    num_splits: Optional[int] = None,  # None → auto-tuned
    combine_mode: Optional[str] = None,  # None → auto ("pallas" iff split-K)
    backend: Optional[str] = None,  # "tpu" | "gpu" | None → auto
) -> jax.Array:
    """Attention of one query token per sequence over its paged KV cache."""
    B, n_heads, head_dim = q.shape
    n_kv = k_pages.shape[2]
    page_size = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(head_dim))

    if impl == "ref":
        return paged_attention_ref(
            q, k_pages, v_pages, block_tables, lens,
            scale=scale, window=window, softcap=softcap, kv_scale=kv_scale)

    backend = resolve_backend(backend)
    ppb, ns, cm = choose_decode_params(max_pages, page_size, head_dim,
                                       pages_per_block, num_splits,
                                       combine_mode, backend=backend)
    G = n_heads // n_kv
    qg = q.reshape(B, n_kv, G, head_dim)
    kernel = (paged_attention_kernel_gpu if backend == "gpu"
              else paged_attention_kernel)
    # interpret stays unresolved here: each pallas_call resolves it against
    # its own lowering (the GPU decode kernel interprets iff off-GPU while
    # the shared combine kernel interprets iff off-TPU — on a real GPU the
    # decode compiles through Triton and the combine falls back to the
    # interpreter / jnp epilogue).
    out = kernel(
        qg, k_pages, v_pages, block_tables, lens,
        scale=scale, window=window, softcap=softcap,
        interpret=interpret, kv_scale=kv_scale,
        pages_per_block=ppb, num_splits=ns, combine_mode=cm)
    return out.reshape(B, n_heads, head_dim)
