"""Public op: paged decode attention (kernel or oracle, GQA-aware).

`paged_attention(...)` is the drop-in attention-over-pages op the rest of
the framework calls.  ``impl="pallas"`` runs the blocked/split-K Pallas
kernel (interpret-mode off-TPU, compiled on real TPU — ``interpret=None``
auto-resolves); ``impl="ref"`` runs the pure-jnp oracle (also the dry-run
lowering path — see DESIGN.md §7).

``pages_per_block`` / ``num_splits`` control the kernel's KV-block width
and flash-decoding split-K factor; ``combine_mode`` picks the split-K
merge implementation ("pallas" = fused on-chip combine kernel, "jnp" =
XLA epilogue).  ``None`` invokes `choose_decode_params`, the auto-tuning
heuristic keyed on ``(max_pages · page_size, page_size, head_dim)``,
which also resolves the combine mode (fused kernel whenever split-K is
active).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import resolve_interpret
from repro.kernels.paged_attention.paged_attention import (
    decode_partition, paged_attention_kernel, resolve_combine_mode)
from repro.kernels.paged_attention.ref import paged_attention_ref

# KV tokens per grid step the MXU digests at full width.
_TARGET_BLOCK_TOKENS = 128
# Per-step K+V VMEM budget (bytes, f32-equivalent) — bounds pages_per_block
# for large head_dim so the double-buffered working set stays comfortable.
_KV_VMEM_BUDGET = 1 << 20
# Flash-decoding split sizing: keep >= this many blocks per split so the
# combine overhead stays negligible, and never exceed _MAX_SPLITS slots.
_MIN_BLOCKS_PER_SPLIT = 4
_MAX_SPLITS = 8


def choose_decode_params(
    max_pages: int,
    page_size: int,
    head_dim: int,
    pages_per_block: Optional[int] = None,
    num_splits: Optional[int] = None,
    combine_mode: Optional[str] = None,
) -> Tuple[int, int, str]:
    """Auto-tune (pages_per_block, num_splits, combine_mode).

    Heuristic, keyed on the sequence capacity ``max_pages · page_size``,
    the page size, and the head dim:

      * block width targets ``_TARGET_BLOCK_TOKENS`` KV tokens per grid
        step (MXU-aligned for page sizes ≤ 128), capped so the K+V block
        working set stays under ``_KV_VMEM_BUDGET`` bytes;
      * split-K grows with the block count (longer sequences → more
        parallel grid slots) but keeps ≥ ``_MIN_BLOCKS_PER_SPLIT`` blocks
        per split and ≤ ``_MAX_SPLITS`` splits — short sequences decode
        in a single split with zero combine overhead;
      * the combine runs as the fused Pallas kernel whenever split-K is
        active (> 1 split after clamping) and as the trivial jnp epilogue
        otherwise — a single-split "combine" is just a normalise.

    Explicit values pass through (clamped / validated).
    """
    if pages_per_block is None:
        target = max(1, _TARGET_BLOCK_TOKENS // max(1, int(page_size)))
        vmem_cap = max(1, _KV_VMEM_BUDGET // (2 * 4 * int(page_size)
                                              * max(1, int(head_dim))))
        pages_per_block = min(target, vmem_cap)
    ppb, n_blocks, _, _ = decode_partition(max_pages, pages_per_block)
    if num_splits is None:
        num_splits = min(max(1, n_blocks // _MIN_BLOCKS_PER_SPLIT),
                         _MAX_SPLITS)
    _, _, ns, _ = decode_partition(max_pages, ppb, num_splits)
    return ppb, ns, resolve_combine_mode(combine_mode, ns)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "softcap", "impl", "interpret",
                     "kv_scale", "pages_per_block", "num_splits",
                     "combine_mode"),
)
def paged_attention(
    q: jax.Array,  # (B, n_heads, head_dim)
    k_pages: jax.Array,  # (num_pages, page_size, n_kv_heads, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    impl: str = "pallas",
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,  # >0: int8 pools, dequantized on the fly
    pages_per_block: Optional[int] = None,  # None → auto-tuned
    num_splits: Optional[int] = None,  # None → auto-tuned
    combine_mode: Optional[str] = None,  # None → auto ("pallas" iff split-K)
) -> jax.Array:
    """Attention of one query token per sequence over its paged KV cache."""
    B, n_heads, head_dim = q.shape
    n_kv = k_pages.shape[2]
    page_size = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(head_dim))

    if impl == "ref":
        return paged_attention_ref(
            q, k_pages, v_pages, block_tables, lens,
            scale=scale, window=window, softcap=softcap, kv_scale=kv_scale)

    ppb, ns, cm = choose_decode_params(max_pages, page_size, head_dim,
                                       pages_per_block, num_splits,
                                       combine_mode)
    G = n_heads // n_kv
    qg = q.reshape(B, n_kv, G, head_dim)
    out = paged_attention_kernel(
        qg, k_pages, v_pages, block_tables, lens,
        scale=scale, window=window, softcap=softcap,
        interpret=resolve_interpret(interpret), kv_scale=kv_scale,
        pages_per_block=ppb, num_splits=ns, combine_mode=cm)
    return out.reshape(B, n_heads, head_dim)
