"""Public op: paged decode attention (kernel or oracle, GQA-aware).

`paged_attention(...)` is the drop-in attention-over-pages op the rest of
the framework calls.  ``impl="pallas"`` runs the Pallas kernel
(interpret-mode on CPU, compiled on real TPU); ``impl="ref"`` runs the
pure-jnp oracle (also the dry-run lowering path — see DESIGN.md §7).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_attention.paged_attention import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


@functools.partial(
    jax.jit,
    static_argnames=("scale", "window", "softcap", "impl", "interpret",
                     "kv_scale"),
)
def paged_attention(
    q: jax.Array,  # (B, n_heads, head_dim)
    k_pages: jax.Array,  # (num_pages, page_size, n_kv_heads, head_dim)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, max_pages)
    lens: jax.Array,  # (B,)
    *,
    scale: Optional[float] = None,
    window: int = 0,
    softcap: float = 0.0,
    impl: str = "pallas",
    interpret: bool = True,
    kv_scale: float = 0.0,  # >0: int8 pools, dequantized on the fly
) -> jax.Array:
    """Attention of one query token per sequence over its paged KV cache."""
    B, n_heads, head_dim = q.shape
    n_kv = k_pages.shape[2]
    scale = float(scale if scale is not None else 1.0 / np.sqrt(head_dim))

    if impl == "ref":
        return paged_attention_ref(
            q, k_pages, v_pages, block_tables, lens,
            scale=scale, window=window, softcap=softcap, kv_scale=kv_scale)

    G = n_heads // n_kv
    qg = q.reshape(B, n_kv, G, head_dim)
    out = paged_attention_kernel(
        qg, k_pages, v_pages, block_tables, lens,
        scale=scale, window=window, softcap=softcap, interpret=interpret,
        kv_scale=kv_scale)
    return out.reshape(B, n_heads, head_dim)
