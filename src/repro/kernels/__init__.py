"""Pallas TPU kernels for the paper's compute hot-spots.

paged_attention/ — fused paged decode attention (the paper's core kernel)
flex_attention/  — flash-style prefill kernel with FlexAttention mask/score
                   mods and BlockMask-driven tile skipping
Each has ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax


@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    # Resolved once per process: Pallas kernels compile on real TPUs and
    # fall back to interpret mode everywhere else (CPU CI, GPU hosts).
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` → auto (interpret iff not running on TPU); bools pass through."""
    return _default_interpret() if interpret is None else bool(interpret)
