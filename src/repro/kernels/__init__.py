"""Pallas kernels for the paper's compute hot-spots, per backend.

paged_attention/ — fused paged decode attention (the paper's core kernel):
                   paged_attention.py is the TPU lowering (scalar-prefetch
                   block tables, Mosaic), paged_attention_gpu.py the
                   Triton/GPU lowering (in-kernel block-table gathers).
flex_attention/  — flash-style prefill kernel with FlexAttention mask/score
                   mods and BlockMask-driven tile skipping
Each has ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle).

Backend-selection contract: every kernel-facing op takes
``backend=None`` (auto: whatever ``jax.default_backend()`` reports,
falling back to the TPU lowering on CPU hosts) and ``interpret=None``
(auto: interpret mode unless the process runs on the backend the kernel
targets — so CPU CI exercises both lowerings through the Pallas
interpreter while real TPUs/GPUs compile).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.errors import EngineConfigError

BACKENDS = ("tpu", "gpu")


@functools.lru_cache(maxsize=None)
def _on_platform(platform: str) -> bool:
    # Resolved once per process: Pallas kernels compile on their target
    # platform and fall back to interpret mode everywhere else (CPU CI,
    # cross-platform hosts).
    return jax.default_backend() == platform


def resolve_backend(backend: Optional[str]) -> str:
    """``None``/"auto" → the running platform's kernel lowering.

    GPU hosts get the Triton lowering, everything else (TPU and the CPU
    interpret-mode CI) the TPU lowering; explicit names pass through
    (validated).
    """
    if backend is None or backend == "auto":
        return "gpu" if _on_platform("gpu") else "tpu"
    if backend not in BACKENDS:
        raise EngineConfigError(f"backend must be one of {BACKENDS} or "
                                f"None/'auto', got {backend!r}",
                                backend=backend)
    return backend


def resolve_interpret(interpret: Optional[bool],
                      backend: str = "tpu") -> bool:
    """``None`` → auto (interpret iff not running on ``backend``'s
    platform); bools pass through."""
    if interpret is not None:
        return bool(interpret)
    return not _on_platform(backend)
