"""Pallas TPU kernels for the paper's compute hot-spots.

paged_attention/ — fused paged decode attention (the paper's core kernel)
flex_attention/  — flash-style prefill kernel with FlexAttention mask/score
                   mods and BlockMask-driven tile skipping
Each has ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle).
"""
