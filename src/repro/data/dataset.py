"""LM data pipelines: synthetic token streams and file-backed text.

Both yield {"inputs": (B, S) int32, "targets": (B, S) int32} next-token
batches, deterministic under a seed, with optional modality-stub extras for
the vlm/encdec families (precomputed patch/frame embeddings — the allowed
carve-out).
"""

from __future__ import annotations

import itertools
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data.tokenizer import ByteTokenizer


def text_to_ids(path: str, tokenizer: Optional[ByteTokenizer] = None
                ) -> np.ndarray:
    tok = tokenizer or ByteTokenizer()
    with open(path, "r", errors="replace") as f:
        return np.asarray(tok.encode(f.read()), np.int32)


def _extras(cfg, B: int, rng: np.random.Generator) -> Dict:
    out = {}
    if cfg is None:
        return out
    if cfg.family == "vlm":
        out["image_embeds"] = rng.standard_normal(
            (B, cfg.n_image_tokens, cfg.d_vision), np.float32)
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (B, cfg.n_audio_frames, cfg.d_model), np.float32)
    return out


def synthetic_batches(batch: int, seq_len: int, vocab: int, seed: int = 0,
                      cfg=None) -> Iterator[Dict]:
    """Markov-ish synthetic stream: learnable structure (not uniform noise),
    so a few hundred steps visibly reduce loss."""
    rng = np.random.default_rng(seed)
    V = min(vocab, 256)
    # sparse bigram transition table: each token has 8 likely successors
    succ = rng.integers(0, V, size=(V, 8))
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=batch)
        noise = rng.random((batch, seq_len))
        pick = rng.integers(0, 8, size=(batch, seq_len))
        rand = rng.integers(0, V, size=(batch, seq_len))
        for t in range(seq_len):
            nxt = succ[toks[:, t], pick[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.9, nxt, rand[:, t])
        yield {"inputs": toks[:, :-1], "targets": toks[:, 1:],
               **_extras(cfg, batch, rng)}


def lm_batches(ids: np.ndarray, batch: int, seq_len: int, seed: int = 0,
               cfg=None) -> Iterator[Dict]:
    """Random-crop next-token batches from one long token array."""
    rng = np.random.default_rng(seed)
    n = len(ids) - seq_len - 1
    if n <= 0:
        reps = -(-(seq_len + 2) // max(len(ids), 1))
        ids = np.tile(ids, reps)
        n = len(ids) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        inp = np.stack([ids[s:s + seq_len] for s in starts])
        tgt = np.stack([ids[s + 1:s + seq_len + 1] for s in starts])
        yield {"inputs": inp.astype(np.int32), "targets": tgt.astype(np.int32),
               **_extras(cfg, batch, rng)}
