"""Byte-level tokenizer (no external vocab files — offline container).

IDs 0..255 are raw bytes; 256 = BOS, 257 = EOS, 258 = PAD. Vocab sizes in
model configs exceed 259, which is fine — unused ids just never occur.
"""

from __future__ import annotations

from typing import Iterable, List

import numpy as np


class ByteTokenizer:
    BOS = 256
    EOS = 257
    PAD = 258
    vocab_size = 259

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        bs = bytes(i for i in ids if i < 256)
        return bs.decode("utf-8", errors="replace")
