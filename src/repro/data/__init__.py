from repro.data.tokenizer import ByteTokenizer
from repro.data.dataset import lm_batches, synthetic_batches, text_to_ids
