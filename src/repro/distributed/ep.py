"""Expert-parallel MoE dispatch via shard_map + all_to_all.

The GSPMD-annotated dispatch in ``models/moe.py`` leaves the (E, C, d)
expert buffers with no batch-sharded dimension, so every data shard
redundantly computes ALL experts' tokens (the roofline useful_frac caught
the 16× compute waste), and annotating C with batch axes makes GSPMD lower
the dispatch gather as a one-hot matmul (measured: worse).  This module is
the explicit fix — the classic EP schedule, hillclimbed in EXPERIMENTS
§Perf H1:

  per data shard (local tokens T_l):
    route locally → capacity C_l = T_l·k/E·cf → dispatch buffer (E, C_l, d)
    all_to_all over "model": (E, C_l, d) → (E/m, m·C_l, d)
    local experts' FFN (E/m per shard)
    all_to_all back → local weighted combine

Compute per device: (E/m)·(m·C_l) = E·C_l rows — exactly the active-token
share, no replication.  Collectives: two all_to_alls of the dispatch
buffer (the pattern the paper's §V-D "cross-modality / MoE" outlook
anticipates).

Drop semantics: capacity is per data shard (standard EP), so dropped
tokens can differ from the global-capacity GSPMD path; with
capacity_factor=0 (dropless) both paths are exact and identical.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.collectives import shard_map
from repro.distributed.sharding import current_mesh, current_rules
from repro.models import moe as moe_mod


def _batch_axes(mesh) -> Tuple[str, ...]:
    phys = current_rules().physical("batch") or ()
    return tuple(a for a in phys if a in mesh.axis_names)


def ep_available(cfg: ModelConfig) -> bool:
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    return m > 1 and cfg.n_experts % m == 0


def apply_moe_ep(p: Dict, x: jax.Array, cfg: ModelConfig
                 ) -> Tuple[jax.Array, jax.Array]:
    """Drop-in replacement for ``moe.apply_moe`` under an active mesh."""
    mesh = current_mesh()
    ba = _batch_axes(mesh)
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    squeeze = x.ndim == 2
    if squeeze:
        x = x[:, None, :]

    def local(xb, router, wg, wu, wd):
        B_l, S, d = xb.shape
        E, k = cfg.n_experts, cfg.top_k
        T_l = B_l * S
        xf = xb.reshape(T_l, d)

        logits = xf @ router
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        gate_vals, idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E), axis=0)
        aux = E * jnp.sum(me * ce)
        if ba:
            aux = jax.lax.pmean(aux, ba)
        aux = jax.lax.pmean(aux, "model")  # replicated out_spec

        cf = cfg.moe_capacity or None
        C = T_l if cf is None else max(1, int(T_l * k / E * cf))
        assign = idx.reshape(-1)
        onehot = jax.nn.one_hot(assign, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
        slot = jnp.sum(pos_in_e * onehot, axis=-1)
        ok = slot < C
        token_of = jnp.arange(T_l).repeat(k)
        disp = jnp.full((E, C), T_l, jnp.int32)
        disp = disp.at[jnp.where(ok, assign, E),
                       jnp.where(ok, slot, 0)].set(token_of, mode="drop")
        # clamped gather: empty slots read an arbitrary row, masked at the
        # combine — avoids materialising a padded copy of xf per layer
        xe = xf[jnp.clip(disp, 0, T_l - 1)]  # (E, C_l, d) local dispatch

        # ---- EP exchange: experts to their owning model shard ------------
        # (E, C, d) --split E / concat C--> (E/m, m·C, d)
        xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                tiled=True)

        if cfg.activation == "silu":
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
                jnp.einsum("ecd,edf->ecf", xe, wu)
        else:
            h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, wg)) * \
                jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", h, wd)  # (E/m, m·C, d)

        # ---- return tokens to their data shard ---------------------------
        ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                tiled=True)

        # ---- local weighted combine --------------------------------------
        gates_flat = gate_vals.reshape(-1)
        out = jnp.zeros((T_l + 1, d), jnp.float32)
        src_e = jnp.where(ok, assign, E)
        src_c = jnp.where(ok, slot, 0)
        contrib = (ye[jnp.clip(src_e, 0, E - 1), src_c].astype(jnp.float32)
                   * gates_flat[:, None])
        contrib = jnp.where(ok[:, None], contrib, 0.0)
        out = out.at[jnp.where(ok, token_of, T_l)].add(contrib, mode="drop")
        return out[:T_l].reshape(B_l, S, d).astype(xb.dtype), aux

    ba_spec = tuple(ba) or None
    fn = shard_map(
        local, mesh,
        in_specs=(P(ba_spec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(ba_spec, None, None), P()),
        check_rep=False)
    out, aux = fn(x, p["router"], p["wg"], p["wu"], p["wd"])
    if squeeze:
        out = out[:, 0]
    return out, aux.astype(jnp.float32)
