"""Distributed paged-decode attention + cache writes (shard_map wrappers).

GSPMD cannot know that block-table gathers are shard-local, so the paged
pools + tables enter explicit ``shard_map`` regions here.  Three schemes
(DESIGN.md §4):

  * ``tp``  — vLLM-faithful tensor parallelism: batch over (pod, data),
    q *and* kv heads over "model" (requires n_kv_heads % model == 0);
    page pools private per data shard.
  * ``dp``  — for *windowed* (bounded-ring) layers: pool sharded over the
    batch axes only, kv replicated over "model", q-head-groups over
    "model".  The ring is small, so replication beats striping.
  * ``kvp`` — flash-decoding on the mesh (beyond-paper): the page dim is
    round-robin *striped* over every mesh axis not used for batch; each
    shard computes a partial online-softmax over its local pages and the
    partials merge with the numerically-stable (m, l, o) combine
    (`merge_flash_partials` — by default the same fused Pallas combine
    kernel the single-device split-K decode uses, with a pmax/psum
    fallback under ``combine_mode="jnp"``).  Works for any GQA layout and
    is what makes batch=1 × 524k-token decode shardable at all.

Table layout contract: tables are (B, n_kv_shards, pages_per_shard); under
``kvp`` local slot j of kv-shard s holds logical page j·n_kv_shards + s.
Under ``tp``/``dp``/local, n_kv_shards == 1 and slots are logical pages.

Outside a mesh context every wrapper is a plain local call (CPU engine).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
try:
    from jax import shard_map as _shard_map  # jax >= 0.8 (check_vma kwarg)

    def shard_map(f, mesh, in_specs, out_specs, check_rep=False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_rep)
except ImportError:  # pragma: no cover — older jax
    from jax.experimental.shard_map import shard_map

from repro.core import attention as core_attn
from repro.core import cache as kvcache
from repro.distributed.sharding import axis_size, current_mesh


def _flat_axis_index(axes: Tuple[str, ...]) -> jax.Array:
    idx = jnp.int32(0)
    for a in axes:
        # axis_size resolves statically from the mesh context (this jax
        # has no jax.lax.axis_size); axis_index is per-shard as usual
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def _mesh_prod(mesh, axes: Tuple[str, ...]) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes[a] for a in axes) if axes else 1


def merge_flash_partials(
    m: jax.Array,  # (B, H) f32 — per-shard running max (NEG_INF if dead)
    l: jax.Array,  # (B, H) f32 — per-shard softmax mass
    o: jax.Array,  # (B, H, D) f32 — per-shard un-normalised accumulator
    axes: Tuple[str, ...],
    *,
    combine_mode: Optional[str] = None,
    out_dtype=jnp.float32,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Merge per-shard flash-decoding partials over mesh ``axes``.

    Runs *inside* shard_map (the kvp decode path).  ``combine_mode``
    selects the reduction implementation:

      * ``"pallas"`` — all-gather the shard axis into a split axis and
        reduce it with the *same* fused combine kernel the single-device
        split-K pipeline uses (`combine_partials_pallas`, each head its
        own (b, h) grid slot) — one reduction implementation across the
        local and distributed paths;
      * ``"jnp"`` — the two-pass pmax/psum merge (no gather; partials
        stay shard-resident).

    ``None`` → auto: pallas when more than one shard participates.
    Returns (B, H, D) in ``out_dtype``.
    """
    from repro.kernels.paged_attention.paged_attention import (
        combine_partials_pallas, resolve_combine_mode)

    n_sh = math.prod(axis_size(a) for a in axes) if axes else 1
    mode = resolve_combine_mode(combine_mode, n_sh)
    if mode == "pallas":
        B, H = m.shape
        D = o.shape[-1]
        ms = jax.lax.all_gather(m, axes)  # (n_sh, B, H)
        ls = jax.lax.all_gather(l, axes)
        os_ = jax.lax.all_gather(o, axes)  # (n_sh, B, H, D)
        m4 = ms.transpose(1, 2, 0)[..., None]  # (B, H, S, 1) — G = 1
        l4 = ls.transpose(1, 2, 0)[..., None]
        acc5 = os_.transpose(1, 2, 0, 3)[:, :, :, None, :]  # (B, H, S, 1, D)
        out = combine_partials_pallas(m4, l4, acc5, dtype=out_dtype,
                                      interpret=interpret)
        return out.reshape(B, H, D)
    m_g = jax.lax.pmax(m, axes)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axes)
    o_g = jax.lax.psum(o * corr[..., None], axes)
    return (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(out_dtype)


def decode_attention_sharded(
    q4: jax.Array,  # (B, Hkv, G, hd) — q heads grouped by kv head
    k_pages: jax.Array,  # (num_pages, P, Hkv, hd)
    v_pages: jax.Array,
    tables: jax.Array,  # (B, n_kv_shards, pages_per_shard) int32
    lens: jax.Array,  # (B,)
    *,
    window: int = 0,
    softcap: float = 0.0,
    scheme: str = "local",  # local | tp | dp | kvp
    batch_axes: Tuple[str, ...] = (),
    impl: str = "ref",
    interpret: Optional[bool] = None,
    kv_scale: float = 0.0,  # >0: int8 pools with this dequant step
    pages_per_block: Optional[int] = None,  # Pallas KV-block width (None=auto)
    num_splits: Optional[int] = None,  # Pallas split-K factor (None=auto)
    combine_mode: Optional[str] = None,  # split-K merge impl (None=auto)
    backend: Optional[str] = None,  # kernel lowering: tpu | gpu (None=auto)
) -> jax.Array:
    """Returns (B, Hkv, G, hd)."""
    mesh = current_mesh()

    def _local(q4, k_pages, v_pages, tables, lens, kv_psum_axes=(),
               page_stride=1, page_offset=0):
        b, nk, g, d = q4.shape
        q = q4.reshape(b, nk * g, d)
        t = tables.reshape(b, -1)
        o = core_attn.decode_attention(
            q, k_pages, v_pages, t, lens, window=window, softcap=softcap,
            impl=impl, kv_psum_axes=kv_psum_axes, page_stride=page_stride,
            page_offset=page_offset, interpret=interpret, kv_scale=kv_scale,
            pages_per_block=pages_per_block, num_splits=num_splits,
            combine_mode=combine_mode, backend=backend)
        return o.reshape(b, nk, g, d)

    if mesh is None or scheme == "local":
        return _local(q4, k_pages, v_pages, tables, lens)

    ba = tuple(batch_axes) or None

    if scheme == "tp":
        in_specs = (P(ba, "model", None, None),
                    P(ba, None, "model", None), P(ba, None, "model", None),
                    P(ba, None, None), P(ba))
        fn = shard_map(_local, mesh=mesh, in_specs=in_specs,
                       out_specs=P(ba, "model", None, None), check_rep=False)
        return fn(q4, k_pages, v_pages, tables, lens)

    if scheme == "dp":
        # shard q-head groups over "model" when divisible; otherwise the
        # bounded-window attention is cheap enough to replicate (e.g.
        # nemotron-15b's G=6 on a 16-wide model axis)
        msize = _mesh_prod(mesh, ("model",)) if "model" in mesh.axis_names else 1
        g_ax = "model" if q4.shape[2] % max(msize, 1) == 0 else None
        in_specs = (P(ba, None, g_ax, None),
                    P(ba, None, None, None), P(ba, None, None, None),
                    P(ba, None, None), P(ba))
        fn = shard_map(_local, mesh=mesh, in_specs=in_specs,
                       out_specs=P(ba, None, g_ax, None), check_rep=False)
        return fn(q4, k_pages, v_pages, tables, lens)

    # ---- kvp ---------------------------------------------------------------
    kv_axes = tuple(a for a in mesh.axis_names if a not in (batch_axes or ()))
    n_kv = _mesh_prod(mesh, kv_axes)
    page_axes = tuple(batch_axes) + kv_axes

    def _kvp(q4, k_pages, v_pages, tables, lens):
        return _local(q4, k_pages, v_pages, tables, lens,
                      kv_psum_axes=kv_axes, page_stride=n_kv,
                      page_offset=_flat_axis_index(kv_axes))

    in_specs = (P(ba, None, None, None),
                P(page_axes, None, None, None), P(page_axes, None, None, None),
                P(ba, kv_axes, None), P(ba))
    fn = shard_map(_kvp, mesh=mesh, in_specs=in_specs,
                   out_specs=P(ba, None, None, None), check_rep=False)
    return fn(q4, k_pages, v_pages, tables, lens)


def write_prefill_sharded(
    k_pages_l: jax.Array,  # (num_pages, P, Hkv, hd)
    v_pages_l: jax.Array,
    tables: jax.Array,  # (B, max_pages) — pool-shard-local physical ids
    k: jax.Array,  # (B, S, Hkv, hd)
    v: jax.Array,
    lens: jax.Array,
    *,
    window: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter a prompt's K/V into the paged pools, shard-locally.

    Under GSPMD the pool scatter all-gathers every update row to every
    device (measured 8 GiB/device/layer on 32k prefill — the dominant
    prefill collective).  Here the pools are sharded (pages × batch-axes,
    head_dim × "model") and each shard scatters only its local rows: the
    only collective left is the reshard of k/v into that layout (an
    all-to-all of one KV slice).  Decode's kvp layout differs (pages
    striped over "model"); the prefill→decode pool reshard is the
    disaggregated-serving phase boundary (DESIGN.md §4).
    """
    mesh = current_mesh()
    if mesh is None:
        return kvcache.write_layer_prefill(k_pages_l, v_pages_l, tables,
                                           k, v, lens, window=window)
    from repro.distributed.sharding import current_rules
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ba = tuple(a for a in (current_rules().physical("batch") or ())
               if a in sizes and k.shape[0] % sizes[a] == 0)
    hd_ax = ("model" if "model" in sizes
             and k.shape[-1] % sizes["model"] == 0 else None)
    ba_s = ba or None

    def _local(kp, vp, tbl, k, v, lens):
        return kvcache.write_layer_prefill(kp, vp, tbl, k, v, lens,
                                           window=window)

    fn = shard_map(
        _local, mesh,
        in_specs=(P(ba_s, None, None, hd_ax), P(ba_s, None, None, hd_ax),
                  P(ba_s, None), P(ba_s, None, None, hd_ax),
                  P(ba_s, None, None, hd_ax), P(ba_s)),
        out_specs=(P(ba_s, None, None, hd_ax), P(ba_s, None, None, hd_ax)),
        check_rep=False)
    return fn(k_pages_l, v_pages_l, tables, k, v, lens)


def write_decode_sharded(
    k_pages: jax.Array,  # (num_pages, P, Hkv, hd)
    v_pages: jax.Array,
    tables: jax.Array,  # (B, n_kv_shards, pages_per_shard)
    positions: jax.Array,  # (B,) — 0-based position of the incoming token
    k_new: jax.Array,  # (B, Hkv, hd)
    v_new: jax.Array,
    *,
    window: int = 0,
    scheme: str = "local",
    batch_axes: Tuple[str, ...] = (),
) -> Tuple[jax.Array, jax.Array]:
    """Scatter one new token per sequence into the (sharded) pools."""
    mesh = current_mesh()
    page_size = k_pages.shape[1]

    def _scatter(kp, vp, phys, off, k, v):
        oob = jnp.where(phys < 0, kp.shape[0], phys)
        return (kp.at[oob, off].set(k, mode="drop"),
                vp.at[oob, off].set(v, mode="drop"))

    def _local(kp, vp, tbl, pos, k, v, stride=1, offset=0):
        logical = pos // page_size
        if window > 0:
            ring = -(-window // page_size) + 1
            logical = logical % ring
        if stride == 1:
            slot = logical
            mine = jnp.ones_like(pos, dtype=bool)
        else:
            slot = logical // stride
            mine = (logical % stride) == offset
        t = tbl.reshape(tbl.shape[0], -1)
        phys = jnp.where(mine, jnp.take_along_axis(
            t, slot[:, None], axis=1)[:, 0], -1)
        return _scatter(kp, vp, phys, pos % page_size, k, v)

    if mesh is None or scheme == "local":
        return _local(k_pages, v_pages, tables, positions, k_new, v_new)

    ba = tuple(batch_axes) or None

    if scheme == "tp":
        in_specs = (P(ba, None, "model", None), P(ba, None, "model", None),
                    P(ba, None, None), P(ba),
                    P(ba, "model", None), P(ba, "model", None))
        out_specs = (P(ba, None, "model", None), P(ba, None, "model", None))
        fn = shard_map(_local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        return fn(k_pages, v_pages, tables, positions, k_new, v_new)

    if scheme == "dp":
        in_specs = (P(ba, None, None, None), P(ba, None, None, None),
                    P(ba, None, None), P(ba),
                    P(ba, None, None), P(ba, None, None))
        out_specs = (P(ba, None, None, None), P(ba, None, None, None))
        fn = shard_map(_local, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        return fn(k_pages, v_pages, tables, positions, k_new, v_new)

    # kvp: only the owning stripe shard commits the write
    kv_axes = tuple(a for a in mesh.axis_names if a not in (batch_axes or ()))
    n_kv = _mesh_prod(mesh, kv_axes)
    page_axes = tuple(batch_axes) + kv_axes

    def _kvp(kp, vp, tbl, pos, k, v):
        return _local(kp, vp, tbl, pos, k, v, stride=n_kv,
                      offset=_flat_axis_index(kv_axes))

    in_specs = (P(page_axes, None, None, None), P(page_axes, None, None, None),
                P(ba, kv_axes, None), P(ba),
                P(ba, None, None), P(ba, None, None))
    out_specs = (P(page_axes, None, None, None),
                 P(page_axes, None, None, None))
    fn = shard_map(_kvp, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return fn(k_pages, v_pages, tables, positions, k_new, v_new)
