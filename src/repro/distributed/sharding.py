"""Logical-axis sharding rules (MaxText-style).

Model code annotates tensors with *logical* axis names ("batch", "heads",
"embed", ...).  A rule table maps each logical axis to zero or more physical
mesh axes.  Outside a mesh context every annotation is a no-op, so the same
model code runs on a laptop CPU and on a 512-chip dry-run unchanged.

Example
-------
    rules = AxisRules({"batch": ("pod", "data"), "heads": "model"})
    with use_mesh(mesh, rules):
        x = logical_shard(x, "batch", None, "embed")
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.errors import DistributedSetupError

PhysAxes = Union[None, str, Tuple[str, ...]]


def _norm(v: PhysAxes) -> Optional[Tuple[str, ...]]:
    if v is None:
        return None
    if isinstance(v, str):
        return (v,)
    return tuple(v)


@dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis name to physical mesh axes."""

    table: Mapping[str, PhysAxes] = field(default_factory=dict)

    def physical(self, logical: Optional[str]) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        return _norm(self.table.get(logical))

    def extend(self, **overrides: PhysAxes) -> "AxisRules":
        t = dict(self.table)
        t.update(overrides)
        return AxisRules(t)


# Rules for the production (pod, data, model) mesh.  Configs may override.
DEFAULT_RULES = AxisRules(
    {
        "batch": ("pod", "data"),
        "embed": None,        # overridden to ("data",) for FSDP on big models
        "heads": ("model",),
        "kv_heads": None,     # set to ("model",) when kv_heads % model == 0
        "mlp": ("model",),
        "experts": ("model",),
        # NOTE: the GSPMD-annotated MoE dispatch leaves the (E, C, d)
        # expert buffers with no batch-sharded dim — every data shard
        # redundantly computes all experts (useful_frac caught the 16x
        # waste). Annotating C with the batch axes makes GSPMD lower the
        # dispatch gather as a one-hot matmul (measured: 4x memory, 100x
        # FLOPs — worse). The real fix is the explicit shard_map EP path
        # (ep_moe in distributed/ep.py), hillclimbed in EXPERIMENTS §Perf.
        "vocab": ("model",),
        "kv_pages": ("pod", "data"),
        "seq": None,        # ("model",) under the sequence-parallel train plan
        "attn_seq": None,   # q/k/v seq dim; ("model",) under the ring plan
        "act_embed": None,  # activations' model dim (distinct from weight "embed")
        "layers": None,
        "state": ("model",),  # recurrent state heads (SSM/RG-LRU)
        "frames": None,
    }
)


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: AxisRules = DEFAULT_RULES


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[AxisRules] = None):
    """Activate a mesh + rule table for logical_shard annotations."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    if rules is not None:
        _CTX.rules = rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> AxisRules:
    return _CTX.rules


def _mesh_axis_sizes(mesh: Mesh) -> Mapping[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def axis_size(name: str) -> int:
    """Static size of a named mesh axis, usable inside shard_map bodies.

    ``jax.lax.axis_size`` only exists in newer jax; callers here need a
    *static* int anyway (ring permutation lists, mixed-radix index math),
    so resolve from the active mesh context first and fall back to the
    jax primitive when available.
    """
    mesh = current_mesh()
    if mesh is not None and name in mesh.axis_names:
        return _mesh_axis_sizes(mesh)[name]
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    raise DistributedSetupError(
        f"axis_size({name!r}): no active mesh defines it and this jax has "
        "no jax.lax.axis_size", axis=name)


def logical_spec(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[AxisRules] = None,
    mesh: Optional[Mesh] = None,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Resolve logical axes to a PartitionSpec.

    If ``shape`` is given, any mapping whose mesh-axis product does not divide
    the dimension is dropped (replicated) — this keeps small smoke configs
    valid under production rules.
    """
    rules = rules or current_rules()
    mesh = mesh or current_mesh()
    sizes = _mesh_axis_sizes(mesh) if mesh is not None else {}
    out = []
    used: set = set()
    for i, ax in enumerate(logical_axes):
        phys = rules.physical(ax)
        if phys is not None and mesh is not None:
            # drop mesh axes the current mesh doesn't have (e.g. "pod" on
            # the single-pod mesh) so one rule table serves every mesh
            phys = tuple(p for p in phys if p in sizes) or None
        if phys is not None and used.intersection(phys):
            # a mesh axis can shard at most one dim; first logical axis wins
            phys = None
        if phys is not None and shape is not None and mesh is not None:
            total = 1
            for p in phys:
                total *= sizes.get(p, 1)
            if shape[i] % total != 0:
                phys = None
        if phys is None:
            out.append(None)
        elif len(phys) == 1:
            used.update(phys)
            out.append(phys[0])
        else:
            used.update(phys)
            out.append(tuple(phys))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def logical_sharding(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[AxisRules] = None,
    mesh: Optional[Mesh] = None,
    shape: Optional[Sequence[int]] = None,
) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(logical_axes, rules, mesh, shape))


def logical_shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint; no-op outside a mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(logical_axes, current_rules(), mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_param_shardings(mesh: Mesh, rules: AxisRules, axes_tree, shapes_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings.

    ``shapes_tree`` (optional, of ShapeDtypeStruct/arrays) enables the
    divisibility fallback per leaf.
    """
    if shapes_tree is None:
        return jax.tree_util.tree_map(
            lambda axes: NamedSharding(mesh, logical_spec(axes, rules, mesh)),
            axes_tree,
            is_leaf=lambda x: isinstance(x, tuple),
        )
    return jax.tree_util.tree_map(
        lambda axes, s: NamedSharding(
            mesh, logical_spec(axes, rules, mesh, shape=s.shape)
        ),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
