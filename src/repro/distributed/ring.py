"""Ring attention (context parallelism) for prefill/training — §Perf H2.

Megatron-style sequence parallelism all-gathers the full hidden states
(B, S, d_model) over "model" before every attention layer; at 32k context
that is the dominant collective (measured ~18 GB/device/layer on
nemotron-15b prefill).  Ring attention keeps activations sequence-sharded
END TO END: each shard holds its sequence slice's q/k/v (full heads), and
the K/V slices rotate around the "model" axis via collective-permute while
an online-softmax accumulator folds in one chunk per step.  Per-layer
traffic becomes the K/V slice (GQA: kv_heads·head_dim ≪ d_model) times
(m−1) hops — ~8× less than the x all-gathers for GQA models, and each hop
overlaps with the previous chunk's compute on real hardware.

Weights are small relative to 32k-token activations, so the q/k/v
projections run with heads UNSHARDED under ring (GSPMD gathers the
~MB-scale weight shards instead of the GB-scale activations).

Masking uses absolute positions (q_offset / kv_offset per ring step), so
causal, sliding-window, and right-padded ``lens`` batches all work; fully
masked chunks still execute (static schedule) — the ≤2× causal FLOPs
overcount is shared with the chunked oracle and noted in §Roofline.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import shard_map
from repro.distributed.sharding import axis_size, current_mesh, current_rules


def _chunk_partial(q, k, v, q_off, k_off, *, scale, causal, window,
                   lens=None, kv_chunk=1024, softcap=0.0):
    """Online-softmax partials of q (B,Hkv,G,Sq,D) against one K/V chunk
    (B,Hkv,Sk,D) at absolute offsets. Returns (m, l, acc) f32."""
    B, Hkv, G, Sq, D = q.shape
    Sk = k.shape[2]
    kc = min(kv_chunk, Sk)
    nk = -(-Sk // kc)
    pad = nk * kc - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kt = k.reshape(B, Hkv, nk, kc, D).transpose(2, 0, 1, 3, 4)
    vt = v.reshape(B, Hkv, nk, kc, D).transpose(2, 0, 1, 3, 4)
    q_idx = (q_off + jnp.arange(Sq))[None, None, None, :, None]

    def body(carry, kv):
        m, l, acc = carry
        j, kb, vb = kv
        k_idx = (k_off + j * kc + jnp.arange(kc))[None, None, None, None, :]
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q, kb,
                       preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        live = k_idx < k_off + Sk  # chunk padding
        if causal:
            live &= k_idx <= q_idx
        if window > 0:
            live &= q_idx - k_idx < window
        if lens is not None:
            live &= k_idx < lens[:, None, None, None, None]
        s = jnp.where(live, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(live, jnp.exp(s - m_safe[..., None]), 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (jnp.full((B, Hkv, G, Sq), -jnp.inf),
            jnp.zeros((B, Hkv, G, Sq)),
            jnp.zeros((B, Hkv, G, Sq, D)))
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nk), kt, vt))
    return m, l, acc


def _ring_local(q, k, v, lens, *, axis, scale, causal, window, softcap):
    """Runs inside shard_map: q/k/v (B, S_l, H|Hkv, D) sequence-local."""
    m_sz = axis_size(axis)  # static (ring permutation list needs an int)
    r = jax.lax.axis_index(axis)
    B, S_l, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S_l, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    q_off = r * S_l

    def step(carry, i):
        (m, l, acc), (kc, vc) = carry
        # chunk currently held arrived from shard (r - i) mod m
        src = (r - i) % m_sz
        k_off = src * S_l
        mc, lc, accc = _chunk_partial(
            qg, kc.transpose(0, 2, 1, 3), vc.transpose(0, 2, 1, 3),
            q_off, k_off, scale=scale, causal=causal, window=window,
            lens=lens, softcap=softcap)
        # merge partials
        m_new = jnp.maximum(m, mc)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        a1 = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        a2 = jnp.where(jnp.isfinite(mc), jnp.exp(mc - m_safe), 0.0)
        l = l * a1 + lc * a2
        acc = acc * a1[..., None] + accc * a2[..., None]
        perm = [(j, (j + 1) % m_sz) for j in range(m_sz)]
        kc = jax.lax.ppermute(kc, axis, perm)
        vc = jax.lax.ppermute(vc, axis, perm)
        return ((m_new, l, acc), (kc, vc)), None

    init_part = (jnp.full((B, Hkv, G, S_l), -jnp.inf),
                 jnp.zeros((B, Hkv, G, S_l)),
                 jnp.zeros((B, Hkv, G, S_l, D)))
    ((m, l, acc), _), _ = jax.lax.scan(
        step, (init_part, (k, v)), jnp.arange(m_sz))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,Hkv,G,S_l,D) -> (B,S_l,H,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S_l, H, D).astype(q.dtype)


def ring_available(seq_len: int) -> bool:
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    m = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if m <= 1 or seq_len % m != 0:
        return False
    return current_rules().physical("seq") == ("model",)


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   lens: Optional[jax.Array] = None, causal: bool = True,
                   window: int = 0, softcap: float = 0.0) -> jax.Array:
    """q: (B,S,H,D), k/v: (B,S,Hkv,D) — global views (called inside jit).

    Requires an active mesh with the "seq"→("model",) rule (ring plan).
    """
    mesh = current_mesh()
    rules = current_rules()
    ba = tuple(a for a in (rules.physical("batch") or ())
               if a in mesh.axis_names and q.shape[0] % _sz(mesh, a) == 0)
    ba_spec = ba or None
    scale = 1.0 / np.sqrt(q.shape[-1])

    def local(q, k, v, lens):
        return _ring_local(q, k, v, lens, axis="model", scale=scale,
                           causal=causal, window=window, softcap=softcap)

    lens_in = lens if lens is not None else jnp.full(
        (q.shape[0],), q.shape[1], jnp.int32)
    fn = shard_map(local, mesh,
                   in_specs=(P(ba_spec, "model", None, None),
                             P(ba_spec, "model", None, None),
                             P(ba_spec, "model", None, None),
                             P(ba_spec)),
                   out_specs=P(ba_spec, "model", None, None),
                   check_rep=False)
    return fn(q, k, v, lens_in)


def _sz(mesh, a):
    return dict(zip(mesh.axis_names, mesh.devices.shape))[a]
