from repro.distributed.sharding import (
    AxisRules,
    DEFAULT_RULES,
    current_mesh,
    current_rules,
    logical_shard,
    logical_spec,
    logical_sharding,
    make_param_shardings,
    use_mesh,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "current_mesh",
    "current_rules",
    "logical_shard",
    "logical_spec",
    "logical_sharding",
    "make_param_shardings",
    "use_mesh",
]
