"""Checkpointing: flat-key .npz save/restore of any pytree.

Keys are slash-joined tree paths; restore reconstructs into a target tree
(so shardings/structure come from the model, not the file). No pickle —
portable and safe.
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    out = {}

    def name(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out[name(path)] = np.asarray(leaf)
    return out


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **_flatten(tree))


def restore(path: str, target: Any) -> Any:
    """Load into the structure of ``target`` (arrays or ShapeDtypeStructs)."""
    with np.load(path) as data:
        flat = dict(data)
    names = list(_flatten_names(target))
    leaves, treedef = jax.tree_util.tree_flatten(target)
    assert len(names) == len(leaves)
    out = []
    for n, ref in zip(names, leaves):
        if n not in flat:
            raise KeyError(f"checkpoint missing {n!r}")
        a = flat[n]
        if tuple(a.shape) != tuple(ref.shape):
            raise ValueError(f"{n}: shape {a.shape} != expected {ref.shape}")
        out.append(jnp.asarray(a, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _flatten_names(tree):
    def name(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        yield name(path)
