"""Train state: params + optimizer state + step, as one pytree."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWState, adamw_init


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array  # () int32

    @classmethod
    def create(cls, params) -> "TrainState":
        return cls(params=params, opt=adamw_init(params),
                   step=jnp.zeros((), jnp.int32))
