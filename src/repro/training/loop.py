"""Training loop: jit-compiled step factory + host-side driver.

``make_train_step`` builds the pjit-able step (loss → grads → clip → AdamW)
with explicit in/out shardings when a mesh is active; this is the exact
function the multi-pod dry-run lowers for the ``train_4k`` shape.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (AxisRules, current_mesh,
                                        logical_shard, make_param_shardings)
from repro.training.optimizer import adamw_update, clip_by_global_norm
from repro.training.state import TrainState


def make_train_step(
    model,
    *,
    lr=3e-4,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
    impl: str = "jnp",
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Returns ``step(state, batch) -> (state', metrics)`` (not yet jitted)."""

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        def loss_of(p):
            batch_s = {
                k: logical_shard(v, "batch", *(None,) * (v.ndim - 1))
                for k, v in batch.items()
            }
            loss, parts = model.loss_fn(p, batch_s, impl=impl)
            return loss, parts

        (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(
            state.params)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, lr=lr, weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, **parts}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return step


def state_shardings(model, mesh, rules: AxisRules, dtype=jnp.float32):
    """NamedShardings for the full TrainState (moments follow params)."""
    axes = model.param_axes()
    shapes = model.abstract_params(dtype)
    p_shard = make_param_shardings(mesh, rules, axes, shapes)
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P())
    from repro.training.optimizer import AdamWState
    return TrainState(
        params=p_shard,
        opt=AdamWState(mu=p_shard, nu=p_shard, count=scalar),
        step=scalar,
    )


def train_loop(
    model,
    data: Iterable[Dict],
    *,
    steps: int,
    lr=3e-4,
    weight_decay: float = 0.1,
    max_grad_norm: float = 1.0,
    rng: Optional[jax.Array] = None,
    state: Optional[TrainState] = None,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
    impl: str = "jnp",
) -> Tuple[TrainState, list]:
    """Host driver: init, jit, iterate. Returns (final state, metric log)."""
    if state is None:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        state = TrainState.create(model.init_params(rng))
    step_fn = jax.jit(make_train_step(
        model, lr=lr, weight_decay=weight_decay,
        max_grad_norm=max_grad_norm, impl=impl))
    history = []
    t0 = time.perf_counter()
    it = iter(data)
    for i in range(steps):
        batch = next(it)
        state, metrics = step_fn(state, batch)
        if (i + 1) % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = int(state.step)
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            log_fn(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
                   f"gnorm {m['grad_norm']:.3f}  {m['wall_s']:.1f}s")
    return state, history
