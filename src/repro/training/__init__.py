from repro.training.optimizer import (adamw_init, adamw_update,
                                      cosine_schedule, clip_by_global_norm)
from repro.training.state import TrainState
from repro.training.loop import make_train_step, train_loop
