"""AdamW + cosine schedule + global-norm clipping, in pure JAX.

No optax dependency: the optimizer state is a plain pytree (mu, nu) matching
the parameter tree, so it shards with the same logical-axis rules as the
parameters (each moment inherits its parameter's NamedSharding) and
checkpointing is uniform.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any  # first moment, same tree as params
    nu: Any  # second moment
    count: jax.Array  # () int32 step counter


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype),
                                  grads), gnorm


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_frac: float = 0.1):
    """Linear warmup then cosine decay to ``min_frac * base_lr``."""

    def lr(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,  # float or callable(step) -> lr
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    """One AdamW step. Returns (new_params, new_state)."""
    count = state.count + 1
    lr_t = lr(count) if callable(lr) else jnp.float32(lr)
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / c1
        vhat = v32 / c2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        # moments are stored in their tree's dtype (f32 by default; the
        # multi-pod giants use bf16 moments — DESIGN.md §7) so the train
        # state pytree round-trips with stable dtypes and donation aliases.
        return ((p - lr_t * step.astype(p.dtype)).astype(p.dtype),
                m32.astype(m.dtype), v32.astype(v.dtype))

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(mu=new_m, nu=new_v, count=count)
