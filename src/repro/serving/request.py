"""Serving request objects + lifecycle states."""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_ids = itertools.count()


class Status(enum.Enum):
    WAITING = "waiting"        # queued, no pages reserved
    PREFILLING = "prefilling"  # in the batch, prompt caching chunk-by-chunk
    RUNNING = "running"        # in the decode batch
    PREEMPTED = "preempted"    # pages reclaimed; will re-prefill
    FINISHED = "finished"


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: Optional[int] = None
    # set by the engine
    rid: int = field(default_factory=lambda: next(_ids))
    status: Status = Status.WAITING
    slot: int = -1                     # batch slot while RUNNING/PREFILLING
    prefill_pos: int = 0               # tokens cached so far (chunked prefill)
    output: List[int] = field(default_factory=list)
    parent: Optional[int] = None       # prefix-shared parent request id
    metrics: Dict[str, float] = field(default_factory=dict)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.output)

    @property
    def done(self) -> bool:
        return self.status == Status.FINISHED
