"""Serving request objects + lifecycle states.

Lifecycle (fault-tolerant serving, ISSUE 6)::

                    admit                 last chunk
    WAITING ─────────────▶ PREFILLING ───────────────▶ RUNNING ──▶ FINISHED
       ▲  ▲ (monolithic: straight to RUNNING)            │
       │  └──────────────── re-queue ◀── PREEMPTED ◀─────┘
       │
      add                 every non-terminal state may also exit to:
                            FAILED     (structured EngineError on `error`)
                            CANCELLED  (Engine.cancel_request)

``FAILED`` / ``CANCELLED`` / ``FINISHED`` are terminal: pages, slot and
block-table row are released on entry and the request never re-enters the
scheduler.  ``done`` is true for all three — callers draining a wave must
not spin on a request that can no longer make progress.
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

_ids = itertools.count()


class Status(enum.Enum):
    WAITING = "waiting"        # queued, no pages reserved
    PREFILLING = "prefilling"  # in the batch, prompt caching chunk-by-chunk
    RUNNING = "running"        # in the decode batch
    PREEMPTED = "preempted"    # pages reclaimed; will re-prefill
    FINISHED = "finished"
    FAILED = "failed"          # terminal: structured error on req.error
    CANCELLED = "cancelled"    # terminal: torn down by cancel_request


# terminal states: resources released, never scheduled again
TERMINAL = (Status.FINISHED, Status.FAILED, Status.CANCELLED)


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 64
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    eos_id: Optional[int] = None
    # deadlines (enforced by the scheduler; miss => FAILED/DeadlineExceeded)
    deadline_steps: Optional[int] = None       # total engine-step budget
    ttft_deadline_steps: Optional[int] = None  # steps until first token
    # set by the engine
    rid: int = field(default_factory=lambda: next(_ids))
    status: Status = Status.WAITING
    slot: int = -1                     # batch slot while RUNNING/PREFILLING
    prefill_pos: int = 0               # tokens cached so far (chunked prefill)
    cached_prefix: int = 0             # tokens served from the global prefix
    #                                    cache at the latest admission (0 =
    #                                    cold prefill); set by the scheduler
    #                                    even on re-admission after preempt
    output: List[int] = field(default_factory=list)
    parent: Optional[int] = None       # prefix-shared parent request id
    metrics: Dict[str, float] = field(default_factory=dict)
    error: Optional[Exception] = None  # EngineError when status is FAILED

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return len(self.prompt) + len(self.output)

    @property
    def done(self) -> bool:
        """Terminal — finished, failed, or cancelled (no more progress)."""
        return self.status in TERMINAL

    @property
    def succeeded(self) -> bool:
        return self.status is Status.FINISHED
