"""Deterministic, seeded fault injection for the serving stack (ISSUE 6).

Production paged-KV servers treat allocator failure, device flakiness and
numerics corruption as first-class, *tested* paths (PagedAttention makes
preempt-and-recover a scheduling primitive; vAttention's critique is
precisely that dynamic KV allocation failing mid-stream is where fragile
engines die).  This module makes those paths exercisable on demand:

  * a ``FaultPlan`` is a seeded list of ``FaultRule``s; each rule names an
    injection *site*, a fault *kind*, and when to fire (the nth matching
    call, or a probability drawn from the plan's private seeded RNG — no
    global randomness, so a given (plan, schedule) pair replays exactly);
  * ``FaultyPageManager`` wraps ``HostPageManager.reserve/extend/free``
    with the plan (forced allocation failure looks exactly like a dry
    pool; an injected ``free`` fault raises a structured allocator error);
  * the engine consults the plan at the prefill/decode dispatch (simulated
    transient device error, retried with backoff) and per request row at
    sampling time (injected NaN logits, caught by the numerics guard).

Injection sites and the fault kinds they accept:

  ========  ===========  ==================================================
  site      kind         effect
  ========  ===========  ==================================================
  reserve   alloc_fail   ``mgr.reserve`` returns False (dry-pool shaped)
  extend    alloc_fail   ``mgr.extend`` returns False (dry-pool shaped)
  free      error        ``mgr.free`` raises SchedulerInvariantError
  prefill   transient    prefill dispatch raises TransientDeviceError
  decode    transient    decode dispatch raises TransientDeviceError
  sample    nan          that request's logits row is set to NaN
  attach    evict        prefix-cache chain evicted between lookup and
                         attach (admission degrades to a cold prefill)
  ========  ===========  ==================================================

All faults fire *before* the wrapped operation mutates anything, so a
retried dispatch (transient) or a refused reservation (alloc_fail) leaves
the allocator state exactly as a real dry pool / flaky device would — the
allocator invariants asserted by the chaos soak hold across every fire.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.paging import HostPageManager
from repro.errors import EngineConfigError, SchedulerInvariantError

SITES = ("reserve", "extend", "free", "prefill", "decode", "sample",
         "attach")
KINDS = ("alloc_fail", "transient", "nan", "error", "evict")
_VALID = {
    "reserve": ("alloc_fail",),
    "extend": ("alloc_fail",),
    "free": ("error",),
    "prefill": ("transient",),
    "decode": ("transient",),
    "sample": ("nan",),
    # prefix-cache attach (core.prefix_cache): the matched chain is
    # evicted between lookup and attach — admission must degrade to a
    # plain cold prefill with nothing leaked
    "attach": ("evict",),
}


@dataclass
class FaultRule:
    """One injection rule.  Fires on the ``nth`` call matching
    (site, rid), or with probability ``prob`` per matching call; at most
    ``times`` fires total (None = unlimited)."""

    site: str
    kind: str
    rid: Optional[int] = None     # restrict to one request (sites that
    #                               carry a rid: reserve/extend/free/sample)
    nth: Optional[int] = None     # 1-based index among matching calls
    prob: float = 0.0             # used only when nth is None
    times: Optional[int] = 1      # max fires (None = unlimited)
    # counters (owned by the plan; one plan instance per engine run)
    seen: int = field(default=0, repr=False)
    fired: int = field(default=0, repr=False)


class FaultPlan:
    """A seeded, replayable schedule of injected faults.

    The plan owns a private ``random.Random(seed)``; probability draws
    consume it only when a prob-rule is consulted, so for a deterministic
    engine schedule the fire pattern is a pure function of (seed, rules).
    ``plan.log`` records every fire as (site, rid, kind, call_index) for
    test assertions; ``plan.calls`` counts consultations per site.
    """

    def __init__(self, rules: List[FaultRule], seed: int = 0):
        for r in rules:
            if r.site not in SITES:
                raise EngineConfigError(f"unknown fault site {r.site!r}; "
                                        f"sites: {SITES}", site=r.site)
            if r.kind not in _VALID[r.site]:
                raise EngineConfigError(
                    f"fault kind {r.kind!r} invalid at site {r.site!r}; "
                    f"valid: {_VALID[r.site]}", site=r.site, kind=r.kind)
        self.rules = rules
        self.seed = seed
        self._rng = random.Random(seed)
        self.log: List[Tuple[str, Optional[int], str, int]] = []
        self.calls = {s: 0 for s in SITES}

    def fire(self, site: str, rid: Optional[int] = None) -> Optional[str]:
        """Consult the plan at an injection point.  Returns the fault kind
        to apply, or None.  At most one rule fires per call (first match
        in rule order wins)."""
        self.calls[site] += 1
        for rule in self.rules:
            if rule.site != site:
                continue
            if rule.rid is not None and rid != rule.rid:
                continue
            rule.seen += 1
            if rule.times is not None and rule.fired >= rule.times:
                continue
            if rule.nth is not None:
                hit = rule.seen == rule.nth
            else:
                hit = self._rng.random() < rule.prob
            if hit:
                rule.fired += 1
                self.log.append((site, rid, rule.kind, self.calls[site]))
                return rule.kind
        return None

    @property
    def fires(self) -> int:
        return len(self.log)


class FaultyPageManager(HostPageManager):
    """``HostPageManager`` with the plan's reserve/extend/free sites wired
    in.  Injected allocation failures are indistinguishable from a dry
    pool (return False, no mutation), so every scheduler recovery path —
    stall, preempt, backpressure, fail — is exercised by the same code
    that handles real exhaustion."""

    def __init__(self, num_pages: int, page_size: int, plan: FaultPlan):
        super().__init__(num_pages, page_size)
        self.plan = plan

    def reserve(self, seq_id: int, new_len: int) -> bool:
        if self.plan.fire("reserve", rid=seq_id) == "alloc_fail":
            return False
        return super().reserve(seq_id, new_len)

    def extend(self, seq_id: int, n_tokens: int = 1) -> bool:
        if self.plan.fire("extend", rid=seq_id) == "alloc_fail":
            return False
        # bypass the faulty `reserve` override: an extend is one logical
        # allocation and must consult the plan exactly once
        return HostPageManager.reserve(
            self, seq_id, self.lens.get(seq_id, 0) + n_tokens)

    def free(self, seq_id: int) -> None:
        if self.plan.fire("free", rid=seq_id) == "error":
            raise SchedulerInvariantError(
                f"injected allocator fault freeing rid {seq_id}",
                rid=seq_id, injected=True)
        super().free(seq_id)
