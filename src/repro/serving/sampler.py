"""Token sampling: greedy / temperature / top-k / top-p, batched + jit-able.

Top-p (nucleus) boundary contract — pinned by ``tests/test_sampler.py``:
the kept set is the **smallest** prefix of the probability-sorted vocab
whose cumulative mass is ``>= p``, i.e. the token whose cumulative sum
*crosses* ``p`` is **included** (token ``i`` survives iff the mass strictly
before it is ``< p``).  Consequences:

  * ``p`` exactly on a cumulative step keeps exactly that prefix (mass
    == p), nothing more;
  * ``p = 1.0`` disables the filter (every token kept);
  * ``p -> 0`` keeps only the argmax (the first token always crosses);
  * tokens *tied in logit* with the crossing token are also kept (the
    cutoff is by value, so a tie cannot be split arbitrarily by sort
    order) — the kept mass is then minimal among value-respecting sets.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.errors import InvalidRequest


def validate_sample_params(req) -> None:
    """Reject out-of-domain sampling knobs at ``add_request`` time.

    A negative temperature or a NaN top_p sails straight through the
    batched ``sample`` math and poisons that row's distribution (NaN
    probabilities => garbage tokens) several steps after admission, where
    the cause is unrecoverable.  Validating up front turns that into a
    structured ``InvalidRequest`` before the request holds any pages.
    """
    t, k, p = req.temperature, req.top_k, req.top_p
    if not math.isfinite(t) or t < 0.0:
        raise InvalidRequest(
            f"temperature must be finite and >= 0, got {t}", rid=req.rid,
            param="temperature", value=t)
    if not (0.0 <= p <= 1.0):  # NaN fails both comparisons
        raise InvalidRequest(
            f"top_p must lie in [0, 1], got {p}", rid=req.rid,
            param="top_p", value=p)
    if k < 0:
        raise InvalidRequest(
            f"top_k must be >= 0 (0 disables), got {k}", rid=req.rid,
            param="top_k", value=k)
    if req.max_new_tokens < 1:
        raise InvalidRequest(
            f"max_new_tokens must be >= 1, got {req.max_new_tokens}",
            rid=req.rid, param="max_new_tokens", value=req.max_new_tokens)


class SampleParams(NamedTuple):
    temperature: jax.Array  # (B,) f32; 0 => greedy
    top_k: jax.Array  # (B,) int32; 0 => off
    top_p: jax.Array  # (B,) f32; 1.0 => off


def top_k_mask(lg: jax.Array, k: jax.Array) -> jax.Array:
    """(V,) logits → logits with everything below the k-th largest at -inf
    (``k <= 0`` disables).  Ties with the k-th value are kept."""
    V = lg.shape[0]
    kth = jnp.sort(lg)[::-1][jnp.clip(k - 1, 0, V - 1)]
    return jnp.where((k > 0) & (lg < kth), -jnp.inf, lg)


def top_p_mask(lg: jax.Array, p: jax.Array) -> jax.Array:
    """(V,) logits → logits outside the nucleus at -inf (``p >= 1``
    disables).  Inclusive boundary: the smallest sorted prefix with
    cumulative probability >= p survives, *including* the crossing token
    (see module docstring)."""
    srt = jnp.sort(lg)[::-1]
    probs = jax.nn.softmax(srt)
    csum = jnp.cumsum(probs)
    # token i kept iff mass strictly before it < p  (always keep argmax)
    keep_sorted = jnp.concatenate([jnp.array([True]), csum[:-1] < p])
    cutoff = jnp.min(jnp.where(keep_sorted, srt, jnp.inf))
    return jnp.where((p < 1.0) & (lg < cutoff), -jnp.inf, lg)


def sample(rng: jax.Array, logits: jax.Array, params: SampleParams
           ) -> jax.Array:
    """logits: (B, V) -> (B,) int32 tokens."""
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    lg = jax.vmap(top_k_mask)(logits, params.top_k)
    lg = jax.vmap(top_p_mask)(lg, params.top_p)

    temp = jnp.maximum(params.temperature, 1e-6)[:, None]
    keys = jax.random.split(rng, B)
    sampled = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, lg / temp)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(params.temperature <= 0.0, greedy, sampled).astype(jnp.int32)
