"""Token sampling: greedy / temperature / top-k / top-p, batched + jit-able."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SampleParams(NamedTuple):
    temperature: jax.Array  # (B,) f32; 0 => greedy
    top_k: jax.Array  # (B,) int32; 0 => off
    top_p: jax.Array  # (B,) f32; 1.0 => off


def sample(rng: jax.Array, logits: jax.Array, params: SampleParams
           ) -> jax.Array:
    """logits: (B, V) -> (B,) int32 tokens."""
    B, V = logits.shape
    logits = logits.astype(jnp.float32)

    # top-k filter
    def topk_mask(lg, k):
        kth = jnp.sort(lg)[::-1][jnp.clip(k - 1, 0, V - 1)]
        return jnp.where((k > 0) & (lg < kth), -jnp.inf, lg)

    lg = jax.vmap(topk_mask)(logits, params.top_k)

    # top-p (nucleus) filter
    def topp_mask(lg, p):
        srt = jnp.sort(lg)[::-1]
        probs = jax.nn.softmax(srt)
        csum = jnp.cumsum(probs)
        # keep the smallest prefix with mass >= p (always keep the argmax)
        keep_sorted = jnp.concatenate([jnp.array([True]), csum[:-1] < p])
        cutoff = jnp.min(jnp.where(keep_sorted, srt, jnp.inf))
        return jnp.where((p < 1.0) & (lg < cutoff), -jnp.inf, lg)

    lg = jax.vmap(topp_mask)(lg, params.top_p)

    temp = jnp.maximum(params.temperature, 1e-6)[:, None]
    keys = jax.random.split(rng, B)
    sampled = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, lg / temp)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(params.temperature <= 0.0, greedy, sampled).astype(jnp.int32)
