"""Token sampling: greedy / temperature / top-k / top-p, batched + jit-able.

Top-p (nucleus) boundary contract — pinned by ``tests/test_sampler.py``:
the kept set is the **smallest** prefix of the probability-sorted vocab
whose cumulative mass is ``>= p``, i.e. the token whose cumulative sum
*crosses* ``p`` is **included** (token ``i`` survives iff the mass strictly
before it is ``< p``).  Consequences:

  * ``p`` exactly on a cumulative step keeps exactly that prefix (mass
    == p), nothing more;
  * ``p = 1.0`` disables the filter (every token kept);
  * ``p -> 0`` keeps only the argmax (the first token always crosses);
  * tokens *tied in logit* with the crossing token are also kept (the
    cutoff is by value, so a tie cannot be split arbitrarily by sort
    order) — the kept mass is then minimal among value-respecting sets.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SampleParams(NamedTuple):
    temperature: jax.Array  # (B,) f32; 0 => greedy
    top_k: jax.Array  # (B,) int32; 0 => off
    top_p: jax.Array  # (B,) f32; 1.0 => off


def top_k_mask(lg: jax.Array, k: jax.Array) -> jax.Array:
    """(V,) logits → logits with everything below the k-th largest at -inf
    (``k <= 0`` disables).  Ties with the k-th value are kept."""
    V = lg.shape[0]
    kth = jnp.sort(lg)[::-1][jnp.clip(k - 1, 0, V - 1)]
    return jnp.where((k > 0) & (lg < kth), -jnp.inf, lg)


def top_p_mask(lg: jax.Array, p: jax.Array) -> jax.Array:
    """(V,) logits → logits outside the nucleus at -inf (``p >= 1``
    disables).  Inclusive boundary: the smallest sorted prefix with
    cumulative probability >= p survives, *including* the crossing token
    (see module docstring)."""
    srt = jnp.sort(lg)[::-1]
    probs = jax.nn.softmax(srt)
    csum = jnp.cumsum(probs)
    # token i kept iff mass strictly before it < p  (always keep argmax)
    keep_sorted = jnp.concatenate([jnp.array([True]), csum[:-1] < p])
    cutoff = jnp.min(jnp.where(keep_sorted, srt, jnp.inf))
    return jnp.where((p < 1.0) & (lg < cutoff), -jnp.inf, lg)


def sample(rng: jax.Array, logits: jax.Array, params: SampleParams
           ) -> jax.Array:
    """logits: (B, V) -> (B,) int32 tokens."""
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    lg = jax.vmap(top_k_mask)(logits, params.top_k)
    lg = jax.vmap(top_p_mask)(lg, params.top_p)

    temp = jnp.maximum(params.temperature, 1e-6)[:, None]
    keys = jax.random.split(rng, B)
    sampled = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, lg / temp)
    greedy = jnp.argmax(logits, axis=-1)
    return jnp.where(params.temperature <= 0.0, greedy, sampled).astype(jnp.int32)
