"""Serving engine: continuous batching over the paged KV cache.

The engine is the paper's "system-level integration" (§III): the model's
prefill/decode steps run against *global* K/V page pools, the scheduler's
host-side page manager decides admission/preemption, and block tables flow
device-side each step (the asynchronous-update contract of DESIGN.md §2).

One Engine instance serves one model on one batch of ``max_slots`` logical
slots. The pool is deliberately *oversubscribable*: ``pool_tokens`` may be
far less than ``max_slots × max_seq_len`` — that is the paper's entire
memory win over max-length pre-allocation.

The contiguous baseline (``paged=False``) allocates the paper's comparison
target instead: per-slot max-length buffers.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.paging import HostPageManager
from repro.core.prefix_cache import PrefixCache
from repro.errors import (EngineConfigError, EngineError, InternalError,
                          InvalidRequest, NumericsError, PoolExhausted,
                          RequestTooLong, SchedulerInvariantError,
                          TransientDeviceError)
from repro.models.api import build_model
from repro.serving.faults import FaultPlan, FaultyPageManager
from repro.serving.request import Request, Status
from repro.serving.sampler import SampleParams, sample, validate_sample_params
from repro.serving.scheduler import Scheduler


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any = None,
        *,
        max_slots: int = 8,
        max_seq_len: int = 512,
        pool_tokens: Optional[int] = None,  # None => slots*max_seq_len (no oversub)
        paged: Optional[bool] = None,
        impl: str = "ref",
        rng: Optional[jax.Array] = None,
        dtype=jnp.float32,
        interpret: Optional[bool] = None,  # None → auto (off-TPU: interpret)
        pages_per_block: Optional[int] = None,  # decode kernel knobs;
        num_splits: Optional[int] = None,  # None → auto-tuned per shape
        combine_mode: Optional[str] = None,  # split-K merge impl (None=auto)
        backend: Optional[str] = None,  # kernel lowering: "tpu" | "gpu"
        # (None → auto from jax.default_backend(); CPU hosts fall back to
        # the TPU lowering in interpret mode)
        prefill_chunk: Optional[int] = None,  # tokens of prompt prefilled
        # per engine step (None = whole prompt in one monolithic pass).
        # Chunked prefill bounds per-step work: the whole prefill
        # sub-batch caches at most `prefill_chunk` tokens per iteration
        # (a *global* budget split across concurrent prefills),
        # interleaved with decode steps for the running batch
        # (vLLM-style continuous batching), resuming from the
        # already-cached prefix pages each step.
        prefix_cache: bool = False,  # global prefix cache: radix-indexed
        # page sharing across requests (core.prefix_cache).  Admission
        # attaches new prompts to the longest previously-cached prefix
        # (zero prefill work for the hit), releases retain written pages,
        # and the pool evicts detached chains LRU-first under pressure.
        # Requires the paged engine with pure dense self-attention (no
        # windowed/recurrent/cross layers — see the gates below).
        # --- fault tolerance (ISSUE 6) --------------------------------
        faults: Optional[FaultPlan] = None,  # deterministic fault
        # injection: wraps the page manager's reserve/extend/free, the
        # prefill/decode dispatch, and per-request sampling rows
        numerics_guard: bool = True,  # detect NaN/Inf logits per row and
        # fail *that* request (the rest of the batch keeps decoding)
        max_waiting: Optional[int] = None,  # bounded wait queue
        # (reject-on-full with Backpressure); None = unbounded
        admit_watermark: Optional[float] = None,  # pool-utilization
        # fraction above which new admits are shed with Backpressure
        # instead of admitted into preemption thrash; None = off
        max_step_retries: int = 3,  # transient-device retries per dispatch
        retry_backoff_s: float = 0.0,  # base backoff (doubles per retry;
        # 0 = no sleep — deterministic tests)
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.impl = impl
        self.interpret = interpret
        self.pages_per_block = pages_per_block
        self.num_splits = num_splits
        self.combine_mode = combine_mode
        self.backend = backend
        self.dtype = dtype
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.paged = cfg.paged_attention if paged is None else paged
        self.prefill_chunk = prefill_chunk
        if prefill_chunk is not None:
            if prefill_chunk < 1:
                raise EngineConfigError(
                    "prefill_chunk must be >= 1 (or None)",
                    prefill_chunk=prefill_chunk)
            if not self.paged:
                raise EngineConfigError(
                    "chunked prefill requires the paged engine (paged=True)",
                    prefill_chunk=prefill_chunk)
            codes = cfg.pattern() if cfg.family != "encdec" else ""
            if any(c in "RMS" for c in codes):
                raise EngineConfigError(
                    "chunked prefill does not support recurrent layers "
                    f"(pattern {cfg.layer_pattern!r}): their prefill "
                    "state replay assumes the whole prompt",
                    pattern=cfg.layer_pattern)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.rng, init_rng = jax.random.split(rng)
        self.params = (params if params is not None
                       else self.model.init_params(init_rng, dtype))

        ps = cfg.page_size
        window = getattr(self.model, "window", 0)
        codes = cfg.pattern() if cfg.family != "encdec" else "A"
        # ring-sized tables are only sound when EVERY attention layer is
        # windowed: a mixed dense/windowed pattern's 'A' layers carry live
        # KV for the whole sequence, so their table must span max_seq_len
        # (the 'W' layers keep using columns 0..ring-1 as the ring).
        self._ring_tables = window > 0 and "A" not in codes
        if self._ring_tables:
            self.pages_per_seq = -(-window // ps) + 1
        elif window > 0:
            self.pages_per_seq = max(-(-max_seq_len // ps),
                                     -(-window // ps) + 1)
        else:
            self.pages_per_seq = -(-max_seq_len // ps)
        if pool_tokens is None:
            num_pages = max_slots * self.pages_per_seq
        else:
            num_pages = max(-(-pool_tokens // ps), self.pages_per_seq)
        self.num_pages = num_pages

        if prefix_cache:
            # pages must be immutable once written for cross-request
            # sharing to be sound, and their content must be a function
            # of the token prefix alone (that is the radix key)
            if not self.paged:
                raise EngineConfigError(
                    "prefix_cache requires the paged engine (paged=True)")
            if window > 0:
                raise EngineConfigError(
                    "prefix_cache requires window=0: windowed layers "
                    "overwrite their ring pages in place, so cached "
                    "pages shared from a live donor would be mutated",
                    window=window)
            if (cfg.family == "encdec"
                    or getattr(self.model, "n_cross_layers", 0)):
                raise EngineConfigError(
                    "prefix_cache does not support encoder/cross-"
                    "attention models: self-attention K/V depend on the "
                    "per-request image/audio context, so token-keyed "
                    "page sharing would be wrong", family=cfg.family)
            if any(c in "RMS" for c in cfg.pattern()):
                raise EngineConfigError(
                    "prefix_cache does not support recurrent layers "
                    f"(pattern {cfg.layer_pattern!r}): their state is "
                    "not page-addressed", pattern=cfg.layer_pattern)

        self.faults = faults
        self.numerics_guard = numerics_guard
        self.max_step_retries = max_step_retries
        self.retry_backoff_s = retry_backoff_s
        self.mgr = (FaultyPageManager(num_pages, ps, faults)
                    if faults is not None else HostPageManager(num_pages, ps))
        self.prefix_cache = (PrefixCache(self.mgr, faults=faults)
                             if prefix_cache else None)
        self.scheduler = Scheduler(self.mgr, max_slots, max_seq_len,
                                   prefill_chunk=prefill_chunk,
                                   max_waiting=max_waiting,
                                   admit_watermark=admit_watermark,
                                   prefix_cache=self.prefix_cache)
        self.state = self._init_state()
        self._slot_extra: Dict[int, Dict] = {}
        self.steps = 0
        self.stats: Dict[str, int] = {"transient_retries": 0}
        self._jit_decode = jax.jit(self._decode_fn, static_argnames=())

    # ------------------------------------------------------------------
    def _init_state(self) -> Dict:
        cfg, m = self.cfg, self.model
        B, ps = self.max_slots, cfg.page_size
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        st: Dict[str, Any] = {"pos": jnp.zeros((B,), jnp.int32)}
        n_attn = getattr(m, "n_attn_layers", 0)
        if n_attn:
            if self.paged:
                pool = (n_attn, self.num_pages, ps, Hkv, hd)
                pool_dt = (jnp.int8 if cfg.kv_dtype == "int8"
                           else self.dtype)
                st["k_pages"] = jnp.zeros(pool, pool_dt)
                st["v_pages"] = jnp.zeros(pool, pool_dt)
                st["tables"] = jnp.full((B, 1, self.pages_per_seq), -1,
                                        jnp.int32)
            else:
                # the paper's baseline: contiguous max-length per-slot buffers
                buf = (n_attn, B, self.max_seq_len, Hkv, hd)
                st["k_buf"] = jnp.zeros(buf, self.dtype)
                st["v_buf"] = jnp.zeros(buf, self.dtype)
        n_cross = getattr(m, "n_cross_layers", 0)
        if cfg.family == "encdec":
            n_cross = cfg.n_layers
        if n_cross:
            ctx_len = (cfg.n_audio_frames if cfg.family == "encdec"
                       else cfg.n_image_tokens)
            ck = (n_cross, B, ctx_len, Hkv, hd)
            st["cross_k"] = jnp.zeros(ck, self.dtype)
            st["cross_v"] = jnp.zeros(ck, self.dtype)
        # recurrent state slots
        from repro.models import rglru, ssm
        rec: Dict[str, Any] = {}
        codes = cfg.pattern() if cfg.family != "encdec" else ""
        for code, init in (("R", rglru.rglru_init_state),
                           ("M", ssm.mlstm_init_state),
                           ("S", ssm.slstm_init_state)):
            n = sum(c == code for c in codes)
            if n:
                one = init(B, cfg, self.dtype)
                rec[code] = jax.tree_util.tree_map(
                    lambda a: jnp.zeros((n,) + a.shape, a.dtype), one)
        if rec:
            st["rec"] = rec
        return st

    # ------------------------------------------------------------------
    def add_request(self, req: Request, extra: Optional[Dict] = None) -> int:
        """Validate and enqueue ``req``.

        Raises structured errors before the request holds any resources:
        ``InvalidRequest`` (bad sampling params), ``RequestTooLong``
        (prompt + budget exceeds max_seq_len), or ``Backpressure`` (wait
        queue full / pool above the admission high-watermark — carries a
        retry hint; resubmit later).
        """
        validate_sample_params(req)
        if req.prompt_len + req.max_new_tokens > self.max_seq_len:
            raise RequestTooLong(
                f"request exceeds engine max_seq_len: prompt_len "
                f"{req.prompt_len} + max_new_tokens {req.max_new_tokens} > "
                f"{self.max_seq_len}", rid=req.rid,
                limit=self.max_seq_len)
        req.metrics["t_arrive"] = time.perf_counter()
        req.metrics["step_arrive"] = self.steps
        if extra is not None:
            req.metrics["_extra"] = extra  # modality stub embeddings
        self.scheduler.add(req)  # may raise Backpressure (nothing held yet)
        return req.rid

    def generate(self, reqs: List[Request],
                 extras: Optional[List[Optional[Dict]]] = None,
                 max_steps: int = 100_000) -> List[Request]:
        """Blocking helper: run until the given requests all finish."""
        extras = extras or [None] * len(reqs)
        for r, e in zip(reqs, extras):
            self.add_request(r, e)
        for _ in range(max_steps):
            if all(r.done for r in reqs):
                break
            self.step()
        return reqs

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine iteration: deadlines → admit → prefill → decode →
        sample → finish.

        Monolithic mode (``prefill_chunk=None``) prefills every admitted
        prompt whole.  Chunked mode interleaves: each PREFILLING request
        caches one ``prefill_chunk``-token installment (resuming from its
        cached pages) and the RUNNING sub-batch decodes one token — both
        sub-batches advance in the same iteration, so no step's cost
        scales with a full prompt length.  Sampling fires only when a
        request's *last* chunk lands.

        Fault isolation contract (gated by ``tests/test_faults.py``):
        failures attributable to one request (NaN logits, deadline miss,
        allocation starvation with no recourse) fail *that* request —
        pages released, batch-mates unaffected; transient device errors
        on a dispatch are retried with backoff; anything unstructured is
        wrapped in ``InternalError``.  No bare exception escapes.

        Returns requests that reached a terminal state this step
        (FINISHED and FAILED; cancellations report via cancel_request).
        """
        try:
            return self._step_impl()
        except EngineError:
            raise  # structured: the caller can route it
        except Exception as e:  # noqa: BLE001 — the wrap IS the contract
            raise InternalError(
                f"unstructured failure escaped engine step: {e!r}") from e

    def _step_impl(self) -> List[Request]:
        self.steps += 1
        self.scheduler.check_deadlines(self.steps)
        admitted = self.scheduler.admit()
        finished: List[Request] = []
        if self.prefill_chunk is None:
            if admitted:
                self._dispatch("prefill", self._prefill, admitted)
                # prefill's sampled token may already hit EOS / max_new
                finished += self._finish_done()
        elif any(r.status is Status.PREFILLING
                 for r in self.scheduler.running.values()):
            self._dispatch("prefill", self._prefill_chunk_step)
            finished += self._finish_done()
        if any(r.status is Status.RUNNING
               for r in self.scheduler.running.values()):
            if self.paged:
                self.scheduler.extend_for_decode()
            # extend may have failed the last decoder (starvation) —
            # re-check before dispatching an empty decode sub-batch
            if any(r.status is Status.RUNNING
                   for r in self.scheduler.running.values()):
                self._dispatch("decode", self._decode)
                finished += self._finish_done()
        finished += self._drain_failed()
        return finished

    def _dispatch(self, site: str, fn, *args):
        """Run a prefill/decode dispatch with transient-fault retries.

        The fault plan's transient site fires *before* ``fn`` mutates any
        state, so a retry re-runs the dispatch from scratch — the same
        recovery a real transient device error at launch time gets.
        Backoff doubles per attempt from ``retry_backoff_s`` (0 = no
        sleep); after ``max_step_retries`` the structured error escapes.
        """
        delay = self.retry_backoff_s
        for attempt in range(self.max_step_retries + 1):
            try:
                if (self.faults is not None
                        and self.faults.fire(site) == "transient"):
                    raise TransientDeviceError(
                        f"injected transient device error at {site} "
                        "dispatch", site=site, attempt=attempt)
                return fn(*args)
            except TransientDeviceError:
                self.stats["transient_retries"] += 1
                if attempt >= self.max_step_retries:
                    raise
                if delay:
                    time.sleep(delay)
                    delay *= 2

    def _drain_failed(self) -> List[Request]:
        """Collect requests failed mid-step (deadline, starvation, NaN
        guard) so ``step`` reports every terminal transition it caused."""
        ev, self.scheduler.failed_events = self.scheduler.failed_events, []
        now = time.perf_counter()
        for r in ev:
            r.metrics.setdefault("t_done", now)
        return ev

    # ------------------------------------------------------------------
    def cancel_request(self, rid: int) -> bool:
        """Tear down request ``rid`` in any state: WAITING (dequeued),
        PREFILLING mid-chunk or stalled-on-dry-pool (pages + table row
        released; no ghost row reaches the next decode sub-batch),
        RUNNING (slot + pages released mid-decode), PREEMPTED (dequeued).
        Returns False for unknown or already-terminal requests.  Safe
        between steps — cancellation never disturbs batch-mates.
        """
        req = self._find_request(rid)
        if req is None:
            return False
        if not self.scheduler.cancel(req):
            return False
        req.metrics.setdefault("t_done", time.perf_counter())
        return True

    def _find_request(self, rid: int) -> Optional[Request]:
        for r in self.scheduler.waiting:
            if r.rid == rid:
                return r
        for r in self.scheduler.running.values():
            if r.rid == rid:
                return r
        return None

    def robustness_report(self) -> Dict[str, int]:
        """Counters for the failure surface (mirrors memory_report)."""
        s = self.scheduler
        pc = self.prefix_cache
        return {
            "failed": s.failed,
            "cancelled": s.cancelled,
            "shed": s.shed,
            "deadline_misses": s.deadline_misses,
            "preempted": s.preempted,
            "prefill_stalls": s.prefill_stalls,
            "transient_retries": self.stats["transient_retries"],
            "fault_fires": self.faults.fires if self.faults else 0,
            # prefix-cache hit surface (all 0 when the cache is off)
            "prefix_hits": pc.hits if pc else 0,
            "prefix_misses": pc.misses if pc else 0,
            "prefix_hit_tokens": pc.hit_tokens if pc else 0,
            "prefix_evicted_pages": pc.evicted_pages if pc else 0,
        }

    # ------------------------------------------------------------------
    def _tables_array(self, decode: bool = False) -> jnp.ndarray:
        """Block tables for the batch, one row per live slot.

        ``decode=True`` blanks PREFILLING slots (their rows stay -1): the
        decode pass must neither write its placeholder token into, nor
        attend over, a half-prefilled sequence's pages.

        A dense sequence whose page row outgrows the device table width is
        a hard error — silently truncating ``row[:pages_per_seq]`` would
        drop the KV tail and produce wrong output with no signal.
        (Pure-windowed models are the exception by design: their row is a
        ring and ``row[:ring]`` IS the table — ring slots are overwritten
        in place, so extra host-side pages never carry live data.  Mixed
        dense/windowed patterns get a full-width table and no exemption.)
        """
        t = np.full((self.max_slots, 1, self.pages_per_seq), -1, np.int32)
        windowed = self._ring_tables
        for slot, req in self.scheduler.running.items():
            if decode and req.status is not Status.RUNNING:
                continue
            row = self.mgr.tables.get(req.rid, [])
            if len(row) > self.pages_per_seq and not windowed:
                raise SchedulerInvariantError(
                    f"request {req.rid} holds {len(row)} pages but the "
                    f"device block table is {self.pages_per_seq} pages wide "
                    f"(max_seq_len={self.max_seq_len}); the sequence "
                    f"outgrew the engine — refusing to truncate its KV "
                    f"tail silently")
            t[slot, 0, :len(row)] = row[:self.pages_per_seq]
        return jnp.asarray(t)

    def _prefill(self, admitted: List[Tuple[int, Request]]) -> None:
        """Prefill newly admitted requests (sub-batch padded to max len)."""
        cfg = self.cfg
        slots = [s for s, _ in admitted]
        reqs = [r for _, r in admitted]
        if any(r.prefill_pos > 0 for r in reqs):
            # at least one row attached to cached prefix pages: run the
            # wave through the prefix-aware chunk kernel, each row's
            # suffix only (cold rows are just q_start=0)
            self._prefill_from(slots, reqs)
            return
        toks = [r.prompt + r.output for r in reqs]  # preempted: re-prefill all
        L = max(len(t) for t in toks)
        B = len(reqs)
        batch = np.zeros((B, L), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, t in enumerate(toks):
            batch[i, :len(t)] = t
            lens[i] = len(t)

        # sub-batch tables for the admitted slots
        full_tables = self._tables_array()
        sub_tables = full_tables[np.asarray(slots), 0]

        st = self.state
        sub_state: Dict[str, Any] = {"pos": jnp.asarray(lens)}
        if self.paged and "k_pages" in st:
            sub_state["k_pages"] = st["k_pages"]
            sub_state["v_pages"] = st["v_pages"]
            sub_state["tables"] = sub_tables
        extra = self._collect_extra(reqs)
        if not self.paged:
            self._prefill_contiguous(slots, batch, lens, extra, reqs)
            return

        logits, new_st = self.model.prefill(
            self.params, jnp.asarray(batch), sub_state,
            lens=jnp.asarray(lens), extra=extra, impl=self.impl)

        # merge: global pools were written in place (scatter by tables);
        # per-slot states (pos, cross, rec) land in the admitted slots.
        if "k_pages" in new_st:
            st["k_pages"] = new_st["k_pages"]
            st["v_pages"] = new_st["v_pages"]
        idx = jnp.asarray(slots)
        st["pos"] = st["pos"].at[idx].set(jnp.asarray(lens))
        for key in ("cross_k", "cross_v"):
            if key in new_st:
                st[key] = st[key].at[:, idx].set(new_st[key])
        if "rec" in new_st:
            st["rec"] = jax.tree_util.tree_map(
                lambda g, s: g.at[:, idx].set(s), st["rec"], new_st["rec"])

        for i, r in enumerate(reqs):
            r.prefill_pos = int(lens[i])  # everything written
        self._cache_insert_live(reqs)
        self._sample_and_append(reqs, logits, first=True)

    def _prefill_from(self, slots: List[int], reqs: List[Request]) -> None:
        """Monolithic prefill resuming past cached prefixes: each row runs
        only its un-cached suffix (``q_start = matched tokens``) through
        the prefix-aware chunk kernel, attending back over the shared
        pages through its block table.  Output must match a cold
        ``model.prefill`` of the whole prompt ≤ 1e-5 — that equivalence
        is exactly what the chunked-prefill gate already proves for the
        kernel, and ``tests/test_prefix_cache.py`` re-proves end-to-end.

        Only reachable with the prefix cache on, which gates the model to
        pure dense self-attention — no cross/rec state to merge here.
        """
        toks = [r.prompt + r.output for r in reqs]
        starts = np.asarray([r.prefill_pos for r in reqs], np.int32)
        lens = np.asarray([len(t) for t in toks], np.int32)
        q_lens = lens - starts  # >= 1: attach caps the match at total-1
        B, C = len(reqs), int(q_lens.max())
        batch = np.zeros((B, C), np.int32)
        for i, t in enumerate(toks):
            batch[i, :q_lens[i]] = t[starts[i]:lens[i]]

        full_tables = self._tables_array()
        sub_tables = np.asarray(full_tables)[np.asarray(slots)]
        st = self.state
        sub_state: Dict[str, Any] = {
            "pos": jnp.asarray(starts),
            "k_pages": st["k_pages"],
            "v_pages": st["v_pages"],
            "tables": jnp.asarray(sub_tables),
        }
        logits, new_st = self.model.prefill_chunk(
            self.params, jnp.asarray(batch), sub_state,
            q_start=jnp.asarray(starts), q_lens=jnp.asarray(q_lens),
            impl=self.impl, interpret=self.interpret,
            pages_per_block=self.pages_per_block,
            num_splits=self.num_splits, combine_mode=self.combine_mode,
            backend=self.backend)

        st["k_pages"] = new_st["k_pages"]
        st["v_pages"] = new_st["v_pages"]
        idx = jnp.asarray(slots)
        st["pos"] = st["pos"].at[idx].set(jnp.asarray(lens))
        for i, r in enumerate(reqs):
            r.prefill_pos = int(lens[i])
        self._cache_insert_live(reqs)
        self._sample_and_append(reqs, logits, first=True)

    def _cache_insert_live(self, reqs: List[Request]) -> None:
        """Index each request's written full pages into the prefix cache
        (progressive insert: concurrent requests sharing a prompt head
        hit on each other's pages mid-wave, not just after release).
        Callers update ``req.prefill_pos`` to the written token count
        first — partial pages are skipped inside ``insert``."""
        if self.prefix_cache is None:
            return
        for r in reqs:
            row = self.mgr.tables.get(r.rid)
            if row:
                self.prefix_cache.insert(r.prompt + r.output, row,
                                         r.prefill_pos)

    def _prefill_chunk_step(self) -> None:
        """Advance every PREFILLING request by one ``prefill_chunk``
        installment (chunked continuous batching).

        The ``prefill_chunk`` token budget is **global across the prefill
        sub-batch**: k concurrent PREFILLING rows split one chunk (oldest
        slot first), they do not each cache a full chunk — the former
        per-request budget let a step's prefill work scale as
        ``k * prefill_chunk``, defeating the bounded-per-step-work
        contract the knob exists for.  Each selected installment is
        reserved chunk-wise (`Scheduler.grow_prefill`); a request whose
        installment cannot get pages stalls this step and resumes from
        its cached pages (``mgr.lens``) later — no recompute.  When a
        request's last chunk lands it flips to RUNNING and its first
        token is sampled from the chunk's last-position logits.
        """
        chunk = self.prefill_chunk
        budget = chunk  # global per-step token budget, split across rows
        sel: List[Tuple[int, Request, int, int]] = []
        for slot in sorted(self.scheduler.running):
            if budget <= 0:
                break
            # re-fetch per iteration: grow_prefill below may preempt a
            # PREFILLING victim in a slot this (snapshotted) loop has not
            # visited yet — indexing the snapshot would KeyError
            req = self.scheduler.running.get(slot)
            if req is None or req.status is not Status.PREFILLING:
                continue
            want = min(budget, req.total_len - req.prefill_pos)
            if not self.scheduler.grow_prefill(req, want):
                continue  # stalled: keeps pages, resumes next step
            start = req.prefill_pos
            q_len = min(want, req.total_len - start)
            sel.append((slot, req, start, q_len))
            budget -= q_len
        # grow_prefill may preempt victims already selected — drop them
        sel = [(s, r, st0, ql) for (s, r, st0, ql) in sel
               if self.scheduler.running.get(s) is r]
        if not sel:
            return
        # fixed (max_slots, prefill_chunk) sub-batch shape: padding rows
        # are dead (tables -1, q_lens 0) so every chunk step traces the
        # same shapes — no per-shape eager-compile stalls on the serving
        # hot path from ragged final chunks or varying batch occupancy
        C = chunk
        B = self.max_slots
        batch = np.zeros((B, C), np.int32)
        q_lens = np.zeros((B,), np.int32)
        starts = np.zeros((B,), np.int32)
        slots = [s for s, _, _, _ in sel]
        reqs = [r for _, r, _, _ in sel]
        for i, (_, req, st0, ql) in enumerate(sel):
            seq = req.prompt + req.output
            batch[i, :ql] = seq[st0:st0 + ql]
            starts[i] = st0
            q_lens[i] = ql
        # padding rows pose as resumes (q_start=1, q_lens=0): they are
        # dead either way, but must not look like first chunks — a row at
        # chunk 0 forces the model to recompute cross-attention K/V
        starts[len(sel):] = 1

        full_tables = self._tables_array()
        sub_tables = np.full((B,) + full_tables.shape[1:], -1, np.int32)
        sub_tables[:len(slots)] = np.asarray(full_tables)[np.asarray(slots)]

        st = self.state
        sub_state: Dict[str, Any] = {
            "pos": jnp.asarray(starts),
            "k_pages": st["k_pages"],
            "v_pages": st["v_pages"],
            "tables": jnp.asarray(sub_tables),
        }
        for key in ("cross_k", "cross_v"):
            if key in st:
                # resume rows reuse their cached cross-K/V (the model
                # skips the encoder/projection when no row is at chunk 0)
                sub = np.zeros((st[key].shape[0], B) + st[key].shape[2:],
                               st[key].dtype)
                sub[:, :len(slots)] = np.asarray(st[key])[:, np.asarray(slots)]
                sub_state[key] = jnp.asarray(sub)
        extra = self._collect_extra(reqs, pad_to=B)
        logits, new_st = self.model.prefill_chunk(
            self.params, jnp.asarray(batch), sub_state,
            q_start=jnp.asarray(starts), q_lens=jnp.asarray(q_lens),
            extra=extra, impl=self.impl, interpret=self.interpret,
            pages_per_block=self.pages_per_block,
            num_splits=self.num_splits, combine_mode=self.combine_mode,
            backend=self.backend)

        st["k_pages"] = new_st["k_pages"]
        st["v_pages"] = new_st["v_pages"]
        idx = jnp.asarray(slots)
        live = np.arange(len(slots))
        st["pos"] = st["pos"].at[idx].set(
            jnp.asarray((starts + q_lens)[live]))
        for key in ("cross_k", "cross_v"):
            if key in new_st:
                st[key] = st[key].at[:, idx].set(new_st[key][:, live])

        done_rows, done_reqs = [], []
        for i, (_, req, st0, ql) in enumerate(sel):
            req.prefill_pos = st0 + ql
            if req.prefill_pos >= req.total_len:  # last chunk landed
                req.status = Status.RUNNING
                done_rows.append(i)
                done_reqs.append(req)
        self._cache_insert_live([r for _, r, _, _ in sel])
        if done_reqs:
            self._sample_and_append(
                done_reqs, jnp.asarray(logits)[np.asarray(done_rows)],
                first=True)

    def _prefill_contiguous(self, slots, batch, lens, extra, reqs):
        """Baseline prefill: run forward, copy K/V into max-length buffers."""
        # teacher-forced forward to get K/V per layer is implicit: reuse the
        # paged prefill with identity tables into a temporary exact-size pool,
        # then gather into the contiguous buffers.
        cfg = self.cfg
        B, L = batch.shape
        ps = cfg.page_size
        pp = -(-L // ps)
        n_attn = getattr(self.model, "n_attn_layers", 0)
        tmp_tables = jnp.arange(B * pp, dtype=jnp.int32).reshape(B, pp)
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        tmp_state: Dict[str, Any] = {
            "pos": jnp.asarray(lens),
            "k_pages": jnp.zeros((n_attn, B * pp, ps, Hkv, hd), self.dtype),
            "v_pages": jnp.zeros((n_attn, B * pp, ps, Hkv, hd), self.dtype),
            "tables": tmp_tables,
        }
        logits, new_st = self.model.prefill(
            self.params, jnp.asarray(batch), tmp_state,
            lens=jnp.asarray(lens), extra=extra, impl=self.impl)
        from repro.core.cache import gather_layer
        idx = jnp.asarray(slots)
        st = self.state
        for li in range(n_attn):
            k, v = gather_layer(new_st["k_pages"][li], new_st["v_pages"][li],
                                tmp_tables, L)
            st["k_buf"] = st["k_buf"].at[li, idx, :L].set(k)
            st["v_buf"] = st["v_buf"].at[li, idx, :L].set(v)
        st["pos"] = st["pos"].at[idx].set(jnp.asarray(lens))
        for key in ("cross_k", "cross_v"):
            if key in new_st:
                st[key] = st[key].at[:, idx].set(new_st[key])
        if "rec" in new_st:
            st["rec"] = jax.tree_util.tree_map(
                lambda g, s: g.at[:, idx].set(s), st["rec"], new_st["rec"])
        self._sample_and_append(reqs, logits, first=True)

    def _collect_extra(self, reqs: List[Request],
                       pad_to: Optional[int] = None) -> Optional[Dict]:
        extras = [r.metrics.get("_extra") for r in reqs]
        if pad_to is not None:
            extras += [None] * (pad_to - len(extras))
        if not any(e for e in extras):
            return None
        keys = next(e for e in extras if e).keys()
        out = {}
        for k in keys:
            parts = []
            for e in extras:
                if e is None or k not in e:
                    ref = next(x for x in extras if x and k in x)[k]
                    parts.append(np.zeros_like(np.asarray(ref)))
                else:
                    parts.append(np.asarray(e[k]))
            out[k] = jnp.asarray(np.stack(parts))
        return out

    # ------------------------------------------------------------------
    def _decode_fn(self, params, tokens, state):
        return self.model.decode_step(
            params, tokens, state, impl=self.impl, interpret=self.interpret,
            pages_per_block=self.pages_per_block, num_splits=self.num_splits,
            combine_mode=self.combine_mode, backend=self.backend)

    def _decode(self) -> None:
        st = dict(self.state)
        if self.paged and "k_pages" in st:
            # decode=True blanks PREFILLING slots: their pages must not
            # receive the placeholder token's K/V nor be attended over
            st["tables"] = self._tables_array(decode=True)
        tokens = np.zeros((self.max_slots,), np.int32)
        live = np.zeros((self.max_slots,), bool)
        reqs: List[Optional[Request]] = [None] * self.max_slots
        for slot, req in self.scheduler.running.items():
            if req.status is not Status.RUNNING:
                continue  # mid-prefill: not in the decode sub-batch
            seq = req.prompt + req.output
            tokens[slot] = seq[-1]
            live[slot] = True
            reqs[slot] = req

        if self.paged or "k_buf" not in st:
            logits, new_st = self._jit_decode(self.params,
                                              jnp.asarray(tokens), st)
        else:
            logits, new_st = self._decode_contiguous(jnp.asarray(tokens), st)
        # dead slots keep their old pos (decode bumps everyone's)
        mask = jnp.asarray(live)
        new_st["pos"] = jnp.where(mask, new_st["pos"], self.state["pos"])
        if self.paged and "tables" in new_st:
            new_st.pop("tables")  # host-owned, rebuilt each step
        self.state.update(new_st)
        live_reqs = [r for r in reqs if r is not None]
        live_logits = jnp.asarray(logits)[np.where(live)[0]]
        self._sample_and_append(live_reqs, live_logits, first=False)

    def _decode_contiguous(self, tokens, st):
        """Baseline decode path (contiguous buffers, family=dense-ish only)."""
        from repro.models import attention as mattn, layers
        cfg = self.cfg
        m = self.model
        params = self.params
        pos = st["pos"]
        x = layers.embed_tokens(params["embed"], tokens)
        layer_params = m._per_layer_params(params)
        codes = cfg.pattern()
        ai = 0
        new_st = dict(st)
        for li, code in enumerate(codes):
            p = layer_params[li]
            h = layers.apply_norm(p["ln1"], x)
            if code in "AW":
                w = cfg.window if code == "W" else 0
                o, kb, vb = mattn.attn_decode_contiguous(
                    p["attn"], h, cfg, st["k_buf"][ai], st["v_buf"][ai],
                    pos, window=w)
                new_st["k_buf"] = new_st["k_buf"].at[ai].set(kb)
                new_st["v_buf"] = new_st["v_buf"].at[ai].set(vb)
                st = new_st
                ai += 1
                x = x + o
            x, _ = m._apply_ffn(p, x)
        new_st["pos"] = pos + 1
        x = layers.apply_norm(params["ln_f"], x)
        return layers.unembed(params["embed"], x, cfg), new_st

    def _sample_and_append(self, reqs: List[Request], logits: jnp.ndarray,
                           first: bool) -> None:
        logits = jnp.asarray(logits)
        if self.faults is not None and reqs:
            # injected NaN logits: per-row poison, caught by the guard
            bad = [i for i, r in enumerate(reqs)
                   if self.faults.fire("sample", rid=r.rid) == "nan"]
            if bad:
                logits = logits.at[jnp.asarray(bad)].set(jnp.nan)
        if self.numerics_guard and reqs:
            # per-row isolation: a poisoned row (overflowed activations,
            # injected NaN) fails *its* request; survivors sample as if
            # the bad row never existed (their logits depend only on
            # their own KV pages, so outputs are bit-identical — gated
            # by tests/test_faults.py)
            finite = np.asarray(jnp.all(jnp.isfinite(logits), axis=-1))
            if not finite.all():
                for r, ok in zip(reqs, finite):
                    if not ok:
                        self.scheduler.fail(r, NumericsError(
                            "non-finite logits in this request's row "
                            f"(step {self.steps})", rid=r.rid,
                            step=self.steps))
                keep = np.where(finite)[0]
                reqs = [reqs[i] for i in keep]
                logits = logits[jnp.asarray(keep)]
        B = len(reqs)
        if B == 0:
            self.rng, _ = jax.random.split(self.rng)  # keep stream parity
            return
        sp = SampleParams(
            temperature=jnp.asarray([r.temperature for r in reqs], jnp.float32),
            top_k=jnp.asarray([r.top_k for r in reqs], jnp.int32),
            top_p=jnp.asarray([r.top_p for r in reqs], jnp.float32),
        )
        self.rng, key = jax.random.split(self.rng)
        toks = np.asarray(sample(key, logits, sp))
        now = time.perf_counter()
        for r, t in zip(reqs, toks):
            r.output.append(int(t))
            if first and "ttft_s" not in r.metrics:
                r.metrics["ttft_s"] = now - r.metrics["t_arrive"]

    def _finish_done(self) -> List[Request]:
        done = []
        for req in list(self.scheduler.running.values()):
            if req.status is not Status.RUNNING:
                continue  # mid-prefill requests have no fresh sample
            hit_eos = (req.eos_id is not None and req.output
                       and req.output[-1] == req.eos_id)
            if len(req.output) >= req.max_new_tokens or hit_eos:
                req.metrics["t_done"] = time.perf_counter()
                req.metrics["tok_s"] = len(req.output) / max(
                    req.metrics["t_done"] - req.metrics["t_arrive"], 1e-9)
                self.scheduler.finish(req)
                done.append(req)
        return done

    # ------------------------------------------------------------------
    # prefix sharing (paper §III contribution 1: fork + copy-on-write)
    def fork_request(self, src: Request, max_new_tokens: int = 64,
                     **sampling) -> Request:
        """Fork a RUNNING request: the child aliases the parent's *full*
        KV pages (refcount++, zero copies) and gets a fresh copy of the
        partial tail page — the paper's copy-on-write prefix sharing.

        The child enters the batch immediately (no re-prefill of the
        shared prefix) and decodes from the parent's current position.
        """
        if src.status != Status.RUNNING or not self.paged:
            raise InvalidRequest("fork requires a RUNNING request on the "
                                 "paged engine", rid=src.rid)
        if src.total_len + max_new_tokens > self.max_seq_len:
            # the same cap add_request enforces — without it the child's
            # page row outgrows the device table width mid-decode and
            # `_tables_array` (rightly) refuses to truncate it
            raise RequestTooLong("fork child exceeds engine max_seq_len",
                                 rid=src.rid, limit=self.max_seq_len)
        slots = self.scheduler.free_slots()
        if not slots:
            raise PoolExhausted("no free slot for fork", rid=src.rid,
                                resource="slots")
        ps = self.cfg.page_size
        seq = src.prompt + src.output
        # Page math must follow the *cached* length (`mgr.lens`, == the
        # parent's decode position): the last sampled token is not in the
        # pools yet — it is the next decode input.  Sizing by len(seq)
        # skipped the tail copy whenever len(seq) was page-aligned while
        # the cache was still one token short of the boundary, handing the
        # child a never-written tail page.
        cached_len = self.mgr.lens[src.rid]
        full_pages = cached_len // ps
        need_tail = 1 if cached_len % ps else 0
        # available_pages counts detached cached chains (reclaimed on
        # demand inside mgr.reserve), not just the raw free list
        if need_tail + self.scheduler.headroom > self.mgr.available_pages:
            raise PoolExhausted("no pages for fork tail", rid=src.rid,
                                resource="pages")

        child = Request(prompt=list(seq), max_new_tokens=max_new_tokens,
                        parent=src.rid, **sampling)
        child.metrics["t_arrive"] = time.perf_counter()
        # host manager: alias full pages (refcount++), reserve fresh tail.
        # fork is all-or-nothing — on a dry pool it rolls the refcount
        # bumps back and returns False, so a failed fork leaves no
        # half-created child row behind (the headroom check above makes
        # this unreachable in practice, but the engine must not trust it:
        # a False here with the bumps kept would alias live pages).
        if not self.mgr.fork(src.rid, child.rid):
            # replint: disable=allocator-discipline -- fork is all-or-nothing: a False return means its internal rollback already ran
            raise PoolExhausted("no pages for fork tail", rid=src.rid,
                                resource="pages")
        # device: copy the parent's partial tail page into the child's
        if need_tail:
            src_tail = self.mgr.tables[src.rid][full_pages]
            dst_tail = self.mgr.tables[child.rid][full_pages]
            st = self.state
            st["k_pages"] = st["k_pages"].at[:, dst_tail].set(
                st["k_pages"][:, src_tail])
            st["v_pages"] = st["v_pages"].at[:, dst_tail].set(
                st["v_pages"][:, src_tail])
        # enter the running batch at the parent's position
        slot = slots[0]
        child.status = Status.RUNNING
        child.slot = slot
        self.scheduler.running[slot] = child
        src_pos = int(np.asarray(self.state["pos"])[src.slot])
        self.state["pos"] = self.state["pos"].at[slot].set(src_pos)
        for key in ("cross_k", "cross_v"):
            if key in self.state:
                self.state[key] = self.state[key].at[:, slot].set(
                    self.state[key][:, src.slot])
        if "rec" in self.state:
            self.state["rec"] = jax.tree_util.tree_map(
                lambda a: a.at[:, slot].set(a[:, src.slot]),
                self.state["rec"])
        child.metrics["ttft_s"] = 0.0  # prefix shared: no prefill
        return child

    # ------------------------------------------------------------------
    # memory accounting (paper Fig. 1/2 + the <5% overhead objective)
    def memory_report(self) -> Dict[str, float]:
        cfg = self.cfg
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        n_attn = getattr(self.model, "n_attn_layers", 0)
        item = jnp.dtype(self.dtype).itemsize
        if self.paged:
            # pools are int8 under kv_dtype="int8" (see _init_state) — the
            # accounting must use the *pool* dtype, not the activation
            # dtype, or pool_bytes/reserved_bytes overstate 4× and skew
            # the paper's <5 % overhead metric
            pool_dt = jnp.int8 if cfg.kv_dtype == "int8" else self.dtype
            item = jnp.dtype(pool_dt).itemsize
            cache_bytes = (2 * n_attn * self.num_pages * cfg.page_size
                           * Hkv * hd * item)
            reserved = self.mgr.bytes_reserved(Hkv, hd, n_attn, item)
        else:
            cache_bytes = (2 * n_attn * self.max_slots * self.max_seq_len
                           * Hkv * hd * item)
            reserved = cache_bytes
        live_tokens = sum(r.total_len
                          for r in self.scheduler.running.values())
        minimum = live_tokens * 2 * n_attn * Hkv * hd * item
        pc = self.prefix_cache
        return {
            "pool_bytes": float(cache_bytes),
            "reserved_bytes": float(reserved),
            "theoretical_min_bytes": float(minimum),
            "overhead_frac": (reserved / minimum - 1.0) if minimum else 0.0,
            "used_pages": float(self.mgr.used_pages) if self.paged else -1.0,
            # prefix-cache residency: `cached_pages` are indexed in the
            # radix trie; the `reclaimable` subset is evictable on demand
            # (detached chains), i.e. capacity rather than load
            "cached_pages": float(pc.resident_pages) if pc else 0.0,
            "reclaimable_pages": float(pc.reclaimable()) if pc else 0.0,
            "prefix_hit_tokens": float(pc.hit_tokens) if pc else 0.0,
        }
