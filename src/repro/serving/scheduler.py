"""Continuous-batching scheduler with paged admission control + preemption.

The scheduler owns the *host* side of the paper's page manager: a
``HostPageManager`` mirror whose O(1) integer ops decide, off the device
critical path, which requests join the batch (RESERVE), which finish (FREE),
and which get preempted when the pool runs dry mid-decode (the paper's
"reclaim space instantly" requirement, §I-A1).

Policy (vLLM-style):
  * FIFO admission; a request is admitted when a batch slot is free AND the
    pool holds its *first prefill installment* + ``headroom`` decode pages.
    With ``prefill_chunk=None`` (monolithic prefill) the installment is the
    whole prompt; with chunked prefill it is one chunk — admission reserves
    **chunk-by-chunk** instead of all-at-front, so a 32k prompt no longer
    head-of-line-blocks the queue on its full page count (the former code
    reserved ``req.total_len`` pages up front even though chunked prefill
    and ``extend_for_decode`` grow incrementally).
  * chunked mode runs requests through a ``PREFILLING`` state: the engine
    caches ``prefill_chunk`` prompt tokens per step (`grow_prefill`
    reserves each next chunk) and flips the request to ``RUNNING`` when the
    last chunk lands.  A prefill whose next chunk cannot get pages simply
    *stalls* — it keeps its pages and resumes from ``mgr.lens`` once decode
    traffic frees space (no recompute), unless nothing is decoding, in
    which case the youngest other request is preempted to guarantee
    progress.
  * every decode step may need one new page per running sequence; if the
    pool cannot serve a needed page, the *youngest* live request
    (decoding or prefilling) is preempted: its pages are freed instantly
    and it re-queues for a full re-prefill (recompute > swap, as in
    vLLM's default).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.paging import HostPageManager
from repro.errors import (Backpressure, DeadlineExceeded, EngineConfigError,
                          EngineError, PoolExhausted)
from repro.serving.request import Request, Status, TERMINAL

# states that occupy a batch slot (and hold pages)
LIVE = (Status.RUNNING, Status.PREFILLING)


class Scheduler:
    def __init__(self, manager: HostPageManager, max_slots: int,
                 max_seq_len: int, headroom_pages: int = 1,
                 prefill_chunk: Optional[int] = None,
                 max_waiting: Optional[int] = None,
                 admit_watermark: Optional[float] = None,
                 prefix_cache=None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise EngineConfigError("prefill_chunk must be >= 1 (or None)",
                                    prefill_chunk=prefill_chunk)
        if admit_watermark is not None and not 0.0 < admit_watermark <= 1.0:
            raise EngineConfigError(
                "admit_watermark must lie in (0, 1] (or None)",
                admit_watermark=admit_watermark)
        self.mgr = manager
        # global prefix cache (core.prefix_cache.PrefixCache or None):
        # admission attaches new requests to the longest cached prefix,
        # and every release (finish/cancel/preempt) retains the written
        # full pages for future hits
        self.cache = prefix_cache
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.headroom = headroom_pages
        self.prefill_chunk = prefill_chunk
        # admission control (None = unbounded / off, the legacy behavior)
        self.max_waiting = max_waiting
        self.admit_watermark = admit_watermark
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}  # slot -> request
        self.preempted: int = 0
        self.prefill_stalls: int = 0
        # robustness counters + the per-step failure channel the engine
        # drains (requests failed mid-step by deadline/starvation/guard)
        self.shed: int = 0
        self.failed: int = 0
        self.cancelled: int = 0
        self.deadline_misses: int = 0
        self.failed_events: List[Request] = []

    # ------------------------------------------------------------------
    def add(self, req: Request) -> None:
        """Enqueue ``req`` — or shed it with a structured ``Backpressure``.

        Two admission gates (both off by default):
          * bounded wait queue (``max_waiting``): reject-on-full instead
            of unbounded queue growth;
          * pool high-watermark (``admit_watermark``): above this
            utilisation fraction new work is shed *at the door* rather
            than admitted into a pool where it can only thrash
            preemptions.
        Preemption re-queues bypass ``add`` (``_preempt`` re-inserts
        directly): backpressure must never drop a request that already
        made progress.
        """
        if (self.max_waiting is not None
                and len(self.waiting) >= self.max_waiting):
            self.shed += 1
            raise Backpressure(
                f"wait queue full ({len(self.waiting)}/{self.max_waiting})",
                reason="queue_full", rid=req.rid,
                retry_after_steps=max(1, len(self.waiting)),
                queue_depth=len(self.waiting),
                pool_util=self._pool_util())
        util = self._pool_util()
        if self.admit_watermark is not None and util >= self.admit_watermark:
            self.shed += 1
            over = self.mgr.used_pages - int(
                self.admit_watermark * self.mgr.num_pages)
            raise Backpressure(
                f"pool utilisation {util:.2f} >= admission high-watermark "
                f"{self.admit_watermark:.2f}",
                reason="pool_watermark", rid=req.rid,
                retry_after_steps=max(1, over),
                queue_depth=len(self.waiting), pool_util=util)
        req.status = Status.WAITING
        self.waiting.append(req)

    def _pool_util(self) -> float:
        # detached cached pages are reclaimable on demand, so they count
        # as capacity, not load — otherwise a warm cache pins the
        # admission watermark at "full" and sheds everything
        if not self.mgr.num_pages:
            return 0.0
        used = self.mgr.num_pages - self.mgr.available_pages
        return used / self.mgr.num_pages

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.mgr.page_size)

    # ------------------------------------------------------------------
    def admit(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests into free slots while pages allow.

        Returns [(slot, request)] newly admitted.  Monolithic mode admits
        straight to RUNNING (the caller prefills the whole prompt);
        chunked mode admits to PREFILLING with only the first chunk
        reserved.
        """
        admitted = []
        slots = self.free_slots()
        while self.waiting and slots:
            req = self.waiting[0]
            # the tokens this request's prefill must cache (preempted
            # requests re-prefill prompt + generated-so-far)
            target = req.total_len
            matched = 0
            if self.cache is not None:
                # longest-cached-prefix attach: alias the shared pages
                # into this rid's row (refcount++) and prefill only the
                # suffix.  Capped at target-1 so at least one position is
                # always prefilled — sampling needs its logits.
                matched = self.cache.attach(
                    req.rid, req.prompt + req.output,
                    max_tokens=target - 1)
            remaining = target - matched
            first = (remaining if self.prefill_chunk is None
                     else min(self.prefill_chunk, remaining))
            need = (self._pages_for(matched + first)
                    - self._pages_for(matched) + self.headroom)
            ok = need <= self.mgr.available_pages
            if ok:
                # may be refused anyway (injected allocation fault);
                # reserve is all-or-nothing, so only the attach (if any)
                # needs rolling back
                ok = self.mgr.reserve(req.rid, matched + first)
            if not ok:
                if matched:
                    # roll the attach back: the shared pages keep their
                    # cache-residency reference (stay resident, off the
                    # free list) — the admission degrades to a retry
                    # next step with nothing leaked
                    self.mgr.free(req.rid)
                break  # head-of-line blocking keeps FIFO fairness
            self.waiting.pop(0)
            slot = slots.pop(0)
            req.prefill_pos = matched
            req.cached_prefix = matched
            req.status = (Status.RUNNING if self.prefill_chunk is None
                          else Status.PREFILLING)
            req.slot = slot
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    # ------------------------------------------------------------------
    def grow_prefill(self, req: Request,
                     n_tokens: Optional[int] = None) -> bool:
        """Reserve pages for ``req``'s next prefill installment (chunked
        mode).

        ``n_tokens`` is the installment size (defaults to the full
        ``prefill_chunk``); the engine passes each request's slice of the
        *global* per-step token budget, so k concurrent prefills split
        one chunk rather than each reserving a whole one.  Returns True
        when the reservation covers
        ``min(prefill_pos + n_tokens, total_len)`` tokens — the engine
        may then run the installment.  On a dry pool the request
        *stalls* (returns False) and resumes from its cached pages on a
        later step — unless no other request is decoding (nothing would
        ever free pages), in which case the youngest other live request
        is preempted so the batch always makes progress.
        """
        assert self.prefill_chunk is not None, "monolithic mode"
        step = self.prefill_chunk if n_tokens is None else n_tokens
        want = min(req.prefill_pos + step, req.total_len)
        if self.mgr.lens.get(req.rid, 0) >= want:
            return True
        while not self.mgr.reserve(req.rid, want):
            others = [r for r in self.running.values() if r is not req]
            if any(r.status is Status.RUNNING for r in others):
                self.prefill_stalls += 1
                return False  # decodes will finish (or preempt) and free
            if not others:
                # nothing to stall on, nothing to preempt: this request is
                # starved with no recourse (pool genuinely smaller than one
                # sequence, or a persistent injected allocation fault).
                # Fail *it* — the engine, its queue and future admits live.
                self.fail(req, PoolExhausted(
                    "page pool cannot serve a single sequence's prefill "
                    f"({want} tokens) and no preemption candidate exists",
                    rid=req.rid, want_tokens=want,
                    free_pages=len(self.mgr.free_list)))
                return False
            self._preempt(max(others, key=lambda r: r.rid))
        return True

    def extend_for_decode(self) -> List[Request]:
        """Grow every *decoding* sequence by one token; preempt on
        exhaustion.

        Returns the requests preempted this step (their slots are now
        free).  PREFILLING requests are not extended (their growth is
        `grow_prefill`'s job) but they are preemption candidates like
        everyone else — youngest first.

        Preemption safety: victims picked mid-loop may sit *later* in the
        iteration order, so every request is re-checked against the live
        ``running`` set before it is extended.  (The former code iterated
        a snapshot list that preemption could not edit — the rebinding
        ``order = [...]`` never touched the active ``for`` — so
        ``mgr.extend`` ran on rids whose pages were just freed,
        re-reserving a page under a PREEMPTED rid; the stale table row
        then survived ``tables.setdefault`` on re-admission and aliased
        pages concurrently handed to other sequences — silent KV
        corruption.)
        """
        victims: List[Request] = []
        # oldest first when extending, youngest first when picking victims
        for req in sorted(self.running.values(), key=lambda r: r.rid):
            if req.status is not Status.RUNNING or req.slot not in self.running:
                continue  # prefilling, or preempted by an earlier extend
            while not self.mgr.extend(req.rid, 1):
                cand = [r for r in self.running.values()
                        if r.status in LIVE and r is not req]
                if not cand:
                    # alone and still starved: fail this request (pages
                    # released) instead of killing the engine — the next
                    # admit may well fit
                    self.fail(req, PoolExhausted(
                        "page pool cannot extend the only live sequence "
                        "and no preemption candidate exists", rid=req.rid,
                        free_pages=len(self.mgr.free_list)))
                    break
                victim = max(cand, key=lambda r: r.rid)
                self._preempt(victim)
                victims.append(victim)
        return victims

    def _retain_in_cache(self, req: Request) -> None:
        """Index ``req``'s written full pages into the prefix cache before
        its row is freed (retain-on-free): the pages gain a residency
        reference, so the ``mgr.free`` that follows leaves them resident
        instead of recycling them.

        ``written`` must not overrun what the model actually wrote:
        PREFILLING rows' ``mgr.lens`` runs ahead of the prefilled prefix
        (chunks are reserved before they run), and a RUNNING row's last
        sampled token is *not* in the pools yet (it is the next decode
        input — the same off-by-one ``fork_request`` sizes its tail by).
        """
        if self.cache is None or req.rid not in self.mgr.tables:
            return
        if req.status is Status.PREFILLING:
            written = req.prefill_pos
        else:
            written = min(self.mgr.lens.get(req.rid, 0), req.total_len - 1)
        self.cache.insert(req.prompt + req.output,
                          self.mgr.tables[req.rid], written)

    def _preempt(self, req: Request) -> None:
        # retain-then-free: the preempted prefix stays cached, so the
        # re-admission re-attaches to it and re-prefills almost nothing
        self._retain_in_cache(req)
        self.mgr.free(req.rid)
        del self.running[req.slot]
        req.slot = -1
        req.prefill_pos = 0  # cached pages are gone: re-prefill from 0
        req.status = Status.PREEMPTED
        # preempted requests restart with prompt+generated so far as prompt
        self.waiting.insert(0, req)
        self.preempted += 1

    def finish(self, req: Request) -> None:
        self._remove(req)
        req.status = Status.FINISHED

    # ------------------------------------------------------------------
    # fault isolation: per-request teardown (FAILED / CANCELLED)
    def _remove(self, req: Request, retain: bool = True) -> None:
        """Release everything ``req`` holds: queue position, batch slot,
        pages + block-table row.  Safe in every state (WAITING holds no
        pages; PREEMPTED holds neither pages nor slot).

        ``retain=True`` indexes the written full pages into the prefix
        cache first (finish/cancel/preempt paths — multi-turn reuse);
        failure teardown passes ``retain=False`` so a request whose row
        may hold poisoned K/V (NaN guard) never seeds the cache."""
        if req in self.waiting:
            self.waiting.remove(req)
        if self.running.get(req.slot) is req:
            del self.running[req.slot]
        if req.rid in self.mgr.tables:
            if retain:
                self._retain_in_cache(req)
            self.mgr.free(req.rid)
        req.slot = -1

    def fail(self, req: Request, err: EngineError) -> None:
        """Terminal per-request failure: resources released, structured
        error attached, batch-mates untouched.  The engine drains
        ``failed_events`` each step to report terminal requests."""
        self._remove(req, retain=False)
        req.error = err
        req.status = Status.FAILED
        self.failed += 1
        self.failed_events.append(req)

    def cancel(self, req: Request) -> bool:
        """Tear ``req`` down in any non-terminal state (WAITING,
        PREFILLING mid-chunk, RUNNING, PREEMPTED, stalled-on-dry-pool).
        Returns False if it was already terminal."""
        if req.status in TERMINAL:
            return False
        self._remove(req)
        req.status = Status.CANCELLED
        self.cancelled += 1
        return True

    def check_deadlines(self, now_step: int) -> List[Request]:
        """Fail every queued/live request past its step budget.

        ``deadline_steps`` bounds arrival → terminal; ``ttft_deadline_steps``
        bounds arrival → first token.  Enforcing in the scheduler (not per
        client) means a request stuck WAITING behind backpressure, stalled
        mid-prefill, or thrashing through preemptions is cut loose the
        moment its budget expires — pages freed for work that can still
        meet its deadline.
        """
        expired: List[Request] = []
        for req in list(self.waiting) + list(self.running.values()):
            start = req.metrics.get("step_arrive")
            if start is None:
                continue
            waited = now_step - start
            if (req.deadline_steps is not None
                    and waited >= req.deadline_steps):
                why = (f"exceeded deadline of {req.deadline_steps} engine "
                       f"steps (waited {waited})")
                budget = req.deadline_steps
            elif (req.ttft_deadline_steps is not None and not req.output
                    and waited >= req.ttft_deadline_steps):
                why = (f"no first token within TTFT budget of "
                       f"{req.ttft_deadline_steps} engine steps")
                budget = req.ttft_deadline_steps
            else:
                continue
            self.fail(req, DeadlineExceeded(
                why, rid=req.rid, waited_steps=waited, budget_steps=budget,
                status_at_expiry=req.status.value))
            self.deadline_misses += 1
            expired.append(req)
        return expired

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
