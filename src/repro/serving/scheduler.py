"""Continuous-batching scheduler with paged admission control + preemption.

The scheduler owns the *host* side of the paper's page manager: a
``HostPageManager`` mirror whose O(1) integer ops decide, off the device
critical path, which requests join the batch (RESERVE), which finish (FREE),
and which get preempted when the pool runs dry mid-decode (the paper's
"reclaim space instantly" requirement, §I-A1).

Policy (vLLM-style):
  * FIFO admission; a request is admitted when a batch slot is free AND the
    pool holds its *first prefill installment* + ``headroom`` decode pages.
    With ``prefill_chunk=None`` (monolithic prefill) the installment is the
    whole prompt; with chunked prefill it is one chunk — admission reserves
    **chunk-by-chunk** instead of all-at-front, so a 32k prompt no longer
    head-of-line-blocks the queue on its full page count (the former code
    reserved ``req.total_len`` pages up front even though chunked prefill
    and ``extend_for_decode`` grow incrementally).
  * chunked mode runs requests through a ``PREFILLING`` state: the engine
    caches ``prefill_chunk`` prompt tokens per step (`grow_prefill`
    reserves each next chunk) and flips the request to ``RUNNING`` when the
    last chunk lands.  A prefill whose next chunk cannot get pages simply
    *stalls* — it keeps its pages and resumes from ``mgr.lens`` once decode
    traffic frees space (no recompute), unless nothing is decoding, in
    which case the youngest other request is preempted to guarantee
    progress.
  * every decode step may need one new page per running sequence; if the
    pool cannot serve a needed page, the *youngest* live request
    (decoding or prefilling) is preempted: its pages are freed instantly
    and it re-queues for a full re-prefill (recompute > swap, as in
    vLLM's default).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.paging import HostPageManager
from repro.serving.request import Request, Status

# states that occupy a batch slot (and hold pages)
LIVE = (Status.RUNNING, Status.PREFILLING)


class Scheduler:
    def __init__(self, manager: HostPageManager, max_slots: int,
                 max_seq_len: int, headroom_pages: int = 1,
                 prefill_chunk: Optional[int] = None):
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1 (or None)")
        self.mgr = manager
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.headroom = headroom_pages
        self.prefill_chunk = prefill_chunk
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}  # slot -> request
        self.preempted: int = 0
        self.prefill_stalls: int = 0

    # ------------------------------------------------------------------
    def add(self, req: Request) -> None:
        req.status = Status.WAITING
        self.waiting.append(req)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.mgr.page_size)

    # ------------------------------------------------------------------
    def admit(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests into free slots while pages allow.

        Returns [(slot, request)] newly admitted.  Monolithic mode admits
        straight to RUNNING (the caller prefills the whole prompt);
        chunked mode admits to PREFILLING with only the first chunk
        reserved.
        """
        admitted = []
        slots = self.free_slots()
        while self.waiting and slots:
            req = self.waiting[0]
            # the tokens this request's prefill must cache (preempted
            # requests re-prefill prompt + generated-so-far)
            target = req.total_len
            first = (target if self.prefill_chunk is None
                     else min(self.prefill_chunk, target))
            need = self._pages_for(first) + self.headroom
            if need > len(self.mgr.free_list):
                break  # head-of-line blocking keeps FIFO fairness
            self.waiting.pop(0)
            slot = slots.pop(0)
            ok = self.mgr.reserve(req.rid, first)
            assert ok, "capacity was checked above"
            req.prefill_pos = 0
            req.status = (Status.RUNNING if self.prefill_chunk is None
                          else Status.PREFILLING)
            req.slot = slot
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    # ------------------------------------------------------------------
    def grow_prefill(self, req: Request) -> bool:
        """Reserve pages for ``req``'s next prefill chunk (chunked mode).

        Returns True when the reservation covers
        ``min(prefill_pos + prefill_chunk, total_len)`` tokens — the
        engine may then run the chunk.  On a dry pool the request
        *stalls* (returns False) and resumes from its cached pages on a
        later step — unless no other request is decoding (nothing would
        ever free pages), in which case the youngest other live request
        is preempted so the batch always makes progress.
        """
        assert self.prefill_chunk is not None, "monolithic mode"
        want = min(req.prefill_pos + self.prefill_chunk, req.total_len)
        if self.mgr.lens.get(req.rid, 0) >= want:
            return True
        while not self.mgr.reserve(req.rid, want):
            others = [r for r in self.running.values() if r is not req]
            if any(r.status is Status.RUNNING for r in others):
                self.prefill_stalls += 1
                return False  # decodes will finish (or preempt) and free
            if not others:
                raise RuntimeError(
                    "page pool too small for a single sequence's prefill")
            self._preempt(max(others, key=lambda r: r.rid))
        return True

    def extend_for_decode(self) -> List[Request]:
        """Grow every *decoding* sequence by one token; preempt on
        exhaustion.

        Returns the requests preempted this step (their slots are now
        free).  PREFILLING requests are not extended (their growth is
        `grow_prefill`'s job) but they are preemption candidates like
        everyone else — youngest first.

        Preemption safety: victims picked mid-loop may sit *later* in the
        iteration order, so every request is re-checked against the live
        ``running`` set before it is extended.  (The former code iterated
        a snapshot list that preemption could not edit — the rebinding
        ``order = [...]`` never touched the active ``for`` — so
        ``mgr.extend`` ran on rids whose pages were just freed,
        re-reserving a page under a PREEMPTED rid; the stale table row
        then survived ``tables.setdefault`` on re-admission and aliased
        pages concurrently handed to other sequences — silent KV
        corruption.)
        """
        victims: List[Request] = []
        # oldest first when extending, youngest first when picking victims
        for req in sorted(self.running.values(), key=lambda r: r.rid):
            if req.status is not Status.RUNNING or req.slot not in self.running:
                continue  # prefilling, or preempted by an earlier extend
            while not self.mgr.extend(req.rid, 1):
                cand = [r for r in self.running.values()
                        if r.status in LIVE and r is not req]
                if not cand:
                    raise RuntimeError(
                        "page pool too small for a single sequence")
                victim = max(cand, key=lambda r: r.rid)
                self._preempt(victim)
                victims.append(victim)
        return victims

    def _preempt(self, req: Request) -> None:
        self.mgr.free(req.rid)
        del self.running[req.slot]
        req.slot = -1
        req.prefill_pos = 0  # cached pages are gone: re-prefill from 0
        req.status = Status.PREEMPTED
        # preempted requests restart with prompt+generated so far as prompt
        self.waiting.insert(0, req)
        self.preempted += 1

    def finish(self, req: Request) -> None:
        self.mgr.free(req.rid)
        if req.slot in self.running and self.running[req.slot] is req:
            del self.running[req.slot]
        req.slot = -1
        req.status = Status.FINISHED

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
