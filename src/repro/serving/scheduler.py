"""Continuous-batching scheduler with paged admission control + preemption.

The scheduler owns the *host* side of the paper's page manager: a
``HostPageManager`` mirror whose O(1) integer ops decide, off the device
critical path, which requests join the batch (RESERVE), which finish (FREE),
and which get preempted when the pool runs dry mid-decode (the paper's
"reclaim space instantly" requirement, §I-A1).

Policy (vLLM-style):
  * FIFO admission; a request is admitted when a batch slot is free AND the
    pool holds its prompt pages + ``headroom`` decode pages.
  * every decode step may need one new page per running sequence; if the
    pool cannot serve a needed page, the *youngest* running request is
    preempted: its pages are freed instantly and it re-queues for a full
    re-prefill (recompute > swap, as in vLLM's default).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.paging import HostPageManager
from repro.serving.request import Request, Status


class Scheduler:
    def __init__(self, manager: HostPageManager, max_slots: int,
                 max_seq_len: int, headroom_pages: int = 1):
        self.mgr = manager
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.headroom = headroom_pages
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}  # slot -> request
        self.preempted: int = 0

    # ------------------------------------------------------------------
    def add(self, req: Request) -> None:
        req.status = Status.WAITING
        self.waiting.append(req)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.running]

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.mgr.page_size)

    # ------------------------------------------------------------------
    def admit(self) -> List[Tuple[int, Request]]:
        """Admit waiting requests into free slots while pages allow.

        Returns [(slot, request)] newly admitted (they need a prefill pass).
        """
        admitted = []
        slots = self.free_slots()
        while self.waiting and slots:
            req = self.waiting[0]
            need = self._pages_for(req.total_len) + self.headroom
            if need > len(self.mgr.free_list):
                break  # head-of-line blocking keeps FIFO fairness
            self.waiting.pop(0)
            slot = slots.pop(0)
            ok = self.mgr.reserve(req.rid, req.total_len)
            assert ok, "capacity was checked above"
            req.status = Status.RUNNING
            req.slot = slot
            self.running[slot] = req
            admitted.append((slot, req))
        return admitted

    def extend_for_decode(self) -> List[Request]:
        """Grow every running sequence by one token; preempt on exhaustion.

        Returns the requests preempted this step (their slots are now free).

        Preemption safety: victims picked mid-loop may sit *later* in the
        iteration order, so every request is re-checked against the live
        ``running`` set before it is extended.  (The former code iterated
        a snapshot list that preemption could not edit — the rebinding
        ``order = [...]`` never touched the active ``for`` — so
        ``mgr.extend`` ran on rids whose pages were just freed,
        re-reserving a page under a PREEMPTED rid; the stale table row
        then survived ``tables.setdefault`` on re-admission and aliased
        pages concurrently handed to other sequences — silent KV
        corruption.)
        """
        victims: List[Request] = []
        # oldest first when extending, youngest first when picking victims
        for req in sorted(self.running.values(), key=lambda r: r.rid):
            if req.status is not Status.RUNNING or req.slot not in self.running:
                continue  # preempted by an earlier extend — pages are freed
            while not self.mgr.extend(req.rid, 1):
                cand = [r for r in self.running.values()
                        if r.status is Status.RUNNING and r is not req]
                if not cand:
                    raise RuntimeError(
                        "page pool too small for a single sequence")
                victim = max(cand, key=lambda r: r.rid)
                self._preempt(victim)
                victims.append(victim)
        return victims

    def _preempt(self, req: Request) -> None:
        self.mgr.free(req.rid)
        del self.running[req.slot]
        req.slot = -1
        req.status = Status.PREEMPTED
        # preempted requests restart with prompt+generated so far as prompt
        self.waiting.insert(0, req)
        self.preempted += 1

    def finish(self, req: Request) -> None:
        self.mgr.free(req.rid)
        if req.slot in self.running and self.running[req.slot] is req:
            del self.running[req.slot]
        req.slot = -1
        req.status = Status.FINISHED

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
