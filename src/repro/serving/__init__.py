from repro.serving.engine import Engine
from repro.serving.request import Request, Status
from repro.serving.sampler import SampleParams, sample
from repro.serving.scheduler import Scheduler
