from repro.errors import (Backpressure, DeadlineExceeded, EngineError,
                          InternalError, InvalidRequest, NumericsError,
                          PoolExhausted, RequestTooLong,
                          SchedulerInvariantError, TransientDeviceError)
from repro.serving.engine import Engine
from repro.serving.faults import FaultPlan, FaultRule, FaultyPageManager
from repro.serving.request import Request, Status
from repro.serving.sampler import SampleParams, sample, validate_sample_params
from repro.serving.scheduler import Scheduler
