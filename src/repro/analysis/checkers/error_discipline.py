"""error-discipline: failures must speak the structured taxonomy.

Scope: ``src/repro/{serving,core,distributed,models}`` — the layers whose
failures are routed per request by the fault-tolerant engine (PR 6).

Checks:

  1. no bare builtin raises (``ValueError``, ``RuntimeError``, ...): every
     raise must construct a ``repro.errors`` type (resolved through the
     file's imports), so callers can catch ``EngineError`` and route it;
  2. no silent except-swallow: an ``except:`` whose body is only
     ``pass``/``continue``/``...`` hides the failure from the engine's
     per-request error routing;
  3. rid discipline: a structured raise inside a function that has a
     request id in scope (a ``rid``/``seq_id``/``req`` parameter) must
     carry ``rid=`` so the engine can fail *that* request.
"""

from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.core import (FileContext, Finding, Project, attr_last,
                                 attr_root, register)

BANNED_BUILTINS = {"ValueError", "RuntimeError", "KeyError", "TypeError",
                   "NotImplementedError", "Exception", "AssertionError",
                   "IndexError", "OSError", "IOError"}

_RID_PARAMS = {"rid", "seq_id", "req"}


def _errors_names(ctx: FileContext) -> Set[str]:
    """Names this file imported from ``repro.errors`` (plus module-alias
    access like ``errors.PoolExhausted``)."""
    names: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.ImportFrom) and node.module == "repro.errors":
            names.update(a.asname or a.name for a in node.names)
        elif isinstance(node, ast.ImportFrom) and node.module == "repro":
            names.update(a.asname or a.name for a in node.names
                         if a.name == "errors")
        elif isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "repro.errors":
                    names.add((a.asname or "repro.errors").split(".")[0])
    return names


def _local_error_classes(ctx: FileContext, errors_names: Set[str]) -> Set[str]:
    """Classes defined in-file whose base chains reach a taxonomy name."""
    out: Set[str] = set()
    classes = {n.name: n for n in ast.walk(ctx.tree)
               if isinstance(n, ast.ClassDef)}
    changed = True
    while changed:
        changed = False
        for name, node in classes.items():
            if name in out:
                continue
            for base in node.bases:
                b = attr_last(base)
                if b in errors_names or b in out:
                    out.add(name)
                    changed = True
                    break
    return out


def _enclosing_function(node: ast.AST):
    cur = getattr(node, "_replint_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = getattr(cur, "_replint_parent", None)
    return None


def _has_rid_in_scope(fn) -> bool:
    if fn is None:
        return False
    a = fn.args
    params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    return bool(params & _RID_PARAMS)


@register(
    "error-discipline",
    "raises come from repro.errors (with rid= when in scope); "
    "no silent except-swallow",
    dirs=("serving", "core", "distributed", "models"),
)
def check(ctx: FileContext, project: Project) -> List[Finding]:
    out: List[Finding] = []
    errors_names = _errors_names(ctx)
    local_errors = _local_error_classes(ctx, errors_names)

    def finding(node: ast.AST, msg: str) -> None:
        out.append(Finding(rule="error-discipline", path=ctx.path,
                           line=node.lineno, col=node.col_offset,
                           symbol=ctx.qualname(node), message=msg))

    for node in ast.walk(ctx.tree):
        # 1 + 3: raise statements
        if isinstance(node, ast.Raise):
            exc = node.exc
            if exc is None:
                continue  # bare re-raise: fine
            if isinstance(exc, ast.Name):
                continue  # `raise e` of a caught exception: fine
            if not isinstance(exc, ast.Call):
                continue
            name = attr_last(exc.func)
            root = attr_root(exc.func)
            structured = (name in errors_names or name in local_errors
                          or root in errors_names)
            if name in BANNED_BUILTINS and not structured:
                finding(node, f"bare `raise {name}` — raise a structured "
                              f"repro.errors type instead")
                continue
            if not structured:
                finding(node, f"`raise {name}` does not come from "
                              f"repro.errors — use (or add) a taxonomy "
                              f"type so callers can route it")
                continue
            # 3: rid must travel when one is in scope
            fn = _enclosing_function(node)
            if _has_rid_in_scope(fn):
                has_rid = any(kw.arg in ("rid", None)
                              for kw in exc.keywords)
                if not has_rid:
                    finding(node, f"structured raise of {name} inside "
                                  f"'{fn.name}' has a request id in scope "
                                  f"but does not pass rid=")

        # 2: silent except-swallow
        elif isinstance(node, ast.ExceptHandler):
            body = node.body
            swallowed = all(
                isinstance(s, (ast.Pass, ast.Continue)) or
                (isinstance(s, ast.Expr) and
                 isinstance(s.value, ast.Constant))
                for s in body)
            if swallowed:
                finding(node, "silent except-swallow: handler only "
                              "passes — re-raise, convert to a "
                              "repro.errors type, or record the failure")
    return out
