"""knob-threading: kernel knobs must flow through every layer.

The serving stack threads a fixed set of tuning knobs end to end
(kernel -> ops -> core -> models -> Engine):

    backend, combine_mode, interpret, pages_per_block, num_splits,
    q_block, prefill_chunk

A function that *accepts* one of these and calls a callee that *also
accepts it* without forwarding it silently pins the callee to its default
— the bug class behind PR 5's per-shape recompile stall (a dropped
``pages_per_block`` re-tuned every call).  This is a call-graph pass over
the project's signature index:

  * callees are resolved by bare name against every def in the analyzed
    file set; a knob is only *required* when every candidate of that name
    accepts it (ambiguity never produces a finding);
  * a knob counts as forwarded when passed by keyword, covered by a
    positional argument (per any candidate's parameter order), or when the
    call splats ``**kwargs``;
  * intentional drops carry ``# replint: disable=knob-threading -- reason``.
"""

from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import (FileContext, Finding, Project, attr_last,
                                 register)

KNOBS = ("backend", "combine_mode", "interpret", "pages_per_block",
         "num_splits", "q_block", "prefill_chunk")

# call targets that are never knob-threading edges: stdlib/jax plumbing
# whose params coincidentally shadow knob names
_IGNORED_CALLEES = {"partial", "jit", "get", "pop", "setdefault"}


def _knob_params(node) -> set:
    a = node.args
    names = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
    return names & set(KNOBS)


def _call_covers(call: ast.Call, knob: str, project: Project,
                 callee: str) -> bool:
    """Does this call pass ``knob`` (kw, **splat, or positionally)?"""
    for kw in call.keywords:
        if kw.arg == knob:
            return True
        if kw.arg is None:  # **splat forwards everything
            return True
    if any(isinstance(a, ast.Starred) for a in call.args):
        return True  # *splat may cover any position
    n_pos = len(call.args)
    for sig in project.signatures.get(callee, ()):
        if knob in sig.positional:
            # account for bound `self` on method calls (obj.m(...))
            offset = 1 if (sig.positional and
                           sig.positional[0] in ("self", "cls") and
                           isinstance(call.func, ast.Attribute)) else 0
            if sig.positional.index(knob) - offset < n_pos:
                return True
    return False


@register(
    "knob-threading",
    "registered kernel knobs must be forwarded to knob-accepting callees",
)
def check(ctx: FileContext, project: Project) -> List[Finding]:
    out: List[Finding] = []
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        knobs = _knob_params(fn)
        if not knobs:
            continue
        symbol = ctx.qualname(fn)
        # walk the whole body, including closures: a nested helper still
        # closes over the enclosing function's knob parameters
        for call in ast.walk(fn):
            if not isinstance(call, ast.Call):
                continue
            callee = attr_last(call.func)
            if not callee or callee in _IGNORED_CALLEES \
                    or callee == fn.name:
                continue
            candidates = project.signatures.get(callee)
            if not candidates:
                continue
            # knob required only if EVERY candidate def accepts it
            required = knobs & set.intersection(
                *(sig.params for sig in candidates))
            for knob in sorted(required):
                if not _call_covers(call, knob, project, callee):
                    out.append(Finding(
                        rule="knob-threading", path=ctx.path,
                        line=call.lineno, col=call.col_offset,
                        symbol=symbol,
                        message=f"'{symbol}' accepts knob '{knob}' but "
                                f"calls '{callee}' (which accepts it) "
                                f"without forwarding it"))
    return out
