"""tracer-safety: no host/trace confusion inside kernels and jitted steps.

Target functions:

  * **kernel bodies** — any function passed (directly or through
    ``functools.partial``) as the first argument of a ``pl.pallas_call``.
    Static values are the kw-only parameters (``functools.partial`` binds
    them at trace time); everything positional is a Ref / traced value,
    as is anything derived from ``pl.program_id``/``pl.num_programs``.
  * **jitted step functions** — defs decorated with ``jax.jit`` (or
    ``functools.partial(jax.jit, static_argnames=...)``), or referenced by
    name in a ``jax.jit(fn, ...)`` call in the same file.  Static values
    are the declared ``static_argnames``.

Checks, inside a target function:

  1. Python ``if``/``while`` on a traced value (concretization error at
     trace time at best, silently-stale specialization at worst — use
     ``jnp.where``/``lax.cond``/``pl.when``);
  2. host escapes: ``.item()``, ``float()``/``int()``/``bool()`` on a
     traced value, and ``np.*`` calls fed a traced value (``np.*`` on
     static shapes/scalars is fine — that is host-side planning);
  3. the int8-pool contract: a kernel that declares a ``kv_scale``
     parameter must actually apply it to the gathered K/V tiles — a
     kernel that reads int8 pages and never multiplies by ``kv_scale``
     returns garbage at int8 serving time.

Taint tracking is a per-function fixpoint over simple assignments;
``.shape``/``.ndim``/``.dtype`` reads and ``len()`` are static (shape
math on traced arrays is host-side and legal).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import (FileContext, Finding, Project, attr_last,
                                 attr_root, dotted_name, kwarg, register,
                                 resolve_name, scope_env)

_STATIC_ATTRS = {"shape", "ndim", "dtype"}
_STATIC_CALLS = {"len", "range", "isinstance", "getattr", "hasattr", "type"}
# structured-control-flow primitives whose carries are always traced:
# the loop body's parameters and the loop's result are traced values
# even when the init operand is a Python constant
_LOOP_CALLS = {"fori_loop", "scan", "while_loop"}


# ---------------------------------------------------------------------------
# target discovery
# ---------------------------------------------------------------------------
def _kernel_defs(ctx: FileContext) -> Dict[str, ast.FunctionDef]:
    """Defs passed as the kernel (first arg) of a pallas_call, resolved
    through local variables and functools.partial."""
    defs = {n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    out: Dict[str, ast.FunctionDef] = {}
    for call in ast.walk(ctx.tree):
        if not (isinstance(call, ast.Call)
                and attr_last(call.func) == "pallas_call" and call.args):
            continue
        env = scope_env(ctx, call)
        target = resolve_name(env, call.args[0])
        if isinstance(target, ast.Call) and \
                attr_last(target.func) == "partial" and target.args:
            target = resolve_name(env, target.args[0])
        if isinstance(target, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out[target.name] = defs.get(target.name, target)
    return out


def _jit_static_names(call: ast.Call) -> Set[str]:
    names: Set[str] = set()
    sa = kwarg(call, "static_argnames")
    if isinstance(sa, (ast.Tuple, ast.List)):
        names = {e.value for e in sa.elts
                 if isinstance(e, ast.Constant) and isinstance(e.value, str)}
    elif isinstance(sa, ast.Constant) and isinstance(sa.value, str):
        names = {sa.value}
    return names


def _jitted_defs(ctx: FileContext) -> Dict[str, Tuple[ast.FunctionDef,
                                                      Set[str]]]:
    """name -> (def, static_argnames) for every jit-wrapped function."""
    defs = {n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    out: Dict[str, Tuple[ast.FunctionDef, Set[str]]] = {}

    for node in ast.walk(ctx.tree):
        # decorated defs
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if dotted_name(dec) in ("jax.jit", "jit"):
                    out[node.name] = (node, set())
                elif isinstance(dec, ast.Call):
                    dn = dotted_name(dec.func)
                    if dn in ("jax.jit", "jit"):
                        out[node.name] = (node, _jit_static_names(dec))
                    elif attr_last(dec.func) == "partial" and dec.args \
                            and dotted_name(dec.args[0]) in ("jax.jit",
                                                             "jit"):
                        out[node.name] = (node, _jit_static_names(dec))
        # jax.jit(fn, ...) call references
        elif isinstance(node, ast.Call) and \
                dotted_name(node.func) in ("jax.jit", "jit") and node.args:
            name = attr_last(node.args[0])
            if name in defs:
                out[name] = (defs[name], _jit_static_names(node))
    return out


# ---------------------------------------------------------------------------
# taint
# ---------------------------------------------------------------------------
def _is_program_id(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and \
        attr_last(node.func) in ("program_id", "num_programs")


def _tainted_expr(node: ast.AST, tainted: Set[str]) -> bool:
    """Does evaluating ``node`` observe a traced value?"""
    if node is None:
        return False
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False  # shape math is host-side and static
        return _tainted_expr(node.value, tainted)
    if isinstance(node, ast.Subscript):
        return _tainted_expr(node.value, tainted) or \
            _tainted_expr(node.slice, tainted)
    if _is_program_id(node):
        return True
    if isinstance(node, ast.Call):
        if attr_last(node.func) in _LOOP_CALLS:
            return True  # the carry is traced even from a constant init
        if attr_last(node.func) in _STATIC_CALLS:
            return False
        return any(_tainted_expr(a, tainted) for a in node.args) or \
            any(_tainted_expr(kw.value, tainted) for kw in node.keywords) \
            or _tainted_expr(node.func, tainted)
    if isinstance(node, (ast.Constant, ast.Lambda)):
        return False
    return any(_tainted_expr(c, tainted) for c in ast.iter_child_nodes(node))


def _target_names(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for e in node.elts:
            out.extend(_target_names(e))
        return out
    if isinstance(node, ast.Starred):
        return _target_names(node.value)
    return []


def _compute_taint(fn: ast.AST, static: Set[str],
                   kernel_mode: bool) -> Set[str]:
    a = fn.args
    tainted: Set[str] = set()
    for p in a.posonlyargs + a.args:
        if p.arg not in ("self", "cls") and p.arg not in static:
            tainted.add(p.arg)
    if a.vararg is not None:  # kernels take *refs
        tainted.add(a.vararg.arg)
    if kernel_mode:
        # nested helpers (fori_loop bodies, pl.when closures) receive
        # traced carries/operands positionally
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                na = node.args
                tainted.update(p.arg for p in na.posonlyargs + na.args)

    # lax.fori_loop/scan/while_loop body closures receive traced
    # carries/operands positionally in *any* traced function — taint the
    # parameters of every function operand of a loop call, resolved
    # through local defs, lambdas and functools.partial
    local_defs = {n.name: n for n in ast.walk(fn)
                  if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and n is not fn}
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and attr_last(node.func) in _LOOP_CALLS):
            continue
        operands = list(node.args) + [kw.value for kw in node.keywords]
        for arg in operands:
            if isinstance(arg, ast.Call) and \
                    attr_last(arg.func) == "partial" and arg.args:
                arg = arg.args[0]
            target: Optional[ast.AST] = None
            if isinstance(arg, ast.Lambda):
                target = arg
            elif isinstance(arg, ast.Name) and arg.id in local_defs:
                target = local_defs[arg.id]
            if target is not None:
                ta = target.args
                tainted.update(p.arg for p in ta.posonlyargs + ta.args)

    for _ in range(10):
        before = len(tainted)
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if _tainted_expr(node.value, tainted):
                    for t in node.targets:
                        tainted.update(_target_names(t))
            elif isinstance(node, ast.AugAssign):
                if _tainted_expr(node.value, tainted) or \
                        _tainted_expr(node.target, tainted):
                    tainted.update(_target_names(node.target))
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                if _tainted_expr(node.value, tainted):
                    tainted.update(_target_names(node.target))
            elif isinstance(node, ast.For):
                if _tainted_expr(node.iter, tainted):
                    tainted.update(_target_names(node.target))
        if len(tainted) == before:
            break
    return tainted


# ---------------------------------------------------------------------------
# the check
# ---------------------------------------------------------------------------
def _check_fn(ctx: FileContext, fn: ast.AST, static: Set[str],
              kernel_mode: bool) -> List[Finding]:
    out: List[Finding] = []
    symbol = ctx.qualname(fn)
    tainted = _compute_taint(fn, static, kernel_mode)

    def finding(node: ast.AST, msg: str) -> None:
        out.append(Finding(rule="tracer-safety", path=ctx.path,
                           line=node.lineno, col=node.col_offset,
                           symbol=symbol, message=msg))

    kv_scale_read = False
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            if _tainted_expr(node.test, tainted):
                kind = "if" if isinstance(node, ast.If) else "while"
                finding(node, f"Python `{kind}` on a traced value — "
                              f"use jnp.where / lax.cond / pl.when")
        elif isinstance(node, ast.Call):
            name = attr_last(node.func)
            if name == "item" and isinstance(node.func, ast.Attribute):
                finding(node, "`.item()` host escape inside a traced "
                              "function forces a device sync")
            elif name in ("float", "int", "bool") and \
                    isinstance(node.func, ast.Name) and node.args and \
                    _tainted_expr(node.args[0], tainted):
                finding(node, f"`{name}()` on a traced value is a host "
                              f"escape — keep it as a jax scalar")
            elif isinstance(node.func, ast.Attribute) and \
                    attr_root(node.func) in ("np", "numpy") and \
                    any(_tainted_expr(arg, tainted) for arg in node.args):
                finding(node, f"np.{node.func.attr}() on a traced value "
                              f"escapes the trace — use jnp")
        if isinstance(node, ast.Name) and node.id == "kv_scale" and \
                isinstance(node.ctx, ast.Load):
            kv_scale_read = True

    if kernel_mode:
        a = fn.args
        params = {p.arg for p in a.posonlyargs + a.args + a.kwonlyargs}
        if "kv_scale" in params and not kv_scale_read:
            finding(fn, "kernel declares `kv_scale` but never applies it "
                        "— int8 pool reads would stay unscaled")
    return out


@register(
    "tracer-safety",
    "no Python control flow / host escapes on traced values in kernels "
    "and jitted steps; int8 reads apply kv_scale",
)
def check(ctx: FileContext, project: Project) -> List[Finding]:
    out: List[Finding] = []
    kernels = _kernel_defs(ctx)
    jitted = _jitted_defs(ctx)
    for name, fn in kernels.items():
        out.extend(_check_fn(ctx, fn, set(), kernel_mode=True))
    for name, (fn, static) in jitted.items():
        if name in kernels:
            continue
        out.extend(_check_fn(ctx, fn, static, kernel_mode=False))
    return out
