"""Built-in replint checkers.

Importing this package registers every rule into
``repro.analysis.core.RULES`` (each module calls ``@register`` at import
time).  ``analyze_paths`` imports it before selecting rules, so rules are
always available to the driver and to tests.
"""

from repro.analysis import shapes, statemachine  # noqa: F401
from repro.analysis.checkers import (allocator_discipline,  # noqa: F401
                                     error_discipline, knob_threading,
                                     pallas_contract, tracer_safety)

__all__ = [
    "allocator_discipline",
    "error_discipline",
    "knob_threading",
    "pallas_contract",
    "shapes",
    "statemachine",
    "tracer_safety",
]
