"""allocator-discipline: the refcount invariant has exactly two owners.

The serving allocator's generalized invariant (PR 7) is

    refcount[p] == occurrences of p across table rows + (1 if cache-resident)

and it only stays provable because *every* mutation of ``refcount`` lives
inside ``HostPageManager`` or ``PrefixCache`` methods.  Checks:

  1. any assignment / augmented assignment / mutating method call on a
     ``refcount`` attribute outside those classes is a violation — callers
     go through ``reserve``/``free``/``fork``/``attach``/``insert``;
  2. rollback-before-raise: a function that calls an allocator mutator
     (``reserve``/``extend``/``attach``/``insert``/``fork`` on an
     allocator receiver) and can still raise *afterwards* must contain a
     rollback path — an undo call (``free``/``release``/``reclaim``/
     ``_evict``…), a direct refcount decrement, or a ``try`` block —
     otherwise the pages acquired by the earlier steps leak when the
     raise fires mid-mutation (the fork-refcount-leak bug class).
"""

from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import (FileContext, Finding, Project, attr_last,
                                 register)

ALLOWED_CLASSES = {"HostPageManager", "PrefixCache", "FaultyPageManager"}

# allocator mutators: multi-step mutation entry points
MUTATORS = {"reserve", "extend", "attach", "insert", "fork"}
# receivers those mutators are allocator calls on (page manager handles,
# the prefix cache, or self inside an allocator class)
RECEIVERS = {"mgr", "manager", "cache", "prefix_cache", "self",
             "HostPageManager", "PrefixCache"}
# evidence of a rollback path
UNDO_CALLS = {"free", "release", "reclaim", "rollback", "detach", "_evict",
              "_evict_chain", "pop"}
_MUTATING_METHODS = {"append", "pop", "clear", "extend", "insert", "remove"}


def _enclosing_class(node: ast.AST) -> Optional[str]:
    cur = getattr(node, "_replint_parent", None)
    while cur is not None:
        if isinstance(cur, ast.ClassDef):
            return cur.name
        cur = getattr(cur, "_replint_parent", None)
    return None


def _touches_refcount(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "refcount":
            return True
    return False


def _receiver_name(call: ast.Call) -> str:
    """Terminal receiver of ``a.b.mgr.reserve(...)`` -> 'mgr'."""
    func = call.func
    if isinstance(func, ast.Attribute):
        return attr_last(func.value)
    return ""


def _is_allocator_mutation(call: ast.Call, in_allowed_class: bool) -> bool:
    name = attr_last(call.func)
    if name not in MUTATORS:
        return False
    recv = _receiver_name(call)
    if recv in ("mgr", "manager", "cache", "prefix_cache"):
        return True
    if recv in ("self", "HostPageManager", "PrefixCache"):
        return in_allowed_class
    return False


@register(
    "allocator-discipline",
    "refcount mutated only inside HostPageManager/PrefixCache; allocator "
    "mutations have a rollback path before any later raise",
)
def check(ctx: FileContext, project: Project) -> List[Finding]:
    out: List[Finding] = []

    def finding(node: ast.AST, msg: str) -> None:
        out.append(Finding(rule="allocator-discipline", path=ctx.path,
                           line=node.lineno, col=node.col_offset,
                           symbol=ctx.qualname(node), message=msg))

    # 1. refcount mutations outside the allocator classes
    for node in ast.walk(ctx.tree):
        cls = _enclosing_class(node)
        allowed = cls in ALLOWED_CLASSES
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if any(_touches_refcount(t) for t in targets) and not allowed:
                finding(node, "refcount mutated outside HostPageManager/"
                              "PrefixCache — go through reserve/free/"
                              "fork/attach so the invariant stays provable")
        elif isinstance(node, ast.Call) and not allowed:
            f = node.func
            if isinstance(f, ast.Attribute) and \
                    f.attr in _MUTATING_METHODS and \
                    _touches_refcount(f.value):
                finding(node, "refcount mutated outside HostPageManager/"
                              "PrefixCache — go through reserve/free/"
                              "fork/attach so the invariant stays provable")

    # 2. rollback-before-raise per function
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        in_allowed = _enclosing_class(fn) in ALLOWED_CLASSES
        mutator_lines = []
        raise_nodes = []
        has_rollback = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    _is_allocator_mutation(node, in_allowed):
                mutator_lines.append(node.lineno)
            elif isinstance(node, ast.Raise):
                raise_nodes.append(node)
            elif isinstance(node, ast.Try):
                has_rollback = True
            elif isinstance(node, ast.Call) and \
                    attr_last(node.func) in UNDO_CALLS:
                has_rollback = True
            elif isinstance(node, ast.AugAssign) and \
                    isinstance(node.op, ast.Sub) and \
                    _touches_refcount(node.target):
                has_rollback = True
        if not mutator_lines or has_rollback:
            continue
        first_mut = min(mutator_lines)
        late = [r for r in raise_nodes if r.lineno > first_mut]
        if late:
            finding(late[0],
                    "raise after an allocator mutation (reserve/extend/"
                    "attach at line %d) with no rollback path — free/undo "
                    "the acquired pages before raising" % first_mut)
    return out
