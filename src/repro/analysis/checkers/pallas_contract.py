"""pallas-contract: every ``pl.pallas_call`` in ``kernels/`` honours the
grid / BlockSpec / split-K partial contracts.

Checks, per call site:

  1. grid rank == ``dimension_semantics`` length (megacore contract —
     a silent mismatch either crashes Mosaic late or drops parallelism);
  2. every BlockSpec ``index_map`` takes exactly ``grid rank +
     num_scalar_prefetch`` positional parameters (a ``*rest`` vararg
     absorbs trailing prefetch operands);
  3. a kernel wrapper emitting split-K partials (function name contains
     ``partials``) must declare exactly three outputs — the ``(m, l, acc)``
     contract shared by both backends and ``combine_partials`` — and all
     three accumulators must be ``jnp.float32``.

The checker resolves the project's real idioms statically: module/local
constants for ``dimension_semantics``, local BlockSpec variables, helper
lambdas returning BlockSpecs (``whole(arr)``, ``kv_spec(j)``), named
index-map defs, and ``functools.partial``-bound index maps.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.core import (FileContext, Finding, Project, attr_last,
                                 kwarg as _kw, register, resolve_name,
                                 scope_env)


def _env_for(ctx: FileContext, node: ast.AST) -> Dict[str, ast.AST]:
    return scope_env(ctx, node)


def _resolve(env: Dict[str, ast.AST], node: ast.AST) -> ast.AST:
    return resolve_name(env, node)


def _literal_int(env: Dict[str, ast.AST], node: ast.AST) -> Optional[int]:
    node = _resolve(env, node)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def _tuple_len(env: Dict[str, ast.AST], node: ast.AST) -> Optional[int]:
    node = _resolve(env, node)
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _index_map_arity(env: Dict[str, ast.AST],
                     node: ast.AST) -> Optional[Tuple[int, bool]]:
    """(positional arity, has_vararg) of an index_map expression.

    ``functools.partial`` binds consume parameters: leading ones when
    bound positionally, named ones when bound by keyword.
    """
    node = _resolve(env, node)
    bound_pos = 0
    bound_kw: set = set()
    while isinstance(node, ast.Call) and attr_last(node.func) == "partial":
        if not node.args:
            return None
        bound_pos += len(node.args) - 1
        bound_kw |= {kw.arg for kw in node.keywords if kw.arg}
        node = _resolve(env, node.args[0])
    if isinstance(node, (ast.Lambda, ast.FunctionDef, ast.AsyncFunctionDef)):
        a = node.args
        pos = [p.arg for p in a.posonlyargs + a.args]
        free = [p for p in pos[bound_pos:] if p not in bound_kw]
        return len(free), a.vararg is not None
    return None


def _iter_blockspecs(env: Dict[str, ast.AST], node: Optional[ast.AST]):
    """Yield every ``pl.BlockSpec(...)`` Call reachable from a specs
    expression: lists/tuples, list concatenation, comprehensions, local
    variables, and calls to local BlockSpec-factory lambdas/defs."""
    if node is None:
        return
    node = _resolve(env, node)
    if isinstance(node, (ast.List, ast.Tuple)):
        for elt in node.elts:
            yield from _iter_blockspecs(env, elt)
    elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        yield from _iter_blockspecs(env, node.left)
        yield from _iter_blockspecs(env, node.right)
    elif isinstance(node, ast.ListComp):
        yield from _iter_blockspecs(env, node.elt)
    elif isinstance(node, ast.Call):
        if attr_last(node.func) == "BlockSpec":
            yield node
        else:
            # a call to a local factory (whole(arr), kv_spec(j)): resolve
            # the factory and yield the BlockSpec its body constructs
            factory = _resolve(env, node.func)
            body = None
            if isinstance(factory, ast.Lambda):
                body = factory.body
            elif isinstance(factory, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                rets = [s.value for s in ast.walk(factory)
                        if isinstance(s, ast.Return) and s.value is not None]
                body = rets[0] if len(rets) == 1 else None
            if isinstance(body, ast.Call) and \
                    attr_last(body.func) == "BlockSpec":
                yield body


def _check_call(ctx: FileContext, call: ast.Call,
                symbol: str) -> List[Finding]:
    env = _env_for(ctx, call)
    out: List[Finding] = []

    def finding(node: ast.AST, msg: str) -> None:
        out.append(Finding(rule="pallas-contract", path=ctx.path,
                           line=node.lineno, col=node.col_offset,
                           symbol=symbol, message=msg))

    grid_expr = _kw(call, "grid")
    prefetch: Optional[int] = 0
    in_specs = _kw(call, "in_specs")
    out_specs = _kw(call, "out_specs")
    grid_spec = _kw(call, "grid_spec")
    if grid_spec is not None:
        gs = _resolve(env, grid_spec)
        if isinstance(gs, ast.Call):
            grid_expr = _kw(gs, "grid")
            in_specs = in_specs or _kw(gs, "in_specs")
            out_specs = out_specs or _kw(gs, "out_specs")
            nsp = _kw(gs, "num_scalar_prefetch")
            prefetch = _literal_int(env, nsp) if nsp is not None else 0

    rank = _tuple_len(env, grid_expr) if grid_expr is not None else None

    # 1. dimension_semantics length == grid rank
    cp = _kw(call, "compiler_params")
    if cp is not None:
        cp = _resolve(env, cp)
        if isinstance(cp, ast.Call):
            ds = _kw(cp, "dimension_semantics")
            if ds is not None:
                ds_len = _tuple_len(env, ds)
                if rank is not None and ds_len is not None \
                        and ds_len != rank:
                    finding(ds, f"dimension_semantics has {ds_len} "
                                f"entries but the grid has rank {rank}")

    # 2. index_map arity == grid rank + num_scalar_prefetch
    if rank is not None:
        expected = rank + prefetch if prefetch is not None else None
        for spec in list(_iter_blockspecs(env, in_specs)) + \
                list(_iter_blockspecs(env, out_specs)):
            imap = spec.args[1] if len(spec.args) > 1 \
                else _kw(spec, "index_map")
            if imap is None:
                continue
            arity = _index_map_arity(env, imap)
            if arity is None:
                continue
            n, vararg = arity
            if vararg:
                if expected is not None and n > expected:
                    finding(spec, f"index_map takes {n} positional "
                                  f"params (+*args) but grid rank + "
                                  f"scalar prefetch is only {expected}")
                elif n < rank:
                    finding(spec, f"index_map takes {n} positional "
                                  f"params (+*args) but the grid alone "
                                  f"has rank {rank}")
            elif expected is not None and n != expected:
                finding(spec, f"index_map takes {n} positional params "
                              f"but grid rank ({rank}) + scalar prefetch "
                              f"({prefetch}) = {expected}")
            elif expected is None and n < rank:
                finding(spec, f"index_map takes {n} positional params "
                              f"but the grid alone has rank {rank}")

    # 3. split-K partial emitters: three (m, l, acc) f32 outputs.
    # ("combine" kernels *consume* partials and emit one merged tensor.)
    if "partials" in symbol and "combine" not in symbol:
        shape = _kw(call, "out_shape")
        shape = _resolve(env, shape) if shape is not None else None
        if isinstance(shape, (ast.List, ast.Tuple)):
            if len(shape.elts) != 3:
                finding(shape, f"split-K partials must emit exactly three "
                               f"(m, l, acc) outputs, found "
                               f"{len(shape.elts)}")
            for elt in shape.elts:
                elt = _resolve(env, elt)
                if not isinstance(elt, ast.Call):
                    continue
                dt = elt.args[1] if len(elt.args) > 1 \
                    else _kw(elt, "dtype")
                if dt is not None and attr_last(dt) != "float32":
                    finding(elt, "split-K partial accumulators must be "
                                 "f32 (jnp.float32), found "
                                 f"'{attr_last(dt) or ast.dump(dt)}'")
        elif shape is not None:
            finding(shape, "split-K partials must emit a list of three "
                           "(m, l, acc) ShapeDtypeStructs")

    return out


@register(
    "pallas-contract",
    "pl.pallas_call grid/dimension_semantics/index_map/split-K contracts",
    dirs=("kernels",),
)
def check(ctx: FileContext, project: Project) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and \
                attr_last(node.func) == "pallas_call":
            out.extend(_check_call(ctx, node, ctx.qualname(node)))
    return out
