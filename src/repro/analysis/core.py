"""replint core: findings, rule registry, suppressions, baseline, reporters.

The analysis suite is a set of *project-native* rules — each one encodes a
cross-layer contract of this serving stack that no generic linter knows
about (Pallas grid/BlockSpec arity, knob threading, the structured-error
taxonomy, tracer safety inside kernels, allocator refcount discipline).

Vocabulary:

  * a ``Rule`` is a named check run over one ``FileContext`` with access to
    the whole ``Project`` (for cross-file passes like the call-graph knob
    checker);
  * a ``Finding`` is one violation, keyed line-independently by
    (rule, path, symbol, message) so baselines survive unrelated edits;
  * a suppression comment ``# replint: disable=rule[,rule] -- reason`` on
    (or directly above) the offending line silences it at the source;
  * a checked-in JSON baseline grandfathers known findings without hiding
    *new* ones — the driver exits non-zero only on unbaselined findings.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

BASELINE_VERSION = 1
REPORT_VERSION = 1

_SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*(disable|disable-file)\s*=\s*"
    r"([A-Za-z0-9_\-]+(?:\s*,\s*[A-Za-z0-9_\-]+)*)"
    r"(?:\s*--\s*(?P<reason>.*))?")


@dataclasses.dataclass
class Finding:
    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    symbol: str  # enclosing qualname, "<module>" at top level
    message: str
    suppressed: bool = False
    baselined: bool = False

    def key(self) -> Tuple[str, str, str, str]:
        """Line-independent identity — what the baseline matches on."""
        return (self.rule, self.path, self.symbol, self.message)

    def as_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "symbol": self.symbol,
                "message": self.message, "suppressed": self.suppressed,
                "baselined": self.baselined}


class FileContext:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.lines = source.splitlines()
        # line (1-based) -> set of rule names disabled on that line
        self.line_disables: Dict[int, set] = {}
        self.file_disables: set = set()
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(2).split(",")}
            if m.group(1) == "disable-file":
                self.file_disables |= rules
            else:
                self.line_disables.setdefault(i, set()).update(rules)
        _annotate_parents(self.tree)

    def is_suppressed(self, rule: str, line: int) -> bool:
        """A finding is suppressed by a comment on its line, on the line
        directly above it, or by a file-level ``disable-file``."""
        if rule in self.file_disables or "all" in self.file_disables:
            return True
        for ln in (line, line - 1):
            disabled = self.line_disables.get(ln, ())
            if rule in disabled or "all" in disabled:
                return True
        return False

    def qualname(self, node: ast.AST) -> str:
        """Dotted name of the def/class chain enclosing ``node``."""
        parts: List[str] = []
        cur = getattr(node, "_replint_parent", None)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            parts.append(node.name)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = getattr(cur, "_replint_parent", None)
        return ".".join(reversed(parts)) or "<module>"


def _annotate_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._replint_parent = parent  # type: ignore[attr-defined]


class Project:
    """The full analyzed file set + lazily built cross-file indexes."""

    def __init__(self, contexts: Sequence[FileContext]):
        self.contexts = list(contexts)
        self._signatures: Optional[Dict[str, List["FuncSig"]]] = None

    @property
    def signatures(self) -> Dict[str, List["FuncSig"]]:
        """Bare function name -> every def of that name in the project."""
        if self._signatures is None:
            index: Dict[str, List[FuncSig]] = {}
            for ctx in self.contexts:
                for node in ast.walk(ctx.tree):
                    if isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        index.setdefault(node.name, []).append(
                            FuncSig.from_def(node, ctx))
            self._signatures = index
        return self._signatures


@dataclasses.dataclass
class FuncSig:
    """Signature facts the call-graph checkers need."""

    name: str
    qualname: str
    path: str
    positional: Tuple[str, ...]  # posonly + pos-or-kw, in order
    kwonly: Tuple[str, ...]
    has_varargs: bool
    has_kwargs: bool

    @property
    def params(self) -> set:
        return set(self.positional) | set(self.kwonly)

    @classmethod
    def from_def(cls, node, ctx: FileContext) -> "FuncSig":
        a = node.args
        pos = tuple(p.arg for p in (a.posonlyargs + a.args))
        return cls(name=node.name, qualname=ctx.qualname(node),
                   path=ctx.path, positional=pos,
                   kwonly=tuple(p.arg for p in a.kwonlyargs),
                   has_varargs=a.vararg is not None,
                   has_kwargs=a.kwarg is not None)


@dataclasses.dataclass
class Rule:
    name: str
    doc: str
    check: Callable[[FileContext, Project], List[Finding]]
    # path-segment filter: the rule runs only on files with one of these
    # directory names in their path; () = every analyzed file
    dirs: Tuple[str, ...] = ()

    def applies(self, path: str) -> bool:
        if not self.dirs:
            return True
        parts = Path(path).parts
        return any(d in parts for d in self.dirs)


RULES: Dict[str, Rule] = {}


def register(name: str, doc: str, dirs: Tuple[str, ...] = ()):
    def deco(fn):
        RULES[name] = Rule(name=name, doc=doc, check=fn, dirs=dirs)
        return fn
    return deco


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------
def call_name(call: ast.Call) -> str:
    """Bare (last-segment) name of a call target; '' if not a name chain."""
    return attr_last(call.func)


def attr_last(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def dotted_name(node: ast.AST) -> str:
    """'pl.pallas_call' for Attribute chains, 'name' for Name, else ''."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def attr_root(node: ast.AST) -> str:
    """Leftmost name of an attribute chain ('np' for np.linalg.norm)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def kwarg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def scope_env(ctx: FileContext, node: ast.AST) -> Dict[str, ast.AST]:
    """Name -> assigned value, module scope overridden by each enclosing
    function scope (innermost wins).  Simple single-assignment resolution:
    the *last* textual assignment of a name in a scope is what resolves."""
    scopes: List[ast.AST] = [ctx.tree]
    chain: List[ast.AST] = []
    cur = getattr(node, "_replint_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            chain.append(cur)
        cur = getattr(cur, "_replint_parent", None)
    scopes.extend(reversed(chain))  # outermost function first
    env: Dict[str, ast.AST] = {}
    for scope in scopes:
        for stmt in ast.iter_child_nodes(scope):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                env[stmt.targets[0].id] = stmt.value
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                env[stmt.name] = stmt
    return env


def resolve_name(env: Dict[str, ast.AST], node: ast.AST) -> ast.AST:
    depth = 0
    while isinstance(node, ast.Name) and node.id in env and depth < 8:
        nxt = env[node.id]
        if nxt is node:
            break
        node = nxt
        depth += 1
    return node


# ---------------------------------------------------------------------------
# driving
# ---------------------------------------------------------------------------
def collect_files(paths: Sequence[str], root: Path) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        pp = (root / p) if not Path(p).is_absolute() else Path(p)
        if pp.is_dir():
            out.extend(sorted(pp.rglob("*.py")))
        elif pp.suffix == ".py":
            out.append(pp)
    return out


def analyze_paths(paths: Sequence[str], root: Path,
                  rules: Optional[Sequence[str]] = None,
                  files: Optional[Sequence[Path]] = None) -> List[Finding]:
    """Run the (selected) rules over every .py file under ``paths``.

    Returns all findings with ``suppressed`` marked; baseline marking is
    the caller's job (it owns the baseline file location).
    """
    # import for side effect: registers every built-in checker
    from repro.analysis import checkers  # noqa: F401

    selected = [RULES[r] for r in rules] if rules else list(RULES.values())
    file_list = list(files) if files is not None \
        else collect_files(paths, root)

    contexts: List[FileContext] = []
    findings: List[Finding] = []
    for f in file_list:
        rel = f.relative_to(root).as_posix() if f.is_absolute() and \
            f.is_relative_to(root) else f.as_posix()
        try:
            contexts.append(FileContext(rel, f.read_text()))
        except SyntaxError as e:
            findings.append(Finding(
                rule="parse-error", path=rel, line=e.lineno or 0, col=0,
                symbol="<module>", message=f"could not parse: {e.msg}"))
    project = Project(contexts)

    for ctx in contexts:
        for rule in selected:
            if not rule.applies(ctx.path):
                continue
            for fnd in rule.check(ctx, project):
                fnd.suppressed = ctx.is_suppressed(fnd.rule, fnd.line)
                findings.append(fnd)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def load_baseline(path: Path) -> set:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {(e["rule"], e["path"], e["symbol"], e["message"])
            for e in data.get("findings", [])}


def apply_baseline(findings: Sequence[Finding], baseline: set) -> None:
    for f in findings:
        if f.key() in baseline:
            f.baselined = True


def write_baseline(findings: Sequence[Finding], path: Path) -> None:
    entries = [{"rule": f.rule, "path": f.path, "symbol": f.symbol,
                "message": f.message}
               for f in findings if not f.suppressed]
    path.write_text(json.dumps(
        {"version": BASELINE_VERSION, "findings": entries},
        indent=2, sort_keys=True) + "\n")


def stale_baseline_entries(findings: Sequence[Finding], baseline: set,
                           analyzed_paths: Optional[Sequence[str]] = None,
                           ) -> List[Tuple]:
    """Baseline entries whose finding no longer fires — the defect was
    fixed, so the grandfathering should be deleted before it masks a
    regression.  ``analyzed_paths=None`` means a full run (every entry
    is in scope); a ``--changed-only`` run passes the analyzed subset so
    entries for unanalyzed files are not falsely flagged as stale."""
    analyzed = None if analyzed_paths is None else set(analyzed_paths)
    current = {f.key() for f in findings}
    return sorted(key for key in baseline
                  if (analyzed is None or key[1] in analyzed)
                  and key not in current)


# ---------------------------------------------------------------------------
# reporters
# ---------------------------------------------------------------------------
def active(findings: Sequence[Finding]) -> List[Finding]:
    """Findings that gate the build: neither suppressed nor baselined."""
    return [f for f in findings if not f.suppressed and not f.baselined]


def render_text(findings: Sequence[Finding],
                show_suppressed: bool = False) -> str:
    out = []
    for f in findings:
        if (f.suppressed or f.baselined) and not show_suppressed:
            continue
        tag = " (suppressed)" if f.suppressed else \
            " (baselined)" if f.baselined else ""
        out.append(f"{f.path}:{f.line}:{f.col}: {f.rule} "
                   f"[{f.symbol}] {f.message}{tag}")
    gating = active(findings)
    n_sup = sum(f.suppressed for f in findings)
    n_base = sum(f.baselined for f in findings)
    out.append(f"replint: {len(gating)} finding(s) "
               f"({n_sup} suppressed, {n_base} baselined)")
    return "\n".join(out)


def render_sarif(findings: Sequence[Finding],
                 rules: Sequence[str]) -> str:
    """SARIF 2.1.0 report — the interchange format GitHub code scanning
    and most IDE problem panes ingest.  Suppressed/baselined findings
    are carried with a ``suppressions`` entry instead of being dropped,
    so the dashboard mirrors the gating semantics."""
    rule_objs = [{
        "id": name,
        "shortDescription": {"text": RULES[name].doc if name in RULES
                             else name},
    } for name in sorted(set(rules) | {f.rule for f in findings})]
    rule_index = {r["id"]: i for i, r in enumerate(rule_objs)}
    results = []
    for f in findings:
        res = {
            "ruleId": f.rule,
            "ruleIndex": rule_index[f.rule],
            "level": "error",
            "message": {"text": f"[{f.symbol}] {f.message}"},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {"uri": f.path,
                                         "uriBaseId": "SRCROOT"},
                    "region": {"startLine": max(1, f.line),
                               "startColumn": f.col + 1},
                },
            }],
        }
        if f.suppressed or f.baselined:
            res["suppressions"] = [{
                "kind": "inSource" if f.suppressed else "external",
                "justification": "replint suppression comment"
                if f.suppressed else "replint baseline",
            }]
        results.append(res)
    payload = {
        "$schema": ("https://raw.githubusercontent.com/oasis-tcs/"
                    "sarif-spec/master/Schemata/sarif-schema-2.1.0.json"),
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": "replint",
                "informationUri": "https://example.invalid/replint",
                "rules": rule_objs,
            }},
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
            "results": results,
        }],
    }
    return json.dumps(payload, indent=2, sort_keys=True)


def render_json(findings: Sequence[Finding],
                rules: Sequence[str]) -> str:
    gating = active(findings)
    payload = {
        "version": REPORT_VERSION,
        "tool": "replint",
        "rules": sorted(rules),
        "findings": [f.as_dict() for f in findings],
        "summary": {
            "total": len(findings),
            "suppressed": sum(f.suppressed for f in findings),
            "baselined": sum(f.baselined for f in findings),
            "gating": len(gating),
        },
    }
    return json.dumps(payload, indent=2, sort_keys=True)
