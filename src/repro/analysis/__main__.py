"""``python -m repro.analysis`` — the replint driver.

Exit status: 0 when every finding is suppressed or baselined, 1 when any
gating finding remains, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import subprocess
import sys
from pathlib import Path

from repro.analysis.core import (RULES, active, analyze_paths, apply_baseline,
                                 load_baseline, render_json, render_sarif,
                                 render_text, stale_baseline_entries,
                                 write_baseline)

DEFAULT_PATHS = ("src/repro",)
DEFAULT_BASELINE = "replint_baseline.json"


def _repo_root() -> Path:
    """Nearest ancestor holding a .git (or pyproject/Makefile) marker."""
    cur = Path.cwd().resolve()
    for cand in (cur, *cur.parents):
        if (cand / ".git").exists() or (cand / "Makefile").exists():
            return cand
    return cur


def _merge_base_files(root: Path) -> list:
    """Paths committed since the merge-base with ``origin/main``.

    A branch with clean worktree but N local commits still differs from
    what CI will see on main — ``--changed-only`` must cover those files
    too, not just the dirty ones.  Silently empty when origin/main is
    absent (fresh clone, detached CI checkout): the dirty-worktree set
    is then the whole answer.
    """
    try:
        base = subprocess.run(
            ["git", "merge-base", "origin/main", "HEAD"], cwd=root,
            capture_output=True, text=True, check=True).stdout.strip()
        out = subprocess.run(
            ["git", "diff", "--name-only", "--diff-filter=d", base,
             "HEAD"], cwd=root,
            capture_output=True, text=True, check=True).stdout
    except (OSError, subprocess.CalledProcessError):
        return []
    return [line.strip() for line in out.splitlines() if line.strip()]


def _changed_files(root: Path) -> list:
    """Changed .py files vs git: the dirty worktree (tracked-but-modified
    + staged + untracked) unioned with commits since the merge-base with
    ``origin/main``.

    Seeded-violation fixtures (tests/fixtures/) are excluded: they are
    *supposed* to light the rules up and are gated by tests, not lint.
    """
    try:
        out = subprocess.run(
            ["git", "status", "--porcelain", "-uall"], cwd=root,
            capture_output=True, text=True, check=True).stdout
    except (OSError, subprocess.CalledProcessError) as e:
        print(f"replint: --changed-only needs git ({e})", file=sys.stderr)
        return []
    paths = []
    for line in out.splitlines():
        if len(line) < 4:
            continue
        path = line[3:].strip()
        if " -> " in path:  # rename: take the new side
            path = path.split(" -> ", 1)[1]
        paths.append(path.strip('"'))
    paths.extend(_merge_base_files(root))
    files = []
    for path in dict.fromkeys(paths):  # de-dupe, keep order
        if not path.endswith(".py") or not (root / path).exists():
            continue
        if "fixtures" in Path(path).parts:
            continue
        files.append(root / path)
    return files


def main(argv=None) -> int:
    # import for side effect: registers the built-in rules before --list-rules
    from repro.analysis import checkers  # noqa: F401

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="replint: project-native static analysis for the "
                    "paged-serving stack")
    parser.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                        help="files or directories to lint "
                             "(default: src/repro)")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset of rules to run")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the registered rules and exit")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable JSON report")
    parser.add_argument("--sarif", action="store_true",
                        help="emit a SARIF 2.1.0 report (for GitHub code "
                             "scanning / IDE problem panes)")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline file (default: %(default)s; "
                             "'' disables)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write current unsuppressed findings to the "
                             "baseline file and exit 0")
    parser.add_argument("--changed-only", action="store_true",
                        help="lint only .py files changed vs git "
                             "(staged, unstaged, untracked)")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="include suppressed/baselined findings in the "
                             "text report")
    args = parser.parse_args(argv)

    if args.json and args.sarif:
        print("replint: --json and --sarif are mutually exclusive",
              file=sys.stderr)
        return 2

    if args.list_rules:
        width = max(len(r) for r in RULES) if RULES else 0
        for name in sorted(RULES):
            print(f"{name:<{width}}  {RULES[name].doc}")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            print(f"replint: unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2

    root = _repo_root()
    files = None
    if args.changed_only:
        files = _changed_files(root)
        if not files:
            print("replint: no changed .py files")
            return 0

    findings = analyze_paths(args.paths, root, rules=rules, files=files)

    baseline_path = (root / args.baseline) if args.baseline else None
    if args.write_baseline:
        if baseline_path is None:
            print("replint: --write-baseline needs --baseline",
                  file=sys.stderr)
            return 2
        write_baseline(findings, baseline_path)
        n = sum(not f.suppressed for f in findings)
        print(f"replint: wrote {n} finding(s) to {baseline_path}")
        return 0
    if baseline_path is not None:
        baseline = load_baseline(baseline_path)
        apply_baseline(findings, baseline)
        if files is None:
            # full run over args.paths: every entry under those roots is
            # in scope (paths with zero current findings included)
            roots = [p.rstrip("/") for p in args.paths]
            analyzed = sorted(
                key[1] for key in baseline
                if any(key[1] == r or key[1].startswith(r + "/")
                       for r in roots))
        else:
            analyzed = sorted(str(p.resolve().relative_to(root))
                              for p in files)
        stale = stale_baseline_entries(findings, baseline, analyzed)
        for key in stale:
            print(f"replint: stale baseline entry {list(key)} — the "
                  f"finding no longer fires; delete it from "
                  f"{baseline_path.name}", file=sys.stderr)

    if args.json:
        print(render_json(findings, rules or sorted(RULES)))
    elif args.sarif:
        print(render_sarif(findings, rules or sorted(RULES)))
    else:
        print(render_text(findings, show_suppressed=args.show_suppressed))
    return 1 if active(findings) else 0


if __name__ == "__main__":
    sys.exit(main())
