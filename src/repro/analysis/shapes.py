"""shapes: abstract interpretation of every ``pallas_call`` launch.

The rule proves, at lint time and without running a kernel, that each
``pallas_call`` site in ``src/repro/kernels/`` agrees with its *declared
contract* (``repro/kernels/paged_attention/contracts.py``):

  * **rank** — every BlockSpec block shape has the operand's rank (the
    BlockSpec-vs-pool-array follow-up from the first replint PR);
  * **divisibility** — block dims divide the operand dims they tile;
  * **in-range indexing** — the ``index_map`` is evaluated symbolically
    for *every* grid point: grid axes become intervals ``[0, size-1]``,
    scalar-prefetch tables carry their declared value range (the
    ``_blocked_tables`` clamp, ``[0, num_pages-1]``), and interval
    arithmetic through ``s * bps + blk``-style expressions bounds every
    block index against the operand extent;
  * **partial dtypes** — split-K ``(m, l, acc)`` outputs must be f32;
  * **handoff + parity** — contracts in a ``partial_group`` must agree
    under their parity samples (TPU ≡ GPU), consumers (the combine
    kernel) must ingest exactly the group's shapes, and the prefill
    group must fold onto the decode group along its q-block axis.

Evaluation is concrete-per-sample: each contract carries sample bindings
(the partition-law boundary cases, derived through ``decode_partition``)
under which the site's actual AST — block shapes, grids, factory lambdas,
``functools.partial``-bound index_maps, list comprehensions over
``range(ppb)`` — is executed by a tiny abstract evaluator.  Only grid
indices and prefetch-table *contents* are intervals; everything else is
an integer, so the arithmetic is exact for the monotone expressions
index_maps use.

Fixtures (not importable) declare contracts inline as a literal::

    REPLINT_KERNEL_CONTRACTS = {"site_fn": {...}}    # ast.literal_eval'd
    REPLINT_PARTIAL_GROUPS = {"group": {...}}        # optional

A ``pallas_call`` under ``src/`` with no registry entry — or in any file
carrying an inline table but missing from it — is itself a finding, so
new kernels cannot dodge the checker.
"""

from __future__ import annotations

import ast
import importlib.util
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.core import (FileContext, Finding, Project, call_name,
                                 dotted_name, kwarg, register, scope_env)

RULE = "shapes"
INLINE_TABLE = "REPLINT_KERNEL_CONTRACTS"
INLINE_GROUPS = "REPLINT_PARTIAL_GROUPS"
_REGISTRY_REL = Path("kernels") / "paged_attention" / "contracts.py"

_F32 = "float32"


# ---------------------------------------------------------------------------
# abstract values
# ---------------------------------------------------------------------------
class Interval:
    """Inclusive integer interval — the only abstract numeric value."""

    __slots__ = ("lo", "hi")

    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def __repr__(self):
        return f"[{self.lo}, {self.hi}]"


def _lo(v) -> int:
    return v.lo if isinstance(v, Interval) else int(v)


def _hi(v) -> int:
    return v.hi if isinstance(v, Interval) else int(v)


def _arith(op, a, b):
    """Exact interval arithmetic via corner evaluation (monotone ops)."""
    if isinstance(a, Interval) or isinstance(b, Interval):
        corners = [op(x, y) for x in (_lo(a), _hi(a))
                   for y in (_lo(b), _hi(b))]
        return Interval(min(corners), max(corners))
    return op(a, b)


class OperandVal:
    """A declared kernel operand: static shape/dtype + content range."""

    __slots__ = ("name", "shape", "dtype", "value_range")

    def __init__(self, name: str, shape: Tuple[int, ...], dtype: str,
                 value_range: Optional[Interval]):
        self.name, self.shape, self.dtype = name, tuple(shape), dtype
        self.value_range = value_range


class ClosureVal:
    """A lambda/def captured with its evaluation environment."""

    __slots__ = ("node", "env")

    def __init__(self, node: ast.AST, env: "Env"):
        self.node, self.env = node, env


class PartialVal:
    __slots__ = ("fn", "kwargs")

    def __init__(self, fn: ClosureVal, kwargs: Dict):
        self.fn, self.kwargs = fn, kwargs


class SpecVal:
    """An evaluated BlockSpec: concrete block shape + index_map closure."""

    __slots__ = ("block", "index_map", "node")

    def __init__(self, block, index_map, node: ast.AST):
        self.block, self.index_map, self.node = block, index_map, node


class StructVal:
    """An evaluated ShapeDtypeStruct."""

    __slots__ = ("shape", "dtype")

    def __init__(self, shape: Tuple[int, ...], dtype: str):
        self.shape, self.dtype = tuple(shape), dtype


class EvalError(Exception):
    """The site uses a construct the interpreter cannot bound."""


class Env:
    """Value bindings chained over lazily-evaluated AST assignments."""

    def __init__(self, values: Dict, ast_env: Dict[str, ast.AST],
                 parent: Optional["Env"] = None):
        self.values = values
        self.ast_env = ast_env
        self.parent = parent

    def child(self, values: Dict) -> "Env":
        return Env(values, self.ast_env, parent=self)

    def lookup(self, name: str):
        env: Optional[Env] = self
        while env is not None:
            if name in env.values:
                return env.values[name]
            env = env.parent
        raise KeyError(name)


class _Evaluator:
    """Evaluates the spec-defining subset of Python over abstract values."""

    def __init__(self, problems: List[Tuple[ast.AST, str]]):
        self.problems = problems
        self._depth = 0

    # -- expressions ----------------------------------------------------
    def eval(self, node: ast.AST, env: Env):
        self._depth += 1
        if self._depth > 200:
            raise EvalError("evaluation too deep (cyclic binding?)")
        try:
            return self._eval(node, env)
        finally:
            self._depth -= 1

    def _eval(self, node: ast.AST, env: Env):
        if isinstance(node, ast.Constant):
            return node.value
        if isinstance(node, ast.Name):
            try:
                return env.lookup(node.id)
            except KeyError:
                pass
            bound = env.ast_env.get(node.id)
            if bound is None:
                raise EvalError(f"unbound name '{node.id}' (bind it in the "
                                "contract sample or declare the operand)")
            if isinstance(bound, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return ClosureVal(bound, env)
            return self.eval(bound, env)
        if isinstance(node, (ast.Tuple, ast.List)):
            vals = [self.eval(e, env) for e in node.elts]
            return tuple(vals) if isinstance(node, ast.Tuple) else vals
        if isinstance(node, ast.BinOp):
            return self._binop(node, env)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.eval(node.operand, env)
            return _arith(lambda a, b: a - b, 0, v) if isinstance(
                v, Interval) else -v
        if isinstance(node, ast.Compare):
            return self._compare(node, env)
        if isinstance(node, ast.Attribute):
            return self._attribute(node, env)
        if isinstance(node, ast.Subscript):
            return self._subscript(node, env)
        if isinstance(node, ast.Call):
            return self._call(node, env)
        if isinstance(node, ast.Lambda):
            return ClosureVal(node, env)
        if isinstance(node, ast.IfExp):
            test = self.eval(node.test, env)
            if not isinstance(test, bool):
                raise EvalError("conditional on a non-static test")
            return self.eval(node.body if test else node.orelse, env)
        if isinstance(node, ast.ListComp):
            return self._listcomp(node, env)
        if isinstance(node, ast.Starred):
            raise EvalError("starred expression inside a spec")
        raise EvalError(f"unsupported construct {type(node).__name__}")

    def _binop(self, node: ast.BinOp, env: Env):
        a = self.eval(node.left, env)
        b = self.eval(node.right, env)
        if isinstance(node.op, ast.Add):
            if isinstance(a, list) and isinstance(b, list):
                return a + b
            if isinstance(a, tuple) and isinstance(b, tuple):
                return a + b
            return _arith(lambda x, y: x + y, a, b)
        if isinstance(node.op, ast.Sub):
            return _arith(lambda x, y: x - y, a, b)
        if isinstance(node.op, ast.Mult):
            if isinstance(a, (tuple, list)) and isinstance(b, int):
                return a * b
            if isinstance(b, (tuple, list)) and isinstance(a, int):
                return b * a
            return _arith(lambda x, y: x * y, a, b)
        if isinstance(node.op, ast.FloorDiv):
            if _lo(b) <= 0 <= _hi(b):
                raise EvalError("floordiv by a range containing zero")
            return _arith(lambda x, y: x // y, a, b)
        if isinstance(node.op, ast.Mod):
            if isinstance(a, Interval) or isinstance(b, Interval):
                if _lo(b) <= 0:
                    raise EvalError("mod by a non-positive range")
                return Interval(0, _hi(b) - 1)
            return a % b
        raise EvalError(f"unsupported operator {type(node.op).__name__}")

    def _compare(self, node: ast.Compare, env: Env) -> bool:
        if len(node.ops) != 1:
            raise EvalError("chained comparison")
        a = self.eval(node.left, env)
        b = self.eval(node.comparators[0], env)
        if isinstance(a, Interval) or isinstance(b, Interval):
            raise EvalError("comparison on a grid-dependent value")
        table = {ast.Eq: lambda: a == b, ast.NotEq: lambda: a != b,
                 ast.Lt: lambda: a < b, ast.LtE: lambda: a <= b,
                 ast.Gt: lambda: a > b, ast.GtE: lambda: a >= b}
        fn = table.get(type(node.ops[0]))
        if fn is None:
            raise EvalError("unsupported comparison")
        return fn()

    def _attribute(self, node: ast.Attribute, env: Env):
        # operand handles expose the static facts kernels read
        try:
            base = self.eval(node.value, env)
        except EvalError:
            # module attribute (jnp.float32, pl.BlockSpec, ...): symbolic —
            # dtype-like leaves evaluate to their attribute name
            return node.attr
        if isinstance(base, OperandVal):
            if node.attr == "shape":
                return base.shape
            if node.attr == "ndim":
                return len(base.shape)
            if node.attr == "dtype":
                return base.dtype
            raise EvalError(f"operand attribute .{node.attr}")
        raise EvalError(f"attribute .{node.attr} on {type(base).__name__}")

    def _subscript(self, node: ast.Subscript, env: Env):
        base = self.eval(node.value, env)
        idx = self.eval(node.slice, env)
        if isinstance(base, OperandVal):
            indices = idx if isinstance(idx, tuple) else (idx,)
            if len(indices) != len(base.shape):
                self.problems.append((node, f"operand '{base.name}' "
                                      f"{base.shape} subscripted with "
                                      f"{len(indices)} indices"))
            for axis, (i, dim) in enumerate(zip(indices, base.shape)):
                if _lo(i) < 0 or _hi(i) >= dim:
                    self.problems.append((
                        node, f"index_map reads operand '{base.name}' axis "
                        f"{axis} at {Interval(_lo(i), _hi(i))} outside "
                        f"[0, {dim - 1}]"))
            if base.value_range is None:
                raise EvalError(f"operand '{base.name}' used as an index "
                                "table but declares no value_range")
            return Interval(base.value_range.lo, base.value_range.hi)
        if isinstance(base, (tuple, list)):
            if not isinstance(idx, int):
                raise EvalError("non-constant subscript of a tuple")
            return base[idx]
        raise EvalError(f"subscript of {type(base).__name__}")

    def _listcomp(self, node: ast.ListComp, env: Env):
        if len(node.generators) != 1 or node.generators[0].ifs:
            raise EvalError("unsupported comprehension shape")
        gen = node.generators[0]
        if not isinstance(gen.target, ast.Name):
            raise EvalError("comprehension target must be a name")
        seq = self.eval(gen.iter, env)
        if not isinstance(seq, (range, list, tuple)):
            raise EvalError("comprehension over a non-static sequence")
        return [self.eval(node.elt, env.child({gen.target.id: item}))
                for item in seq]

    def _call(self, node: ast.Call, env: Env):
        name = call_name(node)
        if name == "range":
            args = [self.eval(a, env) for a in node.args]
            if not all(isinstance(a, int) for a in args):
                raise EvalError("range() over non-static bounds")
            return range(*args)
        if name == "partial":
            fn = self.eval(node.args[0], env)
            if not isinstance(fn, ClosureVal):
                raise EvalError("partial of a non-function")
            kwargs = {kw.arg: self.eval(kw.value, env)
                      for kw in node.keywords if kw.arg}
            return PartialVal(fn, kwargs)
        if name == "BlockSpec":
            return self._blockspec(node, env)
        if name == "ShapeDtypeStruct":
            shape = self.eval(node.args[0], env)
            dtype = self.eval(node.args[1], env)
            if not isinstance(dtype, str):
                raise EvalError("non-static out_shape dtype")
            return StructVal(shape, dtype)
        if name == "len":
            v = self.eval(node.args[0], env)
            if isinstance(v, (tuple, list)):
                return len(v)
            raise EvalError("len() of a non-sequence")
        # factory call: the callee must resolve to a closure
        fn = self.eval(node.func, env)
        if isinstance(fn, (ClosureVal, PartialVal)):
            args = [self.eval(a, env) for a in node.args]
            kwargs = {kw.arg: self.eval(kw.value, env)
                      for kw in node.keywords if kw.arg}
            return self.call_function(fn, args, kwargs)
        raise EvalError(f"call of unsupported target '{name}'")

    def _blockspec(self, node: ast.Call, env: Env) -> SpecVal:
        block_node = kwarg(node, "block_shape") or (
            node.args[0] if node.args else None)
        map_node = kwarg(node, "index_map") or (
            node.args[1] if len(node.args) > 1 else None)
        block = self.eval(block_node, env) if block_node is not None else None
        index_map = self.eval(map_node, env) if map_node is not None else None
        if block is not None and not (
                isinstance(block, tuple)
                and all(isinstance(d, int) for d in block)):
            raise EvalError(f"non-static block shape {block!r}")
        return SpecVal(block, index_map, node)

    # -- function application -------------------------------------------
    def call_function(self, fn, args: Sequence, kwargs: Dict):
        bound_kwargs = dict(kwargs)
        if isinstance(fn, PartialVal):
            bound_kwargs.update(fn.kwargs)
            fn = fn.fn
        node, env = fn.node, fn.env
        a = node.args
        params = [p.arg for p in (a.posonlyargs + a.args)]
        local: Dict = {}
        if len(args) > len(params):
            if a.vararg is None:
                raise EvalError(
                    f"index_map/factory takes {len(params)} args, got "
                    f"{len(args)} (grid + scalar-prefetch operands)")
            local[a.vararg.arg] = tuple(args[len(params):])
            args = args[:len(params)]
        if len(args) < len(params) - len(a.defaults):
            raise EvalError(
                f"index_map/factory takes {len(params)} args, got "
                f"{len(args)} (grid + scalar-prefetch operands)")
        local.update(zip(params, args))
        for p in a.kwonlyargs:
            if p.arg in bound_kwargs:
                local[p.arg] = bound_kwargs[p.arg]
        call_env = env.child(local)
        if isinstance(node, ast.Lambda):
            return self.eval(node.body, call_env)
        result = self._exec_body(node.body, call_env)
        if result is _NO_RETURN:
            raise EvalError(f"'{node.name}' never returns")
        return result

    def _exec_body(self, stmts: Sequence[ast.stmt], env: Env):
        for stmt in stmts:
            if isinstance(stmt, ast.Return):
                return self.eval(stmt.value, env) if stmt.value else None
            if isinstance(stmt, (ast.Delete, ast.Pass, ast.Expr)):
                continue
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                env.values[stmt.targets[0].id] = self.eval(stmt.value, env)
                continue
            if isinstance(stmt, ast.If):
                test = self.eval(stmt.test, env)
                if not isinstance(test, bool):
                    raise EvalError("index_map branches on a grid value")
                result = self._exec_body(
                    stmt.body if test else stmt.orelse, env)
                if result is not _NO_RETURN:
                    return result
                continue
            raise EvalError(
                f"unsupported statement {type(stmt).__name__} in index_map")
        return _NO_RETURN


_NO_RETURN = object()


# ---------------------------------------------------------------------------
# contract resolution
# ---------------------------------------------------------------------------
_registry_cache: Optional[Tuple[Dict, Dict]] = None


def load_registry() -> Tuple[Dict, Dict]:
    """(CONTRACTS, PARTIAL_GROUPS) from the declared-contract module,
    loaded by file path so the import costs nothing (stdlib-only)."""
    global _registry_cache
    if _registry_cache is None:
        path = Path(__file__).resolve().parent.parent / _REGISTRY_REL
        spec = importlib.util.spec_from_file_location(
            "_replint_kernel_contracts", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)  # type: ignore[union-attr]
        _registry_cache = (mod.CONTRACTS, mod.PARTIAL_GROUPS)
    return _registry_cache


def _inline_tables(ctx: FileContext) -> Tuple[Optional[Dict], Dict]:
    """Literal ``REPLINT_KERNEL_CONTRACTS`` / ``REPLINT_PARTIAL_GROUPS``
    declared in the analyzed file (fixture support)."""
    table, groups = None, {}
    for stmt in ctx.tree.body:
        if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)):
            continue
        name = stmt.targets[0].id
        if name not in (INLINE_TABLE, INLINE_GROUPS):
            continue
        try:
            value = ast.literal_eval(stmt.value)
        except (ValueError, SyntaxError):
            continue
        if name == INLINE_TABLE:
            table = value
        else:
            groups = value
    return table, groups


def _resolve_sym(sym, sample: Dict, what: str):
    if isinstance(sym, int):
        return sym
    if isinstance(sym, str):
        if sym not in sample:
            raise EvalError(f"{what} symbol '{sym}' missing from sample")
        return sample[sym]
    raise EvalError(f"{what} entry {sym!r} is neither int nor symbol")


def _resolve_shape(shape, sample: Dict) -> Tuple[int, ...]:
    if isinstance(shape, str):  # whole shape bound per sample (rank varies)
        return tuple(_resolve_sym(shape, sample, "shape"))
    return tuple(_resolve_sym(s, sample, "shape") for s in shape)


def _expand_operands(contract: Dict, sample: Dict) -> List[OperandVal]:
    out: List[OperandVal] = []
    for op in contract.get("operands", ()):
        shape = _resolve_shape(op["shape"], sample)
        vr = op.get("value_range")
        rng = Interval(_resolve_sym(vr[0], sample, "value_range"),
                       _resolve_sym(vr[1], sample, "value_range")) \
            if vr is not None else None
        val = OperandVal(op["name"], shape, op.get("dtype", _F32), rng)
        out.extend([val] * _resolve_sym(op.get("repeat", 1), sample,
                                        "repeat"))
    return out


def _resolve_outputs(contract: Dict, sample: Dict
                     ) -> List[Tuple[Tuple[int, ...], str]]:
    return [(_resolve_shape(o["shape"], sample), o.get("dtype", _F32))
            for o in contract.get("outputs", ())]


def _parity_sample(contract: Dict) -> Optional[Dict]:
    hits = [s for s in contract.get("samples", ()) if s.get("_parity")]
    return hits[0] if len(hits) == 1 else None


# ---------------------------------------------------------------------------
# the per-site verification
# ---------------------------------------------------------------------------
def _as_list(v) -> list:
    return v if isinstance(v, list) else [v]


def _find_launch_parts(call: ast.Call) -> Dict[str, Optional[ast.AST]]:
    """grid / num_scalar_prefetch / in_specs / out_specs / out_shape AST
    nodes of a pallas_call, whether given flat or via a grid_spec."""
    parts = {"grid": kwarg(call, "grid"),
             "num_scalar_prefetch": None,
             "in_specs": kwarg(call, "in_specs"),
             "out_specs": kwarg(call, "out_specs"),
             "out_shape": kwarg(call, "out_shape")}
    gs = kwarg(call, "grid_spec")
    if isinstance(gs, ast.Call):
        for key in ("grid", "num_scalar_prefetch", "in_specs", "out_specs"):
            val = kwarg(gs, key)
            if val is not None:
                parts[key] = val
    return parts


def _check_spec(ev: _Evaluator, spec: SpecVal, op: OperandVal,
                axes: List, what: str) -> List[str]:
    """One BlockSpec against one operand under one sample binding."""
    msgs: List[str] = []
    if spec.block is None:
        return msgs
    if len(spec.block) != len(op.shape):
        msgs.append(f"{what} block shape {spec.block} has rank "
                    f"{len(spec.block)} but operand '{op.name}' has rank "
                    f"{len(op.shape)} {op.shape}")
        return msgs
    for axis, (bs, dim) in enumerate(zip(spec.block, op.shape)):
        if bs <= 0 or dim % bs:
            msgs.append(f"{what} block dim {bs} does not divide operand "
                        f"'{op.name}' axis {axis} (size {dim})")
    if spec.index_map is None:
        return msgs
    try:
        idx = ev.call_function(spec.index_map, list(axes), {})
    except EvalError as e:
        msgs.append(f"{what} index_map for operand '{op.name}': {e}")
        return msgs
    if not isinstance(idx, tuple):
        idx = (idx,)
    if len(idx) != len(op.shape):
        msgs.append(f"{what} index_map returns {len(idx)} block indices "
                    f"for rank-{len(op.shape)} operand '{op.name}'")
        return msgs
    for axis, (i, bs, dim) in enumerate(zip(idx, spec.block, op.shape)):
        lo, hi = _lo(i), _hi(i)
        if lo < 0 or (hi + 1) * bs > dim:
            msgs.append(
                f"{what} index_map addresses blocks {Interval(lo, hi)} × "
                f"block dim {bs} beyond operand '{op.name}' axis {axis} "
                f"(size {dim})")
    return msgs


def _check_site(ctx: FileContext, call: ast.Call, site: str,
                contract: Dict) -> List[Finding]:
    parts = _find_launch_parts(call)
    ast_env = scope_env(ctx, call)
    messages: Dict[str, Tuple[int, int]] = {}

    def add(msg: str, node: ast.AST = call):
        messages.setdefault(msg, (node.lineno, node.col_offset))

    for sample in contract.get("samples", ()):
        problems: List[Tuple[ast.AST, str]] = []
        ev = _Evaluator(problems)
        try:
            operands = _expand_operands(contract, sample)
            values = {k: v for k, v in sample.items()
                      if not k.startswith("_")}
            for op in operands:
                values.setdefault(op.name, op)
            env = Env(values, ast_env)

            # grid: site expression vs contract symbols
            if parts["grid"] is None:
                raise EvalError("pallas_call has no grid/grid_spec")
            grid = ev.eval(parts["grid"], env)
            want_grid = tuple(_resolve_sym(g, sample, "grid")
                              for g in contract.get("grid", ()))
            if tuple(grid) != want_grid:
                add(f"grid {tuple(grid)} != declared grid {want_grid}")
                continue
            axes = [Interval(0, n - 1) for n in grid]

            # scalar-prefetch split
            npf_decl = _resolve_sym(contract.get("num_scalar_prefetch", 0),
                                    sample, "num_scalar_prefetch")
            npf_node = parts["num_scalar_prefetch"]
            npf = ev.eval(npf_node, env) if npf_node is not None else 0
            if npf != npf_decl:
                add(f"num_scalar_prefetch {npf} != declared {npf_decl}")
                continue
            prefetch = operands[:npf]
            blocked = operands[npf:]
            axes_and_prefetch = axes + list(prefetch)

            # in_specs, positionally against the expanded operand list
            specs = _as_list(ev.eval(parts["in_specs"], env)) \
                if parts["in_specs"] is not None else []
            if len(specs) != len(blocked):
                add(f"{len(specs)} in_specs for {len(blocked)} declared "
                    f"non-prefetch operands "
                    f"(sample ppb={sample.get('ppb')})")
                continue
            for spec, op in zip(specs, blocked):
                if not isinstance(spec, SpecVal):
                    add(f"in_spec for operand '{op.name}' is not a "
                        "BlockSpec")
                    continue
                for msg in _check_spec(ev, spec, op, axes_and_prefetch,
                                       "in_spec"):
                    add(msg, spec.node)

            # out_shape vs the declared output contract
            outs = _resolve_outputs(contract, sample)
            structs = _as_list(ev.eval(parts["out_shape"], env)) \
                if parts["out_shape"] is not None else []
            if len(structs) != len(outs):
                add(f"{len(structs)} out_shape entries for {len(outs)} "
                    "declared outputs")
                continue
            group = contract.get("partial_group")
            out_ops = []
            for i, (st, (shape, dtype)) in enumerate(zip(structs, outs)):
                if not isinstance(st, StructVal):
                    add(f"out_shape[{i}] is not a ShapeDtypeStruct")
                    continue
                if st.shape != shape:
                    add(f"out_shape[{i}] {st.shape} != declared {shape}")
                if st.dtype != dtype:
                    tag = (f" (split-K '{group}' partials must be "
                           f"{dtype})" if group else "")
                    add(f"out_shape[{i}] dtype {st.dtype} != declared "
                        f"{dtype}{tag}")
                out_ops.append(OperandVal(f"out[{i}]", st.shape, st.dtype,
                                          None))

            # out_specs against the evaluated out_shape
            ospecs = _as_list(ev.eval(parts["out_specs"], env)) \
                if parts["out_specs"] is not None else []
            if len(ospecs) != len(out_ops):
                add(f"{len(ospecs)} out_specs for {len(out_ops)} outputs")
                continue
            for spec, op in zip(ospecs, out_ops):
                if not isinstance(spec, SpecVal):
                    continue
                for msg in _check_spec(ev, spec, op, axes_and_prefetch,
                                       "out_spec"):
                    add(msg, spec.node)
        except EvalError as e:
            add(f"could not verify against contract: {e}")
        for node, msg in problems:
            add(msg, node)

    return [Finding(rule=RULE, path=ctx.path, line=line, col=col,
                    symbol=site, message=msg)
            for msg, (line, col) in messages.items()]


# ---------------------------------------------------------------------------
# group-level checks: parity, handoff, fold
# ---------------------------------------------------------------------------
def _check_groups(path: str, contracts: Dict, groups: Dict) -> List[Finding]:
    findings: List[Finding] = []

    def add(symbol: str, msg: str):
        findings.append(Finding(rule=RULE, path=path, line=1, col=0,
                                symbol=symbol, message=msg))

    canonical: Dict[str, List[Tuple[Tuple[int, ...], str]]] = {}
    anchor: Dict[str, str] = {}
    for group in groups:
        members = [(site, c) for site, c in sorted(contracts.items())
                   if c.get("partial_group") == group]
        for site, contract in members:
            sample = _parity_sample(contract)
            if sample is None:
                add(site, f"partial group '{group}' member needs exactly "
                    "one sample marked _parity")
                continue
            try:
                outs = _resolve_outputs(contract, sample)
            except EvalError as e:
                add(site, f"could not resolve parity outputs: {e}")
                continue
            for i, (_, dtype) in enumerate(outs):
                if dtype != _F32:
                    add(site, f"partial group '{group}' output[{i}] "
                        f"declares dtype {dtype}; split-K (m, l, acc) "
                        "partials must be float32")
            if group not in canonical:
                canonical[group], anchor[group] = outs, site
            elif outs != canonical[group]:
                add(site, f"partial contract skew in group '{group}': "
                    f"{site} declares {outs} but {anchor[group]} declares "
                    f"{canonical[group]} (TPU/GPU parity broken)")

    # consumers must ingest exactly the group's partial shapes
    for site, contract in sorted(contracts.items()):
        consumes = contract.get("consumes")
        if not consumes:
            continue
        group = consumes.get("group")
        if group not in canonical:
            add(site, f"consumes unknown partial group '{group}'")
            continue
        sample = _parity_sample(contract)
        if sample is None:
            add(site, "consumer contract needs exactly one _parity sample")
            continue
        by_name = {op["name"]: op for op in contract.get("operands", ())}
        got = []
        try:
            for name in consumes.get("operands", ()):
                op = by_name.get(name)
                if op is None:
                    raise EvalError(f"consumed operand '{name}' not "
                                    "declared")
                got.append((_resolve_shape(op["shape"], sample),
                            op.get("dtype", _F32)))
        except EvalError as e:
            add(site, f"could not resolve consumed operands: {e}")
            continue
        if got != canonical[group]:
            add(site, f"handoff mismatch: consumes {got} but group "
                f"'{group}' emits {canonical[group]} "
                f"(declared by {anchor[group]})")

    # fold relations between groups (prefill q-block axis → decode batch)
    for group, meta in sorted(groups.items()):
        target = meta.get("folds_into")
        if not target:
            continue
        axis = meta.get("fold_axis", 0)
        if group not in canonical or target not in canonical:
            continue
        folded = [(s[:axis] + s[axis + 1:], d) for s, d in canonical[group]]
        if folded != canonical[target]:
            add(anchor[group],
                f"group '{group}' folded along axis {axis} gives {folded} "
                f"but group '{target}' emits {canonical[target]}")
    return findings


# ---------------------------------------------------------------------------
# the rule
# ---------------------------------------------------------------------------
def _enclosing_function(node: ast.AST) -> Optional[str]:
    cur = getattr(node, "_replint_parent", None)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur.name
        cur = getattr(cur, "_replint_parent", None)
    return None


def _is_registry_file(path: str) -> bool:
    return path.startswith("src/") and \
        path.endswith(_REGISTRY_REL.as_posix())


@register(
    RULE,
    "abstract interpretation of pallas_call launches against the declared "
    "kernel contracts: BlockSpec rank/divisibility, in-range index_maps "
    "over every grid point, f32 split-K partials, decode/prefill/combine "
    "handoff and TPU≡GPU parity",
    dirs=("kernels",))
def check(ctx: FileContext, project: Project) -> List[Finding]:
    inline_table, inline_groups = _inline_tables(ctx)
    if inline_table is not None:
        contracts, groups = inline_table, inline_groups
        require_contract = True
    elif ctx.path.startswith("src/"):
        contracts, groups = load_registry()
        require_contract = True
    else:
        # fixtures/examples without an inline table opt out entirely
        return []

    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and call_name(node) == "pallas_call"):
            continue
        site = _enclosing_function(node)
        contract = contracts.get(site) if site else None
        if contract is None:
            if require_contract:
                findings.append(Finding(
                    rule=RULE, path=ctx.path, line=node.lineno,
                    col=node.col_offset, symbol=site or "<module>",
                    message=f"pallas_call in '{site}' has no declared "
                    f"kernel contract (add it to "
                    f"{_REGISTRY_REL.as_posix()} or {INLINE_TABLE})"))
            continue
        findings.extend(_check_site(ctx, node, site, contract))

    if inline_table is not None or _is_registry_file(ctx.path):
        findings.extend(_check_groups(ctx.path, contracts, groups))
    return findings
