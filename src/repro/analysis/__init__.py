"""replint: project-native static analysis for the paged-serving stack.

Generic linters cannot see this repo's cross-layer contracts — that a
Pallas grid's rank must match its ``dimension_semantics``, that a kernel
knob accepted at the engine API must survive every hop down to the
``pallas_call``, that failures must speak the ``repro.errors`` taxonomy so
the engine can route them per request.  ``replint`` encodes those
contracts as AST/call-graph rules and proves them at lint time.

Usage::

    python -m repro.analysis                  # lint src/repro, text report
    python -m repro.analysis --json           # machine-readable report
    python -m repro.analysis --rules pallas-contract,knob-threading
    python -m repro.analysis --changed-only   # only files touched vs git
    python -m repro.analysis --write-baseline # grandfather current findings

Suppress a finding at the source with a trailing (or preceding-line)
comment::

    raise ValueError("boom")  # replint: disable=error-discipline -- why

See ``repro.analysis.core`` for the registry/baseline machinery and
``repro.analysis.checkers`` for the rules themselves.
"""

from repro.analysis import checkers  # noqa: F401  (registers the rules)
from repro.analysis.core import (BASELINE_VERSION, REPORT_VERSION, FileContext,
                                 Finding, FuncSig, Project, Rule, RULES,
                                 active, analyze_paths, apply_baseline,
                                 collect_files, load_baseline, register,
                                 render_json, render_text, write_baseline)

__all__ = [
    "BASELINE_VERSION",
    "REPORT_VERSION",
    "FileContext",
    "Finding",
    "FuncSig",
    "Project",
    "Rule",
    "RULES",
    "active",
    "analyze_paths",
    "apply_baseline",
    "collect_files",
    "load_baseline",
    "register",
    "render_json",
    "render_text",
    "write_baseline",
]
