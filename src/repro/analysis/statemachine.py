"""statemachine: bounded exhaustive model checking of the request
lifecycle.

``test_scheduler_preempt.py`` / ``test_prefix_cache.py`` *sample* the
scheduler+allocator state space with stress soaks; this rule *enumerates*
it.  The transition relation (admit → attach-prefix, chunk-grow, extend
(+preempt/resume), fork, cancel, fail, finish, evict) is factored behind
``LifecycleDriver`` — a pure driver over ``Scheduler`` /
``HostPageManager`` / ``PrefixCache`` whose every action runs on a
``clone()`` of the state — and BFS explores **every** interleaving of
enabled actions for small bounded configurations (``CONFIGS``: ≤3
requests × ≤8 pages × ≤2 pages per request, plus a prefix-cache-enabled
configuration), asserting at every reachable state:

  * ``refcount[p] == table occurrences of p + cache residency`` (the
    generalized allocator invariant — catches leaked refcount bumps such
    as the historical fork-without-rollback bug);
  * table rows belong only to LIVE requests / tracked forked rows (a row
    under a PREEMPTED or terminal rid is the historical
    extend-after-preempt aliasing bug);
  * free-list conservation: no duplicates, no referenced page on the
    list, ``free + referenced == num_pages``;
  * row geometry: ``len(row) == ceil(lens / page_size)``;
  * terminal cleanliness: terminal requests hold no slot/row, and when
    everything is terminal only cache-resident pages stay off the free
    list.

BFS order makes the first counterexample **minimal**: the finding
message carries the shortest action trace reaching the violation (read
left to right; each step is one driver action with its request id).

Fixture support: a file assigning ``REPLINT_STATEMACHINE_CASES`` (a
module-level list of ``(label, driver_factory)``) is loaded by path and
each factory's state space is explored — re-seeding a historical bug
into a ``LifecycleDriver`` subclass demonstrably rediscovers it (gated
by ``tests/test_statemachine.py``).  On the live tree the rule runs the
real driver over ``CONFIGS`` when it reaches ``serving/scheduler.py``.
"""

from __future__ import annotations

import ast
import importlib.util
from collections import Counter, deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.analysis.core import FileContext, Finding, Project, register

RULE = "statemachine"
FIXTURE_CASES = "REPLINT_STATEMACHINE_CASES"
MAX_STATES = 200_000
FORK_RID_BASE = 100


# ---------------------------------------------------------------------------
# bounded configurations
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ModelConfig:
    """One bounded exploration: every field is part of the proof's scope."""

    name: str
    num_pages: int
    page_size: int
    max_slots: int
    prompts: Tuple[Tuple[int, ...], ...]
    prefill_chunk: Optional[int] = None  # None = monolithic prefill
    max_new: int = 1                     # decode tokens per request
    fork: bool = False                   # enable the copy-on-write action
    cache: bool = False                  # wire a PrefixCache in
    headroom: int = 0
    # injected-teardown budgets: each run may cancel/fail at most this
    # many requests (the teardown paths are fully covered with 1; an
    # unbounded budget multiplies the space without new behaviors)
    cancel_budget: int = 1
    fail_budget: int = 1


# ≤3 requests × ≤8 pages × ≤2 pages per request, per the bounded-model
# contract documented in README §Static analysis.
CONFIGS: Tuple[ModelConfig, ...] = (
    # chunked prefill under pool pressure: stall/preempt/resume paths
    ModelConfig(name="chunked-preempt", num_pages=4, page_size=2,
                max_slots=2, prompts=((1, 2, 3), (1, 2, 3), (4, 5)),
                prefill_chunk=2),
    # monolithic + fork: copy-on-write tail reservation on a tight pool
    ModelConfig(name="fork-cow", num_pages=3, page_size=2, max_slots=2,
                prompts=((1, 2, 3), (4, 5)), fork=True),
    # prefix cache: attach/retain/evict interleaved with the lifecycle
    # (r0 and r2 share their full prefix; r1 diverges after one page)
    ModelConfig(name="prefix-cache", num_pages=6, page_size=2, max_slots=2,
                prompts=((1, 2, 3), (1, 2, 4), (1, 2, 3)), cache=True),
)


# ---------------------------------------------------------------------------
# the pure driver
# ---------------------------------------------------------------------------
class LifecycleDriver:
    """The scheduler/page-manager transition relation behind a pure
    interface: ``enabled()`` lists applicable actions, ``apply()``
    executes one, ``clone()`` branches the whole state, ``violations()``
    evaluates the allocator invariants.  Buggy fixture drivers override
    individual ``_do_*`` methods to re-seed historical defects."""

    def __init__(self, cfg: ModelConfig):
        # imports live here so the analysis package stays importable
        # without jax (paging pulls it in)
        from repro.core.paging import HostPageManager
        from repro.serving.request import Request
        from repro.serving.scheduler import Scheduler

        self.cfg = cfg
        mgr = HostPageManager(cfg.num_pages, cfg.page_size)
        cache = None
        if cfg.cache:
            from repro.core.prefix_cache import PrefixCache
            cache = PrefixCache(mgr)
        self.sched = Scheduler(
            mgr, max_slots=cfg.max_slots,
            max_seq_len=max(len(p) for p in cfg.prompts) + cfg.max_new,
            headroom_pages=cfg.headroom, prefill_chunk=cfg.prefill_chunk,
            prefix_cache=cache)
        self.requests = []
        for i, prompt in enumerate(cfg.prompts):
            req = Request(prompt=list(prompt), max_new_tokens=cfg.max_new,
                          rid=i)
            self.requests.append(req)
            self.sched.add(req)
        self.forked: FrozenSet[int] = frozenset()
        self.fork_count = 0
        self.cancel_count = 0
        self.fail_count = 0

    # -- cloning ---------------------------------------------------------
    def clone(self) -> "LifecycleDriver":
        from repro.serving.request import Request
        from repro.serving.scheduler import Scheduler

        new = object.__new__(type(self))
        new.cfg = self.cfg
        mgr = self.sched.mgr.clone()
        cache = self.sched.cache.clone(mgr) if self.sched.cache else None

        def clone_req(r):
            c = Request(prompt=list(r.prompt),
                        max_new_tokens=r.max_new_tokens, rid=r.rid)
            c.status = r.status
            c.slot = r.slot
            c.prefill_pos = r.prefill_pos
            c.cached_prefix = r.cached_prefix
            c.output = list(r.output)
            c.parent = r.parent
            c.error = r.error
            return c

        by_rid = {r.rid: clone_req(r) for r in self.requests}
        s = self.sched
        sched = object.__new__(Scheduler)
        sched.mgr = mgr
        sched.cache = cache
        for attr in ("max_slots", "max_seq_len", "headroom",
                     "prefill_chunk", "max_waiting", "admit_watermark",
                     "preempted", "prefill_stalls", "shed", "failed",
                     "cancelled", "deadline_misses"):
            setattr(sched, attr, getattr(s, attr))
        sched.waiting = [by_rid[r.rid] for r in s.waiting]
        sched.running = {slot: by_rid[r.rid]
                         for slot, r in s.running.items()}
        sched.failed_events = [by_rid[r.rid] for r in s.failed_events
                               if r.rid in by_rid]
        new.sched = sched
        new.requests = [by_rid[r.rid] for r in self.requests]
        new.forked = self.forked
        new.fork_count = self.fork_count
        new.cancel_count = self.cancel_count
        new.fail_count = self.fail_count
        return new

    # -- the transition relation ----------------------------------------
    def enabled(self) -> List[Tuple]:
        from repro.serving.request import Status, TERMINAL

        sched = self.sched
        actions: List[Tuple] = []
        if sched.waiting and len(sched.running) < sched.max_slots:
            actions.append(("admit",))
        live = list(sched.running.values())
        for r in live:
            if r.status is Status.PREFILLING:
                actions.append(("grow", r.rid))
        if any(r.status is Status.RUNNING for r in live):
            actions.append(("decode",))
        for r in live:
            if r.status is Status.RUNNING:
                actions.append(("finish", r.rid))
                if self.cfg.fork and self.fork_count < 1:
                    actions.append(("fork", r.rid))
            if self.fail_count < self.cfg.fail_budget:
                actions.append(("fail", r.rid))
        if self.cancel_count < self.cfg.cancel_budget:
            for r in self.requests:
                if r.status not in TERMINAL:
                    actions.append(("cancel", r.rid))
        for dst in sorted(self.forked):
            actions.append(("free_fork", dst))
        if sched.cache is not None and sched.cache._page_node:
            actions.append(("evict",))
        return actions

    def apply(self, action: Tuple) -> None:
        getattr(self, "_do_" + action[0])(*action[1:])

    def _req(self, rid: int):
        return next(r for r in self.requests if r.rid == rid)

    def _do_admit(self) -> None:
        self.sched.admit()

    def _do_grow(self, rid: int) -> None:
        """One chunked-prefill installment (the engine's per-step cache)."""
        from repro.serving.request import Status

        req = self._req(rid)
        if self.sched.grow_prefill(req):
            req.prefill_pos = min(req.prefill_pos + self.sched.prefill_chunk,
                                  req.total_len)
            if req.prefill_pos >= req.total_len:
                req.status = Status.RUNNING

    def _do_decode(self) -> None:
        """One decode step: extend every running row, sample one token."""
        from repro.serving.request import Status

        self.sched.extend_for_decode()
        for req in list(self.sched.running.values()):
            if (req.status is Status.RUNNING
                    and len(req.output) < self.cfg.max_new):
                req.output.append(7)

    def _do_finish(self, rid: int) -> None:
        self.sched.finish(self._req(rid))

    def _do_cancel(self, rid: int) -> None:
        self.cancel_count += 1
        self.sched.cancel(self._req(rid))

    def _do_fail(self, rid: int) -> None:
        from repro.errors import EngineError

        self.fail_count += 1
        self.sched.fail(self._req(rid), EngineError("injected fault"))

    def _do_fork(self, src_rid: int) -> None:
        """Copy-on-write child row (no scheduler request — the model
        tracks the bare row so ``fork``'s all-or-nothing contract is
        checkable in isolation)."""
        dst = FORK_RID_BASE + self.fork_count
        self.fork_count += 1
        if self.sched.mgr.fork(src_rid, dst):
            self.forked = self.forked | {dst}

    def _do_free_fork(self, dst: int) -> None:
        self.sched.mgr.free(dst)
        self.forked = self.forked - {dst}

    def _do_evict(self) -> None:
        self.sched.cache.reclaim(1)

    # -- canonical state ------------------------------------------------
    def state_key(self) -> Tuple:
        """Hashable quotient of the full state.

        Two abstractions keep the space finite and small, both sound
        because the dynamics never inspect the quotiented detail:

        * **page renaming** — physical page ids are interchangeable
          (every operation treats them opaquely), so pages are
          renumbered in first-appearance order over a fixed
          serialization (rows by rid, forked rows, cache trie by token
          path, then the free list in stack order);
        * **LRU rank** — the cache clock grows without bound; only each
          node's *rank* in the (last_use, seq) order affects future
          eviction choices, so the rank replaces the absolute clock.
        """
        mgr = self.sched.mgr
        rename: Dict[int, int] = {}

        def pid(p: int) -> int:
            if p not in rename:
                rename[p] = len(rename)
            return rename[p]

        reqs = tuple(
            (r.rid, r.status.value, r.slot, r.prefill_pos, r.cached_prefix,
             len(r.output),
             tuple(pid(p) for p in mgr.tables.get(r.rid, ())),
             mgr.lens.get(r.rid, -1))
            for r in self.requests)
        forked = tuple(
            (d, tuple(pid(p) for p in mgr.tables.get(d, ())),
             mgr.lens.get(d, -1))
            for d in sorted(self.forked))
        cache_key: Tuple = ()
        if self.sched.cache is not None:
            nodes = sorted(self.sched.cache._page_node.values(),
                           key=lambda n: (n.last_use, n.seq))
            rank = {id(n): i for i, n in enumerate(nodes)}

            def path(n) -> Tuple:
                parts = []
                while n.parent is not None:
                    parts.append(n.chunk)
                    n = n.parent
                return tuple(reversed(parts))

            cache_key = tuple(
                (p, pid(page), rk) for p, page, rk in sorted(
                    (path(n), n.page, rank[id(n)]) for n in nodes))
        free = tuple(pid(p) for p in mgr.free_list)
        # refcounts of renamed pages in rename order, then the refcount
        # multiset of any page not reached by the serialization (a leaked
        # page is renaming-equivalent to any other leaked page)
        by_new = sorted(rename, key=rename.get)
        refs = tuple(mgr.refcount[p] for p in by_new)
        leaked = tuple(sorted(mgr.refcount[p] for p in range(mgr.num_pages)
                              if p not in rename))
        return (reqs, tuple(r.rid for r in self.sched.waiting), free,
                refs, leaked, forked, cache_key,
                self.fork_count, self.cancel_count, self.fail_count)

    # -- the invariants --------------------------------------------------
    def violations(self) -> List[str]:
        from repro.serving.request import TERMINAL
        from repro.serving.scheduler import LIVE

        mgr = self.sched.mgr
        out: List[str] = []
        live_rids = {r.rid for r in self.requests if r.status in LIVE}
        allowed = live_rids | set(self.forked)
        for rid in mgr.tables:
            if rid not in allowed:
                status = next((r.status.value for r in self.requests
                               if r.rid == rid), "untracked")
                out.append(
                    f"table row held by non-live rid {rid} (status "
                    f"{status}): its pages can alias a later reservation")
        occ = Counter(p for row in mgr.tables.values() for p in row)
        resident = (set(self.sched.cache._page_node)
                    if self.sched.cache is not None else set())
        for p in range(mgr.num_pages):
            expect = occ.get(p, 0) + (1 if p in resident else 0)
            if mgr.refcount[p] != expect:
                out.append(
                    f"page {p} refcount {mgr.refcount[p]} != "
                    f"{occ.get(p, 0)} table occurrences + "
                    f"{int(p in resident)} cache residency")
        free = mgr.free_list
        if len(set(free)) != len(free):
            out.append("free list holds duplicate pages")
        for p in free:
            if mgr.refcount[p] != 0:
                out.append(f"page {p} on the free list with refcount "
                           f"{mgr.refcount[p]}")
        held = sum(1 for p in range(mgr.num_pages) if mgr.refcount[p] > 0)
        if len(set(free)) + held != mgr.num_pages:
            out.append(f"free-list conservation broken: {len(set(free))} "
                       f"free + {held} referenced != {mgr.num_pages}")
        for rid, row in mgr.tables.items():
            want = -(-mgr.lens.get(rid, 0) // mgr.page_size)
            if len(row) != want:
                out.append(f"rid {rid} holds {len(row)} pages for "
                           f"{mgr.lens.get(rid, 0)} tokens (want {want})")
        for r in self.requests:
            if r.status in TERMINAL and r.slot != -1:
                out.append(f"terminal rid {r.rid} still owns slot "
                           f"{r.slot}")
        if (not self.forked
                and all(r.status in TERMINAL for r in self.requests)):
            if len(free) + len(resident) != mgr.num_pages:
                out.append(
                    "terminal-state leak: all requests terminal but "
                    f"{mgr.num_pages - len(free) - len(resident)} "
                    "page(s) neither free nor cache-resident")
        return out


# ---------------------------------------------------------------------------
# BFS over the bounded state space
# ---------------------------------------------------------------------------
@dataclass
class ExploreResult:
    states: int = 0
    capped: bool = False
    trace: Optional[List[str]] = None       # minimal counterexample
    violations: List[str] = field(default_factory=list)


def _fmt(action: Tuple) -> str:
    return action[0] if len(action) == 1 else \
        f"{action[0]}({', '.join(str(a) for a in action[1:])})"


def explore(make_driver, max_states: int = MAX_STATES) -> ExploreResult:
    """BFS every interleaving; the first violation (BFS order = fewest
    actions) is returned with its minimal trace."""
    from repro.errors import EngineError

    res = ExploreResult()
    root = make_driver()
    root_key = root.state_key()
    # key -> (parent_key, action) for minimal-trace reconstruction
    seen: Dict[Tuple, Optional[Tuple]] = {root_key: None}

    def trace_to(key: Tuple, last: Optional[Tuple]) -> List[str]:
        steps: List[Tuple] = [last] if last is not None else []
        while seen[key] is not None:
            parent_key, action = seen[key]
            steps.append(action)
            key = parent_key
        return [_fmt(a) for a in reversed(steps)]

    queue = deque([(root, root_key)])
    while queue:
        drv, key = queue.popleft()
        res.states += 1
        bad = drv.violations()
        if bad:
            res.trace = trace_to(key, None)
            res.violations = bad
            return res
        for action in drv.enabled():
            nxt = drv.clone()
            try:
                nxt.apply(action)
            except EngineError as e:
                # an invariant guard tripping mid-transition IS a
                # counterexample (e.g. a double free the relation allows)
                res.trace = trace_to(key, action)
                res.violations = [f"{type(e).__name__}: {e}"]
                return res
            nkey = nxt.state_key()
            if nkey in seen:
                continue
            if len(seen) >= max_states:
                res.capped = True
                return res
            seen[nkey] = (key, action)
            queue.append((nxt, nkey))
    return res


# ---------------------------------------------------------------------------
# the rule
# ---------------------------------------------------------------------------
_result_cache: Dict[Tuple[str, int], List[Tuple[str, str]]] = {}


def _run_cases(cases: Sequence[Tuple]) -> List[Tuple[str, str]]:
    """[(label, message)] for every configuration that fails its proof."""
    failures: List[Tuple[str, str]] = []
    for label, factory in cases:
        res = explore(factory)
        if res.capped:
            failures.append((label, f"model check '{label}' exceeded "
                             f"{MAX_STATES} states — tighten the bounded "
                             "configuration"))
        elif res.violations:
            failures.append((
                label,
                f"model check '{label}' found an invariant violation "
                f"after {res.states} states: {res.violations[0]} — "
                f"minimal trace: {' -> '.join(res.trace) or '<initial>'}"))
    return failures


def _live_cases() -> List[Tuple]:
    return [(cfg.name, (lambda c=cfg: LifecycleDriver(c)))
            for cfg in CONFIGS]


def _fixture_cases(ctx: FileContext) -> Optional[Sequence[Tuple]]:
    if not any(isinstance(s, ast.Assign) and len(s.targets) == 1
               and isinstance(s.targets[0], ast.Name)
               and s.targets[0].id == FIXTURE_CASES
               for s in ctx.tree.body):
        return None
    path = Path(ctx.path)
    if not path.is_absolute():
        path = Path.cwd() / path
    spec = importlib.util.spec_from_file_location(
        "_replint_statemachine_fixture", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # type: ignore[union-attr]
    return getattr(mod, FIXTURE_CASES)


@register(
    RULE,
    "bounded exhaustive model checking of the request lifecycle: BFS over "
    "every admit/grow/extend/preempt/fork/cancel/fail/finish/evict "
    "interleaving of small configurations, asserting refcount == table "
    "occurrences + cache residency, free-list conservation and terminal "
    "cleanliness at every reachable state",
    dirs=("serving",))
def check(ctx: FileContext, project: Project) -> List[Finding]:
    is_live = (ctx.path.startswith("src/")
               and ctx.path.endswith("serving/scheduler.py"))
    cache_key = (ctx.path, hash(ctx.source))
    if cache_key not in _result_cache:
        if is_live:
            cases = _live_cases()
        else:
            cases = _fixture_cases(ctx)
            if cases is None:
                return []
        _result_cache[cache_key] = _run_cases(cases)
    return [Finding(rule=RULE, path=ctx.path, line=1, col=0, symbol=label,
                    message=message)
            for label, message in _result_cache[cache_key]]
