"""Whisper-medium [arXiv:2212.04356].

Encoder-decoder: 24 encoder + 24 decoder layers, d_model 1024, 16 heads
(MHA kv=16), d_ff 4096, vocab 51865.  The mel-spectrogram + conv frontend is
stubbed per the harness carve-out: input_specs() provides
(batch, 1500, d_model) frame embeddings.  Decoder self-attention uses the
paged KV cache; cross-attention KV over encoder frames is fixed-length.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1_024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4_096,
    vocab_size=51_865,
    activation="gelu_ungated",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=0.0,  # whisper uses learned/sinusoidal positions, not RoPE
    n_audio_frames=1_500,
    max_target_positions=448,
    axis_overrides={"kv_heads": ("model",)},
    source="arXiv:2212.04356",
)
