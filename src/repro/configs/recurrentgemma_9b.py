"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427].

38 layers, d_model 4096, 16 heads (MQA kv=1), d_ff 12288, vocab 256000.
Pattern: 2 RG-LRU recurrent blocks then 1 local sliding-window attention
(window 2048) — "1:2" attention:recurrent.  The local-attention layers use a
bounded *ring of pages* KV cache (pages past the window are freed).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="rglru",
    n_layers=38,
    d_model=4_096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    activation="gelu",
    layer_pattern="RRW",
    window=2_048,
    lru_width=4_096,
    conv1d_width=4,
    logit_softcap=30.0,
    rope_theta=10_000.0,
    source="arXiv:2402.19427",
)
