"""xLSTM-350M [arXiv:2405.04517].

24 blocks, d_model 1024, 4 heads, vocab 50304.  sLSTM + mLSTM mix: the paper's
xLSTM[7:1] ratio — one sLSTM block per 8, rest mLSTM.  Attention-free: the
paged-KV technique does not apply (O(1) recurrent state; see DESIGN.md
§Arch-applicability).  d_ff=0: blocks carry their own up/down projections.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="xlstm",
    n_layers=24,
    d_model=1_024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50_304,
    layer_pattern="MMMMMMMS",  # 7 mLSTM : 1 sLSTM
    paged_attention=False,
    source="arXiv:2405.04517",
)
