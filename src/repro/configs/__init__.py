"""Config registry: one module per assigned architecture (+ the paper's own).

``get_config(name)`` returns the full production ModelConfig;
``get_smoke(name)`` the reduced CPU-testable variant.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import INPUT_SHAPES, ModelConfig, RunConfig, make_run

_MODULES = {
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "llama3-405b": "repro.configs.llama3_405b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "whisper-medium": "repro.configs.whisper_medium",
    "granite-8b": "repro.configs.granite_8b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    # the paper's own evaluation model (LLaMA-7B on FMS)
    "llama2-7b": "repro.configs.llama2_7b",
}

ASSIGNED: List[str] = [k for k in _MODULES if k != "llama2-7b"]


def list_configs() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return get_config(name).smoke()


__all__ = [
    "ASSIGNED",
    "INPUT_SHAPES",
    "ModelConfig",
    "RunConfig",
    "get_config",
    "get_smoke",
    "list_configs",
    "make_run",
]
