"""Llama-3 405B [arXiv:2407.21783].

126 layers, d_model 16384, 128 heads (GQA kv=8), d_ff 53248, vocab 128256.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    activation="silu",
    rope_theta=500_000.0,
    axis_overrides={"embed": ("data",)},  # FSDP: 405B params
    decode_scheme="kvp",
    source="arXiv:2407.21783",
)
