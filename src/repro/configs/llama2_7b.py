"""LLaMA-7B — the paper's own evaluation model (32 heads, d_model 4096).

Used by the paper-claims benchmarks (latency/memory/perplexity-equivalence).
MHA (kv = heads = 32), SwiGLU, RMSNorm, vocab 32000.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11_008,
    vocab_size=32_000,
    activation="silu",
    rope_theta=10_000.0,
    source="paper §III-B / arXiv:2302.13971",
)
