"""Granite-8B-Code [arXiv:2405.04324].

36 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 49152,
llama-style (SwiGLU, RMSNorm), tied embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=49_152,
    activation="silu",
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    axis_overrides={"embed": ("data",)},
    source="arXiv:2405.04324",
)
