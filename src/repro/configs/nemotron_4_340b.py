"""Nemotron-4-340B [arXiv:2402.16819].

96 layers, d_model 18432, 96 heads (GQA kv=8), d_ff 73728, vocab 256000,
squared-ReLU MLP (no gating), LayerNorm, RoPE.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18_432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    activation="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    axis_overrides={"embed": ("data",)},  # FSDP: 340B params
    decode_scheme="kvp",
    source="arXiv:2402.16819",
)
