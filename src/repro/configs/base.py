"""Model / run configuration system.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
``family`` selects the model builder in ``repro.models``; everything else is
data.  ``smoke()`` derives the reduced CPU-testable variant mandated by the
harness (≤2 layers, d_model ≤ 512, ≤4 experts).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------
INPUT_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | xlstm | rglru | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "silu"  # silu | relu2 | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0  # expert hidden size (granite/olmoe use d_ff as expert size)
    router_aux_coef: float = 0.01
    # Switch-style expert capacity factor; 0 => dropless (C = T, exact).
    # Production MoE training uses a finite factor so dispatch buffers are
    # O(T·k/E), not O(T·E); routing/drops are identical across forward/
    # prefill/decode paths, so numerical-equivalence tests still hold.
    moe_capacity: float = 0.0
    # explicit shard_map expert parallelism (distributed/ep.py) instead of
    # the GSPMD-annotated dispatch; beyond-paper §Perf H1 optimization
    moe_ep: bool = False

    # --- hybrid / pattern ---
    # layer_pattern: string of block codes, tiled to n_layers.
    #   'A' global attention   'W' sliding-window attention
    #   'R' RG-LRU recurrent   'M' mLSTM    'S' sLSTM
    #   'C' cross-attention + self-attention (VLM)
    layer_pattern: str = "A"
    window: int = 0  # sliding-window size for 'W' layers
    conv1d_width: int = 4  # RG-LRU temporal conv width
    lru_width: int = 0  # RG-LRU recurrent width (0 -> d_model)

    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500
    max_target_positions: int = 448

    # --- vlm ---
    n_image_tokens: int = 1601
    cross_attn_every: int = 5  # one cross-attn layer per N layers
    d_vision: int = 1280  # stubbed ViT output width (projector input)

    # --- paged KV cache (the paper's technique) ---
    page_size: int = 64
    paged_attention: bool = True  # paper flag: drop-in enable/disable
    # beyond-paper (§Perf H3): store KV pages in int8 with a fixed
    # symmetric scale — halves decode's dominant HBM traffic (lossy;
    # the paper's C1 exact-equivalence claim applies to kv_dtype="bf16")
    kv_dtype: str = "base"  # "base" (= activation dtype) | "int8"
    kv_scale: float = 0.05  # int8 dequant step (calibration knob)

    # fully unroll the layer-group scan (used by the dry-run's L1/L2 cost
    # probes: XLA's cost_analysis counts a while-loop body ONCE regardless
    # of trip count, so the probes must lower loop-free — DESIGN.md §7)
    scan_unroll: bool = False

    # --- numerics / distribution ---
    dtype: str = "float32"
    param_dtype: str = "float32"
    remat: str = "none"  # none | dots | full
    axis_overrides: Dict[str, Any] = field(default_factory=dict)
    # decode sharding scheme: "tp" (vLLM-style: batch x data, heads x model,
    # KV replicated over model) or "kvp" (flash-decoding: pages sharded over
    # model too, online-softmax psum combine). "auto" picks by KV size.
    decode_scheme: str = "auto"
    source: str = ""  # citation

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_ff(self) -> int:
        return self.d_ff_expert or self.d_ff

    def pattern(self) -> str:
        """Per-layer block codes, length n_layers."""
        pat = self.layer_pattern
        reps = -(-self.n_layers // len(pat))
        return (pat * reps)[: self.n_layers]

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        d_model = min(self.d_model, 256)
        updates: Dict[str, Any] = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            page_size=8,
            window=min(self.window, 64) if self.window else 0,
            lru_width=min(self.lru_width, d_model) if self.lru_width else 0,
            remat="none",
        )
        if self.is_moe:
            updates.update(n_experts=4, top_k=2, d_ff_expert=64)
        if self.n_encoder_layers:
            updates.update(n_encoder_layers=2, n_audio_frames=16)
        if self.family == "vlm":
            updates.update(n_image_tokens=8, cross_attn_every=2,
                           layer_pattern="CA")  # both block types in 2 layers
        if self.family == "rglru":
            updates.update(conv1d_width=4, layer_pattern="RW")
        if self.family == "xlstm":
            updates.update(layer_pattern="MS")
        return replace(self, **updates)

    def replace(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class RunConfig:
    """A fully-specified runnable: model + input shape + paging pool."""

    model: ModelConfig
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    variant: str = "base"  # base | swa (sliding-window long-context variant)
    # pool slack: pages beyond the exact requirement, power-of-two rounded
    pool_slack: float = 1.0

    @property
    def pages_per_seq(self) -> int:
        ps = self.model.page_size
        return -(-self.seq_len // ps)

    @property
    def num_pages(self) -> int:
        exact = self.global_batch * self.pages_per_seq
        n = max(1, int(exact * self.pool_slack))
        # paper §IV-B1: power-of-two pool allocations
        p = 1
        while p < n:
            p <<= 1
        return p


def make_run(model: ModelConfig, shape_name: str, variant: str = "base") -> RunConfig:
    spec = INPUT_SHAPES[shape_name]
    m = model
    if variant == "swa" and m.family in ("dense", "moe", "vlm"):
        # beyond-paper sliding-window variant for sub-quadratic long context
        pat = "W" if m.family != "vlm" else m.layer_pattern.replace("A", "W")
        m = m.replace(layer_pattern=pat, window=m.window or 4096)
    return RunConfig(model=m, seq_len=spec["seq_len"],
                     global_batch=spec["global_batch"], kind=spec["kind"],
                     variant=variant)
