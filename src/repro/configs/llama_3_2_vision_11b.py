"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision].

Language backbone: 40 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 128256, with a cross-attention (image) layer every 5th layer.
The ViT vision encoder is stubbed per the harness carve-out: input_specs()
provides (batch, n_image_tokens, d_model) projected patch embeddings.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4_096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    activation="silu",
    rope_theta=500_000.0,
    n_image_tokens=1_601,
    cross_attn_every=5,
    layer_pattern="CAAAA",  # cross-attn layer leads each group of 5
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
