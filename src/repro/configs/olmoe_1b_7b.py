"""OLMoE-1B-7B [arXiv:2409.02060].

16 layers, d_model 2048, 16 heads (kv=16), expert d_ff 1024, vocab 50304,
64 experts top-8.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2_048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1_024,
    vocab_size=50_304,
    activation="silu",
    rope_theta=10_000.0,
    n_experts=64,
    top_k=8,
    moe_capacity=1.25,  # Switch-style capacity factor (production dispatch bound)
    d_ff_expert=1_024,
    axis_overrides={"kv_heads": ("model",)},  # 16 kv heads == model axis
    source="arXiv:2409.02060",
)
