"""Granite-3.0-1B-A400M (MoE) [hf:ibm-granite/granite-3.0-1b-a400m-base].

24 layers, d_model 1024, 16 heads (GQA kv=8), expert d_ff 512, vocab 49155,
32 experts top-8.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1_024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    activation="silu",
    rope_theta=10_000.0,
    n_experts=32,
    top_k=8,
    moe_capacity=1.25,  # Switch-style capacity factor (production dispatch bound)
    d_ff_expert=512,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
