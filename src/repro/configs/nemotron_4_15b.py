"""Nemotron-4-15B [arXiv:2402.16819].

32 layers, d_model 6144, 48 heads (GQA kv=8), d_ff 24576, vocab 256000,
squared-ReLU MLP, LayerNorm.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6_144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=256_000,
    activation="relu2",
    norm="layernorm",
    rope_theta=10_000.0,
    axis_overrides={"embed": ("data",)},
    source="arXiv:2402.16819",
)
