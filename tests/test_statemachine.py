"""Bounded exhaustive model checking of the request lifecycle (ISSUE 9).

Covers, per the acceptance contract:

  * every live bounded configuration (chunked prefill under pressure,
    fork/copy-on-write, prefix cache) explores to completion with zero
    invariant violations and without hitting the state cap;
  * the two historical allocator bugs — extend-after-preempt aliasing
    (PR 4) and the fork refcount rollback leak — re-seeded as fixture
    drivers are *rediscovered* by the checker, each with a minimal
    counterexample trace;
  * ``LifecycleDriver.clone`` (and the ``HostPageManager`` /
    ``PrefixCache`` clone support underneath) is a true deep copy: BFS
    branches never bleed state into each other;
  * the ``statemachine`` replint rule reports fixture failures as
    findings with the trace in the message, and stays quiet on serving
    files without a case table.
"""

import importlib.util
from pathlib import Path

import pytest

from repro.analysis import analyze_paths
from repro.analysis.statemachine import (CONFIGS, LifecycleDriver,
                                         ModelConfig, explore)

ROOT = Path(__file__).resolve().parent.parent
FIXTURE = ROOT / "tests" / "fixtures" / "analysis" / "serving" / \
    "statemachine_bugs.py"


def load_fixture_cases():
    spec = importlib.util.spec_from_file_location("_sm_bugs", FIXTURE)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return dict(mod.REPLINT_STATEMACHINE_CASES)


# ---------------------------------------------------------------------------
# the live transition relation satisfies the invariants exhaustively
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.name for c in CONFIGS])
def test_live_config_explores_clean(cfg):
    res = explore(lambda: LifecycleDriver(cfg))
    assert not res.capped, f"{cfg.name} exceeded the state cap"
    assert res.violations == [], \
        f"{cfg.name}: {res.violations} via {res.trace}"
    assert res.trace is None
    # exhaustive means exhaustive: a trivial state count would mean the
    # interleavings never actually branched
    assert res.states > 100


# ---------------------------------------------------------------------------
# re-seeded historical bugs are rediscovered with minimal traces
# ---------------------------------------------------------------------------
def test_extend_after_preempt_bug_rediscovered():
    res = explore(load_fixture_cases()["extend-after-preempt"])
    assert res.violations
    # the aliasing shows up as a table row held by a preempted rid (and
    # the refcount/occupancy ledger breaking with it)
    assert any("non-live rid" in v or "refcount" in v
               for v in res.violations)
    # BFS order guarantees the first counterexample is minimal: admit
    # both requests, then one decode pass that preempts and re-extends
    assert res.trace == ["admit", "decode"]


def test_fork_rollback_bug_rediscovered():
    res = explore(load_fixture_cases()["fork-no-rollback"])
    assert res.violations
    assert any("refcount" in v for v in res.violations)
    assert res.trace == ["admit", "fork(0)"]


def test_fixed_tree_passes_the_buggy_configs():
    # the same bounded configs the buggy drivers fail are clean under
    # the live transition relation — the proof discriminates
    cases = load_fixture_cases()
    for label, factory in cases.items():
        cfg = factory().cfg
        res = explore(lambda: LifecycleDriver(cfg))
        assert res.violations == [], f"{label} config dirty on live tree"


# ---------------------------------------------------------------------------
# clone isolation (the BFS correctness precondition)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", CONFIGS, ids=[c.name for c in CONFIGS])
def test_clone_is_deeply_isolated(cfg):
    drv = LifecycleDriver(cfg)
    drv.apply(("admit",))
    key = drv.state_key()
    branch = drv.clone()
    assert branch.state_key() == key
    # drive the branch a few transitions; the original must not move
    for _ in range(3):
        actions = branch.enabled()
        if not actions:
            break
        branch.apply(actions[0])
    assert drv.state_key() == key


def test_page_manager_clone_is_deep():
    from repro.core.paging import HostPageManager
    mgr = HostPageManager(4, 2)
    assert mgr.reserve(0, 3)
    snap_tables = {r: list(row) for r, row in mgr.tables.items()}
    snap_free = list(mgr.free_list)
    new = mgr.clone()
    new.free(0)
    assert new.reserve(7, 4)
    assert mgr.tables == snap_tables
    assert mgr.free_list == snap_free
    assert new.cache is None  # the hook never leaks across clones


def test_prefix_cache_clone_is_deep():
    from repro.core.paging import HostPageManager
    from repro.core.prefix_cache import PrefixCache
    mgr = HostPageManager(4, 2)
    cache = PrefixCache(mgr)
    assert mgr.reserve(0, 4)
    cache.insert([1, 2, 3, 4], mgr.tables[0], 4)
    mgr.free(0)
    assert cache.resident_pages == 2

    mgr2 = mgr.clone()
    cache2 = cache.clone(mgr2)
    assert mgr2.cache is cache2
    assert cache2.resident_pages == 2
    # evicting in the clone leaves the original trie and refcounts alone
    assert cache2.reclaim(2) == 2
    assert cache.resident_pages == 2
    assert sum(mgr.refcount) == 2
    # and the clone attaches from its own copy of the trie
    assert cache2.resident_pages == 0


# ---------------------------------------------------------------------------
# the replint rule plumbing
# ---------------------------------------------------------------------------
def test_statemachine_rule_reports_fixture_failures_with_traces():
    findings = analyze_paths([], ROOT, rules=["statemachine"],
                             files=[FIXTURE])
    by_label = {f.symbol: f.message for f in findings}
    assert set(by_label) == {"extend-after-preempt", "fork-no-rollback"}
    for msg in by_label.values():
        assert "minimal trace:" in msg
        assert "invariant violation" in msg


def test_statemachine_rule_quiet_without_case_table(tmp_path):
    plain = tmp_path / "serving" / "helper.py"
    plain.parent.mkdir()
    plain.write_text("def admit(x):\n    return x\n")
    assert analyze_paths([], tmp_path, rules=["statemachine"],
                         files=[plain]) == []


# ---------------------------------------------------------------------------
# bounds hygiene: the documented envelope is what the code explores
# ---------------------------------------------------------------------------
def test_configs_stay_inside_documented_bounds():
    for cfg in CONFIGS:
        assert isinstance(cfg, ModelConfig)
        assert len(cfg.prompts) <= 3
        assert cfg.num_pages <= 8
        for prompt in cfg.prompts:
            pages = -(-(len(prompt) + cfg.max_new) // cfg.page_size)
            assert pages <= 2 + 1  # ≤2 prompt pages (+1 decode spill)
