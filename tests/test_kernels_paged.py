"""Pallas paged-decode kernels vs the pure-jnp oracle (ref.py).

Sweeps shapes / dtypes / GQA ratios / windows / softcap, per the harness
contract: every kernel is validated in interpret mode against ref.py.
The numeric sweeps run per *backend* — the TPU lowering (scalar-prefetch
BlockSpec pipeline) and the GPU/Triton lowering (in-kernel block-table
gathers) are gated against the identical oracles, so neither backend can
drift from the other's semantics.  Off the target hardware both run
through the Pallas interpreter (``interpret=True``); on real TPUs/GPUs
the same tests compile.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as kvcache, paging
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

from conftest import assert_close

BACKENDS = ["tpu", "gpu"]


def partials_fn(backend):
    """The backend's split-K partials entry point (same contract both)."""
    if backend == "gpu":
        from repro.kernels.paged_attention.paged_attention_gpu import (
            paged_attention_partials_gpu)
        return paged_attention_partials_gpu
    from repro.kernels.paged_attention.paged_attention import (
        paged_attention_partials)
    return paged_attention_partials


def make_case(rng, B, H, Hkv, D, page, max_pages, lens, dtype=jnp.float32,
              scatter=True):
    """Random paged cache with per-seq lens; returns (q, kp, vp, tables, lens)."""
    num_pages = B * max_pages + 3
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D), dtype)
    # shuffled physical pages (scattered layout — the paper's whole point)
    perm = np.random.RandomState(0).permutation(num_pages)
    tables = np.full((B, max_pages), -1, np.int32)
    lens = np.asarray(lens, np.int32)
    k = 0
    for b in range(B):
        n = -(-int(lens[b]) // page)
        tables[b, :n] = perm[k:k + n]
        k += n
    return q, kp, vp, jnp.asarray(tables), jnp.asarray(lens)


SWEEP = [
    # B, H, Hkv, D, page, max_pages, lens
    (1, 4, 4, 32, 8, 4, [25]),          # MHA
    (3, 8, 2, 64, 16, 4, [64, 17, 1]),  # GQA 4:1
    (2, 16, 1, 128, 8, 3, [24, 9]),     # MQA
    (4, 8, 8, 16, 4, 8, [32, 31, 5, 2]),
    (2, 8, 4, 128, 64, 2, [128, 100]),  # production page size
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", SWEEP, ids=[str(i) for i in range(len(SWEEP))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(rng, case, dtype, backend):
    B, H, Hkv, D, page, mp, lens = case
    q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, mp, lens,
                                        dtype)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    out = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                          interpret=True, backend=backend)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert_close(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("softcap", [0.0, 30.0])
@pytest.mark.parametrize("window", [0, 12, 40])
def test_kernel_window_softcap(rng, window, softcap, backend):
    B, H, Hkv, D, page = 2, 8, 4, 32, 8
    lens = [61, 23]
    if window > 0:
        ring = -(-window // page) + 1
        mp = ring
        # windowed ring cache: logical page index wraps mod ring
        num_pages = B * mp
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D))
        vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D))
        tables = jnp.arange(num_pages, dtype=jnp.int32).reshape(B, mp)
        lens = jnp.asarray(lens, jnp.int32)
    else:
        q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, 8, lens)
    ref = paged_attention_ref(q, kp, vp, tables, lens, window=window,
                              softcap=softcap)
    out = paged_attention(q, kp, vp, tables, lens, window=window,
                          softcap=softcap, impl="pallas", interpret=True,
                          backend=backend)
    assert_close(out, ref)


@pytest.mark.parametrize("backend", BACKENDS)
def test_kernel_equals_contiguous_attention(rng, backend):
    """The paper's C1: paged == contiguous attention, end to end."""
    B, H, Hkv, D, page, mp = 2, 8, 4, 32, 8, 6
    lens = [41, 29]
    q, kp, vp, tables, lens_a = make_case(rng, B, H, Hkv, D, page, mp, lens)
    # materialise contiguous K/V via Alg.1 GATHER and run dense attention
    k, v = kvcache.gather_layer(kp, vp, tables, mp * page)
    from repro.core.attention import decode_attention_contiguous
    ref = decode_attention_contiguous(q, k, v, lens_a)
    out = paged_attention(q, kp, vp, tables, lens_a, impl="pallas",
                          interpret=True, backend=backend)
    assert_close(out, ref, rtol=1e-4, atol=1e-4)


def test_blockspec_mxu_alignment():
    """Structural check: kernel block shapes are MXU-aligned for the
    production page sizes (DESIGN.md §7)."""
    for page_size in (64, 128):
        assert page_size % 8 == 0  # sublane
    for head_dim in (128,):
        assert head_dim % 128 == 0  # lane


@pytest.mark.parametrize("backend", BACKENDS)
def test_int8_kv_kernel_matches_ref(rng, backend):
    """Beyond-paper int8 KV pages: kernel dequant == ref dequant, and both
    approximate the bf16 result within quantization error."""
    B, H, Hkv, D, page, mp = 2, 8, 4, 32, 8, 4
    q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, mp, [25, 17])
    scale = 0.035  # ~4.4 sigma for unit-normal KV
    kp8 = jnp.clip(jnp.round(kp / scale), -127, 127).astype(jnp.int8)
    vp8 = jnp.clip(jnp.round(vp / scale), -127, 127).astype(jnp.int8)
    ref8 = paged_attention_ref(q, kp8, vp8, tables, lens, kv_scale=scale)
    out8 = paged_attention(q, kp8, vp8, tables, lens, impl="pallas",
                           interpret=True, kv_scale=scale, backend=backend)
    assert_close(out8, ref8, rtol=1e-4, atol=1e-4)
    exact = paged_attention_ref(q, kp, vp, tables, lens)
    err = float(jnp.max(jnp.abs(ref8 - exact)))
    assert err < 0.2  # quantization-level error, not garbage


def test_fully_masked_row_is_zero(rng):
    """len=0 sequences (dead batch slots) must produce zeros, not NaNs."""
    q, kp, vp, tables, _ = make_case(rng, 2, 4, 4, 16, 8, 2, [9, 16])
    lens = jnp.asarray([9, 0], jnp.int32)
    tables = tables.at[1].set(-1)
    out = paged_attention(q, kp, vp, tables, lens, impl="ref")
    assert not np.isnan(np.asarray(out)).any()
    assert np.abs(np.asarray(out)[1]).max() == 0.0


# ---------------------------------------------------------------------------
# blocked multi-page KV + flash-decoding split-K (kernel v2)

BLOCK_SPLIT_GRID = [(ppb, ns) for ppb in (1, 2, 4) for ns in (1, 3)]
VARIANTS = ["plain", "window", "softcap", "int8"]


def _variant_case(rng, variant):
    """Ragged lens leaving partial blocks AND empty split-K partitions:
    seq1's 2 live pages put every later split's whole range past len."""
    B, H, Hkv, D, page = 2, 8, 4, 32, 8
    if variant == "window":
        window, mp = 20, -(-20 // page) + 1  # ring cache
        num_pages = B * mp
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D))
        vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D))
        tables = jnp.arange(num_pages, dtype=jnp.int32).reshape(B, mp)
        lens = jnp.asarray([65, 9], jnp.int32)
        return q, kp, vp, tables, lens, dict(window=window)
    q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, 9, [65, 9])
    if variant == "softcap":
        return q, kp, vp, tables, lens, dict(softcap=30.0)
    if variant == "int8":
        scale = 0.035
        kp8 = jnp.clip(jnp.round(kp / scale), -127, 127).astype(jnp.int8)
        vp8 = jnp.clip(jnp.round(vp / scale), -127, 127).astype(jnp.int8)
        return q, kp8, vp8, tables, lens, dict(kv_scale=scale)
    return q, kp, vp, tables, lens, {}


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("ppb,ns", BLOCK_SPLIT_GRID)
@pytest.mark.parametrize("variant", VARIANTS)
def test_blocked_splitk_matches_ref(rng, ppb, ns, variant, backend):
    q, kp, vp, tables, lens, kw = _variant_case(rng, variant)
    ref = paged_attention_ref(q, kp, vp, tables, lens, **kw)
    out = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                          interpret=True, pages_per_block=ppb,
                          num_splits=ns, backend=backend, **kw)
    # acceptance bar: split-K path agrees with ref.py to <= 1e-5 max abs
    assert float(jnp.max(jnp.abs(out - ref))) <= 1e-5


@pytest.mark.parametrize("backend", BACKENDS)
def test_splitk_partials_match_ref(rng, backend):
    """Kernel split-K partials == the ref.py partial-softmax oracle, and the
    combine reproduces full attention (incl. empty partitions) — both
    backends emit the identical (m, l, acc) contract."""
    from repro.kernels.paged_attention.paged_attention import combine_partials
    from repro.kernels.paged_attention.ref import (
        combine_partials_ref, paged_attention_partials_ref)

    B, H, Hkv, D, page, mp = 2, 8, 4, 32, 8, 9
    ppb, ns = 2, 3
    q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, mp, [65, 9])
    scale = 1.0 / np.sqrt(D)
    m, l, acc = partials_fn(backend)(
        q.reshape(B, Hkv, H // Hkv, D), kp, vp, tables, lens, scale=scale,
        interpret=True, pages_per_block=ppb, num_splits=ns)
    mr, lr, accr = paged_attention_partials_ref(
        q, kp, vp, tables, lens, num_splits=ns, pages_per_block=ppb)
    assert float(jnp.max(jnp.abs(m - mr))) <= 1e-5
    assert float(jnp.max(jnp.abs(l - lr))) <= 1e-5
    assert float(jnp.max(jnp.abs(acc - accr))) <= 1e-5
    out = combine_partials(m, l, acc).reshape(B, H, D)
    ref_out = combine_partials_ref(mr, lr, accr)
    assert_close(out, ref_out, rtol=1e-5, atol=1e-5)
    assert_close(out, paged_attention_ref(q, kp, vp, tables, lens),
                 rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_split_partition_is_neutral(rng, backend):
    """A split whose whole page range is past len must emit (NEG_INF, 0, 0)
    and change nothing in the combine."""
    from repro.kernels.paged_attention.paged_attention import NEG_INF

    B, H, Hkv, D, page, mp = 1, 4, 2, 16, 4, 8
    q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, mp, [5])
    m, l, acc = partials_fn(backend)(
        q.reshape(B, Hkv, H // Hkv, D), kp, vp, tables, lens,
        scale=1.0 / np.sqrt(D), interpret=True,
        pages_per_block=1, num_splits=4)
    # pages 2..7 are dead -> splits 1..3 are empty partitions
    assert np.all(np.asarray(m)[:, :, 1:] == NEG_INF)
    assert np.all(np.asarray(l)[:, :, 1:] == 0.0)
    assert np.all(np.asarray(acc)[:, :, 1:] == 0.0)


def test_blocked_kernel_grid_step_reduction():
    """Acceptance: >= 4x fewer grid steps at seq 2048 / page 16 than the
    one-page-per-step baseline, with auto-tuned knobs."""
    from repro.kernels.paged_attention.ops import choose_decode_params
    from repro.kernels.paged_attention.paged_attention import decode_grid_steps

    max_pages = 2048 // 16
    ppb, ns, _ = choose_decode_params(max_pages, 16, 128)
    baseline = decode_grid_steps(max_pages)  # one page per step
    blocked = decode_grid_steps(max_pages, pages_per_block=ppb, num_splits=ns)
    assert baseline == max_pages
    assert blocked * 4 <= baseline


def test_auto_knobs_clamp_to_legal_ranges():
    from repro.kernels.paged_attention.ops import choose_decode_params

    ppb, ns, cm = choose_decode_params(1, 64, 64)  # single-page cache
    assert (ppb, ns) == (1, 1)
    assert cm == "jnp"  # no split-K → no combine kernel
    ppb, ns, cm = choose_decode_params(4, 16, 64, pages_per_block=64,
                                       num_splits=64)
    assert ppb == 4 and ns <= 4  # clamped to the table
    assert cm == ("pallas" if ns > 1 else "jnp")
    ppb, ns, cm = choose_decode_params(256, 16, 128)
    assert ppb * 16 == 128  # MXU-aligned block
    assert 1 <= ns <= 8
    assert cm == "pallas"  # long sequence → split-K → fused combine
    # explicit modes pass through; junk is rejected
    assert choose_decode_params(256, 16, 128, combine_mode="jnp")[2] == "jnp"
    with pytest.raises(ValueError):
        choose_decode_params(256, 16, 128, combine_mode="cuda")


def test_gpu_auto_knobs_warp_shaped():
    """GPU heuristics target warp-width blocks (64 KV tokens, not the
    MXU's 128) and split earlier/wider for SM occupancy."""
    from repro.kernels.paged_attention.ops import choose_decode_params

    ppb_t, ns_t, _ = choose_decode_params(256, 16, 128, backend="tpu")
    ppb_g, ns_g, cm_g = choose_decode_params(256, 16, 128, backend="gpu")
    assert ppb_t * 16 == 128  # MXU-width block
    assert ppb_g * 16 == 64  # warp-width block
    assert ns_g >= ns_t  # GPU splits at least as wide
    assert ns_g <= 16
    # auto combine on GPU is the jnp epilogue even under split-K: the
    # fused combine kernel is a TPU lowering and would run through the
    # interpreter on a real GPU's hot path; explicit "pallas" still works
    assert cm_g == "jnp"
    assert choose_decode_params(256, 16, 128, combine_mode="pallas",
                                backend="gpu")[2] == "pallas"
    # short sequences: single split, no combine kernel — both backends
    assert choose_decode_params(1, 64, 64, backend="gpu") == (1, 1, "jnp")
    # explicit knobs pass through clamping identically on both backends
    assert (choose_decode_params(16, 16, 64, 2, 4, backend="gpu")[:2]
            == choose_decode_params(16, 16, 64, 2, 4, backend="tpu")[:2])


def test_backend_resolution():
    """backend=None auto-resolves from the platform (TPU lowering off-GPU);
    explicit names pass through and junk is rejected."""
    from repro.kernels import resolve_backend

    assert resolve_backend("tpu") == "tpu"
    assert resolve_backend("gpu") == "gpu"
    auto = resolve_backend(None)
    assert auto == ("gpu" if jax.default_backend() == "gpu" else "tpu")
    assert resolve_backend("auto") == auto
    with pytest.raises(ValueError):
        resolve_backend("cuda")


def test_backends_agree_bitwise_partition(rng):
    """Both lowerings share decode_partition, so their outputs agree with
    each other (not just with the oracle) across knob points."""
    q, kp, vp, tables, lens = make_case(rng, 2, 8, 4, 32, 8, 9, [65, 9])
    for ppb, ns in [(1, 1), (2, 3), (4, 2)]:
        o_tpu = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                                interpret=True, pages_per_block=ppb,
                                num_splits=ns, backend="tpu")
        o_gpu = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                                interpret=True, pages_per_block=ppb,
                                num_splits=ns, backend="gpu")
        assert float(jnp.max(jnp.abs(o_tpu - o_gpu))) <= 1e-5
