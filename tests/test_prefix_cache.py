"""Global prefix cache (radix-indexed page sharing): the acceptance gate.

The contract this suite pins down (tentpole of the prefix-cache PR):

  * released requests index their *full* KV pages into a radix trie keyed
    by page-granular token chunks; admission attaches new requests to the
    longest cached prefix (refcount++ per shared page, ``mgr.lens`` /
    ``prefill_pos`` advanced past the match) and prefills only the
    suffix;
  * residency is one refcount share, so ``mgr.free`` *retains* cached
    pages, and the allocator invariant generalizes to
    ``refcount[p] == table occurrences + (1 if cache-resident)`` — which
    ``check_cache_invariants`` asserts exhaustively, together with
    free-list conservation and trie consistency;
  * eviction is LRU, leaf-first, refcount-aware: attached chains are
    untouchable, and ``mgr.reserve`` reclaims detached pages on demand,
    so a warm cache is capacity (``mgr.available_pages``), never
    deadlock;
  * hits are provably lossless: greedy cache-on output equals cache-off
    output for monolithic and chunked prefill, through mid-prefill
    stalls, preemption of attached requests, and eviction racing
    admission.

Run via ``make test-prefix`` (CI leg ``prefix``).
"""

import random

import jax
import pytest

from repro.configs import get_smoke
from repro.core.paging import HostPageManager
from repro.core.prefix_cache import PrefixCache
from repro.errors import SchedulerInvariantError
from repro.serving import Engine, Request
from repro.serving.request import Status
from repro.serving.scheduler import Scheduler

PS = 4  # page size for the model-free unit tests


# ---------------------------------------------------------------------------
# the cache-aware allocator invariant (supersedes the exact-refcount check
# in test_scheduler_preempt for managers with a cache wired in)
# ---------------------------------------------------------------------------
def check_cache_invariants(mgr: HostPageManager, cache: PrefixCache,
                           sched: Scheduler = None):
    # 1. trie consistency: every resident page is reachable from the root
    #    exactly once, with coherent parent/child/chunk links.
    reachable = set()

    def walk(node):
        for chunk, child in node.children.items():
            assert child.parent is node and child.chunk == chunk
            assert len(chunk) == mgr.page_size, "non-page-granular chunk"
            assert cache._page_node.get(child.page) is child
            assert child.page not in reachable, "page cached twice"
            reachable.add(child.page)
            walk(child)

    walk(cache.root)
    assert reachable == set(cache._page_node)

    # 2. refcount == table occurrences + residency share, for every page.
    occ = {}
    for row in mgr.tables.values():
        for p in row:
            occ[p] = occ.get(p, 0) + 1
    for p in range(mgr.num_pages):
        want = occ.get(p, 0) + (1 if p in reachable else 0)
        assert mgr.refcount[p] == want, (
            f"page {p}: refcount {mgr.refcount[p]} != {occ.get(p, 0)} "
            f"occurrences + {int(p in reachable)} residency")

    # 3. free-list conservation: free xor held, no duplicates, whole pool.
    free = set(mgr.free_list)
    assert len(free) == len(mgr.free_list), "duplicate pages on free list"
    held = set(occ) | reachable
    assert not (free & held), "page simultaneously free and held"
    assert len(held) + len(mgr.free_list) == mgr.num_pages

    # 4. table rows only under live rids (when a scheduler is in play).
    if sched is not None:
        live = {r.rid for r in sched.running.values()}
        assert set(mgr.tables) == live
        assert set(mgr.lens) == live


def _mgr_cache(pages=16):
    mgr = HostPageManager(num_pages=pages, page_size=PS)
    return mgr, PrefixCache(mgr)


# ---------------------------------------------------------------------------
# trie unit tests: insert / match / attach / dedupe
# ---------------------------------------------------------------------------
def test_insert_caches_only_full_pages_and_free_retains():
    mgr, cache = _mgr_cache()
    toks = list(range(10))  # 2 full pages + 2-token partial tail
    assert mgr.reserve(0, 10)
    row = list(mgr.tables[0])
    assert cache.insert(toks, row, written=10) == 2
    assert cache.resident_pages == 2
    assert row[2] not in cache._page_node, "partial tail must not cache"
    check_cache_invariants(mgr, cache)
    mgr.free(0)
    # retain-on-free: the cached pages hold their residency reference
    assert mgr.refcount[row[0]] == 1 and mgr.refcount[row[1]] == 1
    assert row[0] not in mgr.free_list and row[1] not in mgr.free_list
    assert row[2] in mgr.free_list, "uncached tail recycles normally"
    check_cache_invariants(mgr, cache)


def test_insert_below_one_page_caches_nothing():
    mgr, cache = _mgr_cache()
    assert mgr.reserve(0, 3)
    assert cache.insert([1, 2, 3], mgr.tables[0], written=3) == 0
    assert cache.resident_pages == 0
    mgr.free(0)
    assert len(mgr.free_list) == mgr.num_pages


def test_attach_aliases_longest_cached_prefix():
    mgr, cache = _mgr_cache()
    toks = list(range(12))
    assert mgr.reserve(0, 12)
    donor_row = list(mgr.tables[0])
    cache.insert(toks, donor_row, written=12)
    mgr.free(0)

    # full-depth hit
    matched = cache.attach(1, toks + [99, 98], max_tokens=13)
    assert matched == 12
    assert mgr.tables[1] == donor_row and mgr.lens[1] == 12
    assert all(mgr.refcount[p] == 2 for p in donor_row)
    assert cache.hits == 1 and cache.hit_tokens == 12
    check_cache_invariants(mgr, cache)
    mgr.free(1)
    assert all(mgr.refcount[p] == 1 for p in donor_row)

    # max_tokens caps the match page-granularly (11 // 4 -> 2 pages):
    # admission passes total-1 so a full-prompt hit still prefills the
    # last position (sampling needs its logits)
    assert cache.attach(2, list(toks), max_tokens=11) == 8
    assert mgr.lens[2] == 8
    mgr.free(2)

    # divergence mid-prefix: only the agreeing pages are shared
    assert cache.attach(3, toks[:6] + [77] * 6, max_tokens=11) == 4
    mgr.free(3)
    check_cache_invariants(mgr, cache)


def test_attach_miss_and_duplicate_insert_dedupes():
    mgr, cache = _mgr_cache()
    toks = [5] * 8
    assert mgr.reserve(0, 8)
    cache.insert(toks, mgr.tables[0], written=8)
    assert cache.attach(1, [6] * 8, max_tokens=7) == 0
    assert cache.misses == 1 and 1 not in mgr.tables
    # a second owner of identical content: chunks already present keep
    # the existing page; the duplicate is not indexed and recycles
    assert mgr.reserve(2, 8)
    dup_row = list(mgr.tables[2])
    assert cache.insert(toks, dup_row, written=8) == 0
    assert cache.resident_pages == 2
    mgr.free(2)
    assert all(p in mgr.free_list for p in dup_row)
    mgr.free(0)
    check_cache_invariants(mgr, cache)


def test_attach_rejects_rid_with_live_table_row():
    mgr, cache = _mgr_cache()
    assert mgr.reserve(0, 8)
    cache.insert([1] * 8, mgr.tables[0], written=8)
    with pytest.raises(SchedulerInvariantError, match="attach"):
        cache.attach(0, [1] * 8, max_tokens=7)


# ---------------------------------------------------------------------------
# eviction: LRU, leaf-first, refcount-aware, reclaim-on-demand
# ---------------------------------------------------------------------------
def test_reclaim_refuses_attached_chains():
    mgr, cache = _mgr_cache()
    toks = list(range(8))
    assert mgr.reserve(0, 8)
    cache.insert(toks, mgr.tables[0], written=8)
    mgr.free(0)
    cache.attach(1, toks, max_tokens=100)
    assert cache.reclaimable() == 0, "attached pages are not capacity"
    assert cache.reclaim(10) == 0
    assert cache.resident_pages == 2
    mgr.free(1)  # detach
    assert cache.reclaimable() == 2
    check_cache_invariants(mgr, cache)


def test_reclaim_is_lru_and_leaf_first():
    mgr, cache = _mgr_cache()
    a_toks, b_toks = [1] * 8, [2] * 8
    assert mgr.reserve(0, 8)
    a_row = list(mgr.tables[0])
    cache.insert(a_toks, a_row, written=8)
    mgr.free(0)
    assert mgr.reserve(1, 8)
    b_row = list(mgr.tables[1])
    cache.insert(b_toks, b_row, written=8)
    mgr.free(1)
    # touch chain A (attach bumps last_use): B becomes the LRU chain
    cache.attach(2, a_toks, max_tokens=100)
    mgr.free(2)

    # leaf-first: one eviction takes B's *deepest* page, not its root
    assert cache.reclaim(1) == 1
    assert b_row[1] not in cache._page_node
    assert b_row[0] in cache._page_node
    check_cache_invariants(mgr, cache)
    # next eviction finishes B before touching the fresher A
    assert cache.reclaim(1) == 1
    assert b_row[0] not in cache._page_node
    assert a_row[0] in cache._page_node and a_row[1] in cache._page_node
    assert cache.evicted_pages == 2
    check_cache_invariants(mgr, cache)


def test_reserve_reclaims_detached_pages_on_demand():
    mgr, cache = _mgr_cache(pages=4)
    toks = list(range(16))
    assert mgr.reserve(0, 16)
    cache.insert(toks, mgr.tables[0], written=16)
    mgr.free(0)
    assert len(mgr.free_list) == 0, "cache holds the whole pool"
    assert mgr.available_pages == 4, "detached cache counts as capacity"
    # a fresh reservation forces LRU eviction inside reserve()
    assert mgr.reserve(1, 8)
    assert cache.evicted_pages == 2
    # the *shallow* prefix survives (leaf-first keeps the trie a prefix)
    assert cache.match(toks, max_tokens=100) != []
    check_cache_invariants(mgr, cache)
    mgr.free(1)
    assert cache.clear() == 2
    assert len(mgr.free_list) == mgr.num_pages
    assert all(c == 0 for c in mgr.refcount)


def test_fork_and_cache_compose():
    """`fork` aliasing and cache residency stack on the same refcounts:
    the generalized invariant holds through fork / free / retain."""
    mgr, cache = _mgr_cache()
    toks = [3] * 8
    assert mgr.reserve(0, 8)
    row = list(mgr.tables[0])
    cache.insert(toks, row, written=8)
    assert mgr.fork(0, 1) is True
    assert all(mgr.refcount[p] == 3 for p in row)  # 2 tables + residency
    check_cache_invariants(mgr, cache)
    mgr.free(0)
    mgr.free(1)
    assert all(mgr.refcount[p] == 1 for p in row)  # retained
    check_cache_invariants(mgr, cache)


# ---------------------------------------------------------------------------
# scheduler integration: admission attach, retain-on-release
# ---------------------------------------------------------------------------
def test_scheduler_admit_attaches_and_retains_on_finish():
    mgr, cache = _mgr_cache()
    sched = Scheduler(mgr, max_slots=2, max_seq_len=64, prefix_cache=cache)
    a = Request(prompt=list(range(12)), max_new_tokens=4)
    sched.add(a)
    assert len(sched.admit()) == 1
    assert a.cached_prefix == 0, "cold cache: no attach"
    a_row = list(mgr.tables[a.rid])
    sched.finish(a)  # RUNNING row: written = min(lens, total-1) = 11
    assert cache.resident_pages == 2
    check_cache_invariants(mgr, cache, sched)

    b = Request(prompt=list(range(12)), max_new_tokens=4)
    sched.add(b)
    assert len(sched.admit()) == 1
    assert b.cached_prefix == 8 and b.prefill_pos == 8
    assert mgr.tables[b.rid][:2] == a_row[:2], "hit must alias donor pages"
    assert mgr.lens[b.rid] == 12, "suffix reserved past the match"
    check_cache_invariants(mgr, cache, sched)
    sched.finish(b)
    check_cache_invariants(mgr, cache, sched)


def test_scheduler_full_prompt_hit_still_prefills_one_position():
    mgr, cache = _mgr_cache()
    sched = Scheduler(mgr, max_slots=2, max_seq_len=64, prefix_cache=cache,
                      prefill_chunk=4)
    prompt = [7] * 8  # exactly 2 pages, both will be cached
    a = Request(prompt=list(prompt), max_new_tokens=4)
    sched.add(a)
    sched.admit()  # first chunk (4 tokens) reserved
    a.prefill_pos = 4  # ...and "run" by the engine
    assert sched.grow_prefill(a)  # second chunk reserved
    a.prefill_pos = 8
    sched.finish(a)  # PREFILLING row: written = prefill_pos = 8
    assert cache.resident_pages == 2

    b = Request(prompt=list(prompt), max_new_tokens=4)
    sched.add(b)
    sched.admit()
    # the cap (total-1 = 7 tokens -> 1 page) leaves the last page to
    # prefill so its logits exist for the first sample
    assert b.cached_prefix == 4 and b.prefill_pos == 4
    assert b.prefill_pos < b.total_len
    check_cache_invariants(mgr, cache, sched)


def test_scheduler_preempt_retains_then_reattaches():
    mgr, cache = _mgr_cache(pages=8)
    sched = Scheduler(mgr, max_slots=2, max_seq_len=256, headroom_pages=1,
                      prefill_chunk=8, prefix_cache=cache)
    a = Request(prompt=[4] * 20, max_new_tokens=4)
    sched.add(a)
    sched.admit()  # first chunk (8 tokens) reserved
    a.prefill_pos = 8
    assert sched.grow_prefill(a)  # second chunk reserved
    a.prefill_pos = 16  # two chunks written: 4 full pages
    sched._preempt(a)
    assert a.status is Status.PREEMPTED and a.prefill_pos == 0
    assert cache.resident_pages == 4, "preempted prefix retained"
    check_cache_invariants(mgr, cache, sched)
    # re-admission attaches to its own retained pages: near-zero re-prefill
    assert len(sched.admit()) == 1
    assert a.cached_prefix == 16 and a.prefill_pos == 16
    check_cache_invariants(mgr, cache, sched)


# ---------------------------------------------------------------------------
# engine gates: configurations where page sharing would be unsound
# ---------------------------------------------------------------------------
def test_engine_rejects_unsound_configs():
    cfg = get_smoke("llama2-7b")
    with pytest.raises(ValueError, match="paged"):
        Engine(cfg, paged=False, prefix_cache=True, max_slots=2,
               max_seq_len=32)
    with pytest.raises(ValueError, match="window"):
        Engine(cfg.replace(layer_pattern="AW", window=12), prefix_cache=True,
               max_slots=2, max_seq_len=32)
    with pytest.raises(ValueError, match="cross"):
        Engine(get_smoke("whisper-medium"), paged=True, prefix_cache=True,
               max_slots=2, max_seq_len=32)
    # recurrentgemma's pattern is RW: its window gate fires first, so use
    # the window-free recurrent config to reach the recurrence gate
    with pytest.raises(ValueError, match="recurrent"):
        Engine(get_smoke("xlstm-350m"), paged=True, prefix_cache=True,
               max_slots=2, max_seq_len=32)
    with pytest.raises(ValueError, match="window"):
        Engine(get_smoke("recurrentgemma-9b"), paged=True, prefix_cache=True,
               max_slots=2, max_seq_len=32)


# ---------------------------------------------------------------------------
# engine equality: cache-on output == cache-off output (greedy, <= 1e-5
# logit agreement makes the argmax chain identical)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def base_engine():
    cfg = get_smoke("llama2-7b")
    eng = Engine(cfg, max_slots=2, max_seq_len=64,
                 rng=jax.random.PRNGKey(7))
    return eng


def _new_engine(base, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq_len", 64)
    kw.setdefault("rng", jax.random.PRNGKey(11))
    return Engine(base.cfg, params=base.params, **kw)


def _run_checked(eng, reqs, max_steps=400):
    """Drive requests to completion, asserting the cache-aware allocator
    invariants after every engine step."""
    for r in reqs:
        eng.add_request(r)
    for _ in range(max_steps):
        if all(r.done for r in reqs):
            break
        eng.step()
        if eng.prefix_cache is not None:
            check_cache_invariants(eng.mgr, eng.prefix_cache, eng.scheduler)
    assert all(r.done for r in reqs)
    return reqs


HEAD = [9] * 24  # shared "system prompt" head (3 pages at page_size 8)


def test_engine_monolithic_warm_hit_matches_cold(base_engine):
    tails = ([], [1, 2, 3, 4, 5])
    mk = lambda tail: Request(prompt=HEAD + list(tail), max_new_tokens=6)

    off = _new_engine(base_engine)
    ref_a = off.generate([mk(tails[0])])[0]
    ref_b = off.generate([mk(tails[1])])[0]

    on = _new_engine(base_engine, prefix_cache=True)
    a = _run_checked(on, [mk(tails[0])])[0]
    b = _run_checked(on, [mk(tails[1])])[0]
    assert a.status is Status.FINISHED and b.status is Status.FINISHED
    assert a.output == ref_a.output, "cold request must match cache-off"
    assert b.cached_prefix > 0, "warm request never hit"
    assert b.output == ref_b.output, "hit request must match cache-off"
    rep = on.robustness_report()
    assert rep["prefix_hits"] >= 1
    assert rep["prefix_hit_tokens"] >= b.cached_prefix
    mem = on.memory_report()
    assert mem["cached_pages"] > 0
    assert mem["reclaimable_pages"] == mem["cached_pages"], (
        "all requests done: every cached page must be detached")


def test_engine_chunked_warm_hit_matches_cold(base_engine):
    mk = lambda t: Request(prompt=HEAD + [5] * t, max_new_tokens=5)
    off = _new_engine(base_engine, prefill_chunk=8)
    ref_a = off.generate([mk(0)])[0]
    ref_b = off.generate([mk(9)])[0]

    on = _new_engine(base_engine, prefill_chunk=8, prefix_cache=True)
    a = _run_checked(on, [mk(0)])[0]
    b = _run_checked(on, [mk(9)])[0]
    assert a.output == ref_a.output
    assert b.cached_prefix > 0
    assert b.output == ref_b.output
    assert on.robustness_report()["prefix_hits"] >= 1


def test_engine_progressive_insert_hits_midprefill_donor(base_engine):
    """A request admitted while the donor is still PREFILLING attaches to
    the donor's already-inserted pages (progressive insert), and both
    outputs match the cache-off run."""
    prompt = [3] * 40
    mk = lambda: Request(prompt=list(prompt), max_new_tokens=4)
    off = _new_engine(base_engine, prefill_chunk=8)
    ref_a, ref_b = off.generate([mk(), mk()])

    on = _new_engine(base_engine, prefill_chunk=8, prefix_cache=True)
    a = mk()
    on.add_request(a)
    for _ in range(3):  # a few chunks land; a is still mid-prefill
        on.step()
    assert a.status is Status.PREFILLING and a.prefill_pos >= 16
    b = mk()
    _run_checked(on, [b], max_steps=200)
    for _ in range(100):
        if a.done:
            break
        on.step()
    assert a.done and b.done
    assert b.cached_prefix > 0, "mid-prefill donor pages never hit"
    assert a.output == ref_a.output
    assert b.output == ref_b.output
    check_cache_invariants(on.mgr, on.prefix_cache, on.scheduler)


def test_engine_eviction_races_admission_losslessly(base_engine):
    """Cold admission against a pool the cache has entirely absorbed:
    ``reserve`` must evict LRU detached pages mid-admission and the new
    request's output must still match the cache-off engine."""
    ps = base_engine.cfg.page_size
    # pool == pages_per_seq (the floor): 8 pages at max_seq_len 64
    off = _new_engine(base_engine, pool_tokens=64)
    warm_p, cold_p = [3] * 5 * ps, [4] * 7 * ps
    ref = off.generate([Request(prompt=list(cold_p), max_new_tokens=2)])[0]

    on = _new_engine(base_engine, pool_tokens=64, prefix_cache=True)
    _run_checked(on, [Request(prompt=list(warm_p), max_new_tokens=2)])
    assert on.prefix_cache.resident_pages >= 5
    free_before = len(on.mgr.free_list)
    r = _run_checked(on, [Request(prompt=list(cold_p), max_new_tokens=2)])[0]
    assert r.status is Status.FINISHED
    assert r.output == ref.output
    assert on.prefix_cache.evicted_pages > 0, (
        f"admission never forced eviction (free before: {free_before})")


def test_engine_pressure_with_attached_requests_matches_cold(base_engine):
    """Two warm-hit requests with distinct tails on a minimum-size pool:
    stalls/preemptions of cache-attached requests must stay output-
    transparent (re-admission re-attaches to the retained prefix)."""
    ps = base_engine.cfg.page_size
    head = [7] * 3 * ps
    # 8 decode tokens: both requests are mid-decode past page 5 at the
    # same time, so peak live demand (3 shared + 3 + 3 pages) exceeds the
    # 8-page pool and eviction alone cannot save it (every resident page
    # is attached) — a stall or preemption is forced
    mk = lambda tail_tok: Request(prompt=head + [tail_tok] * 2 * ps,
                                  max_new_tokens=8)
    off = _new_engine(base_engine, max_slots=1)
    ref_w = off.generate([Request(prompt=list(head), max_new_tokens=2)])[0]
    ref_a = off.generate([mk(11)])[0]
    ref_b = off.generate([mk(12)])[0]

    on = _new_engine(base_engine, max_slots=3, pool_tokens=8 * ps,
                     prefill_chunk=ps, prefix_cache=True)
    w = _run_checked(on, [Request(prompt=list(head), max_new_tokens=2)])[0]
    assert w.output == ref_w.output
    assert on.prefix_cache.resident_pages >= 3, "head never cached"
    a, b = _run_checked(on, [mk(11), mk(12)], max_steps=600)
    assert a.status is Status.FINISHED and b.status is Status.FINISHED
    assert a.cached_prefix > 0 and b.cached_prefix > 0
    assert a.output == ref_a.output
    assert b.output == ref_b.output
    rep = on.robustness_report()
    assert rep["preempted"] + rep["prefill_stalls"] >= 1, (
        "pool pressure never materialised: the test lost its point")
    # drain the cache: the pool must come back whole
    assert on.mgr.used_pages == on.prefix_cache.resident_pages
    on.prefix_cache.clear()
    assert on.mgr.used_pages == 0
    assert sorted(on.mgr.free_list) == list(range(on.num_pages))
    assert all(c == 0 for c in on.mgr.refcount)


def test_engine_cancel_and_fork_with_cache(base_engine):
    """Cancellation retains written pages; fork composes with residency
    refcounts; invariants hold throughout."""
    ps = base_engine.cfg.page_size
    on = _new_engine(base_engine, max_slots=3, prefix_cache=True,
                     prefill_chunk=ps)
    long_req = Request(prompt=[6] * 5 * ps, max_new_tokens=4)
    on.add_request(long_req)
    for _ in range(3):
        on.step()
    assert long_req.status is Status.PREFILLING
    assert long_req.prefill_pos >= 2 * ps
    assert on.cancel_request(long_req.rid)
    check_cache_invariants(on.mgr, on.prefix_cache, on.scheduler)
    assert on.prefix_cache.resident_pages >= 2, (
        "cancelled mid-prefill request must retain its written pages")

    # same prompt again: hits the cancelled request's retained prefix
    redo = Request(prompt=[6] * 5 * ps, max_new_tokens=4)
    _run_checked(on, [redo])
    assert redo.cached_prefix > 0

    # fork a running request while the cache holds shares of its pages
    parent = Request(prompt=[6] * 5 * ps, max_new_tokens=8)
    on.add_request(parent)
    while parent.status is not Status.RUNNING:
        on.step()
    child = on.fork_request(parent, max_new_tokens=4)
    check_cache_invariants(on.mgr, on.prefix_cache, on.scheduler)
    for _ in range(200):
        if parent.done and child.done:
            break
        on.step()
    assert parent.status is Status.FINISHED
    assert child.status is Status.FINISHED
    check_cache_invariants(on.mgr, on.prefix_cache, on.scheduler)


# ---------------------------------------------------------------------------
# the acceptance stress: 250 steps of admit/attach/evict/preempt/cancel
# with the generalized invariants asserted after every step
# ---------------------------------------------------------------------------
def test_prefix_cache_scheduler_stress_invariants():
    rnd = random.Random(0xFACE)
    mgr = HostPageManager(num_pages=24, page_size=4)
    cache = PrefixCache(mgr)
    sched = Scheduler(mgr, max_slots=4, max_seq_len=256, headroom_pages=1,
                      prefill_chunk=8, prefix_cache=cache)
    heads = ([1] * 12, [2] * 20, [3] * 8)  # shared system-prompt menu
    all_reqs = []

    def submit():
        head = rnd.choice(heads)
        tail = [rnd.randrange(10, 90) for _ in range(rnd.randint(0, 12))]
        r = Request(prompt=list(head) + tail,
                    max_new_tokens=rnd.randint(2, 10))
        all_reqs.append(r)
        sched.add(r)

    def drive_prefill_chunks():
        # mirror Engine._prefill_chunk_step (full chunk per row: the
        # global budget is an engine concern; the allocator paths are
        # identical either way)
        for r in sorted(sched.running.values(), key=lambda x: x.rid):
            if r.status is not Status.PREFILLING:
                continue
            if sched.running.get(r.slot) is not r:
                continue
            if not sched.grow_prefill(r):
                continue
            if sched.running.get(r.slot) is not r:
                continue
            r.prefill_pos = min(r.prefill_pos + sched.prefill_chunk,
                                r.total_len)
            if r.prefill_pos >= r.total_len:
                r.status = Status.RUNNING

    for _ in range(3):
        submit()
    for step in range(250):
        if len(sched.waiting) < 2 and rnd.random() < 0.6:
            submit()
        sched.admit()
        check_cache_invariants(mgr, cache, sched)
        drive_prefill_chunks()
        check_cache_invariants(mgr, cache, sched)
        if any(r.status is Status.RUNNING for r in sched.running.values()):
            sched.extend_for_decode()
            for r in sched.running.values():
                if r.status is Status.RUNNING:
                    r.output.append(0)
            check_cache_invariants(mgr, cache, sched)
        live = [r for r in all_reqs
                if not r.done and r.status is not Status.PREEMPTED]
        if live and rnd.random() < 0.05:
            sched.cancel(rnd.choice(live))
            check_cache_invariants(mgr, cache, sched)
        for r in list(sched.running.values()):
            if (r.status is Status.RUNNING
                    and len(r.output) >= r.max_new_tokens):
                sched.finish(r)
        check_cache_invariants(mgr, cache, sched)
        sched.failed_events.clear()

    # the schedule must have exercised every hard path
    assert cache.hits >= 5, "stress never hit the cache"
    assert cache.evicted_pages >= 1, "stress never evicted"
    assert sched.preempted >= 1, "stress never preempted"
    assert sched.cancelled >= 2, "stress never cancelled"

    # drain, then clear the cache: the pool must come back whole
    for _ in range(2000):
        if not sched.has_work:
            break
        sched.admit()
        drive_prefill_chunks()
        if any(r.status is Status.RUNNING for r in sched.running.values()):
            sched.extend_for_decode()
            for r in sched.running.values():
                if r.status is Status.RUNNING:
                    r.output.append(0)
        for r in list(sched.running.values()):
            if (r.status is Status.RUNNING
                    and len(r.output) >= r.max_new_tokens):
                sched.finish(r)
        check_cache_invariants(mgr, cache, sched)
    assert not sched.has_work
    assert mgr.used_pages == cache.resident_pages
    cache.clear()
    assert len(mgr.free_list) == mgr.num_pages
    assert all(c == 0 for c in mgr.refcount)
    assert cache.resident_pages == 0
