"""Chunked paged prefill: kernel conformance + model/engine equivalence.

The acceptance gate for the chunked-prefill contract (ISSUE 5):

  * the prefix-aware paged prefill kernels (TPU scalar-prefetch lowering
    and GPU/Triton lowering) match `ref.paged_prefill_ref` across
    ``pages_per_block`` × ``num_splits`` × ``q_block`` × GQA layouts —
    both share `decode_partition`'s page ranges and the decode kernel's
    ``(m, l, acc)`` partial contract;
  * splitting any prompt into ``prefill_chunk``-token installments
    (resuming each chunk from the cached prefix pages at ``mgr.lens``)
    reproduces the monolithic prefill's logits to <= 1e-5 — at the model
    level for every chunkable family (dense / windowed / VLM / enc-dec)
    and at the engine level for sampled outputs, for chunk sizes of one
    page, two pages, and a non-page-aligned odd size;
  * the chunked scheduler's failure paths are output-transparent: a
    request preempted mid-run re-prefills chunk-by-chunk to the same
    tokens, and a prefill stalled on a dry pool resumes from its cached
    pages (no recompute) with identical output.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.kernels.paged_attention.ops import paged_prefill
from repro.kernels.paged_attention.paged_attention import (
    combine_prefill_partials, paged_prefill_partials)
from repro.kernels.paged_attention.ref import (paged_attention_ref,
                                               paged_prefill_partials_ref,
                                               paged_prefill_ref)
from repro.models.api import build_model
from repro.serving import Engine, Request
from repro.serving.request import Status

from conftest import assert_close

BACKENDS = ["tpu", "gpu"]


# ---------------------------------------------------------------------------
# kernel conformance (both lowerings, one oracle)
# ---------------------------------------------------------------------------
def make_prefill_case(seed, B, H, Hkv, D, page, max_pages, kv_lens, q_start):
    rng = np.random.RandomState(seed)
    num_pages = B * max_pages + 3
    kv_lens = np.asarray(kv_lens, np.int32)
    q_start = np.asarray(q_start, np.int32)
    C = int((kv_lens - q_start).max())
    q = jnp.asarray(rng.randn(B, C, H, D), jnp.float32)
    kp = jnp.asarray(rng.randn(num_pages, page, Hkv, D), jnp.float32)
    vp = jnp.asarray(rng.randn(num_pages, page, Hkv, D), jnp.float32)
    perm = rng.permutation(num_pages)
    tables = np.full((B, max_pages), -1, np.int32)
    k = 0
    for b in range(B):
        n = -(-int(kv_lens[b]) // page)
        tables[b, :n] = perm[k:k + n]
        k += n
    return (q, kp, vp, jnp.asarray(tables), jnp.asarray(kv_lens),
            jnp.asarray(q_start))


PREFILL_SWEEP = [
    # B, H, Hkv, D, page, max_pages, kv_lens, q_start
    (1, 4, 4, 32, 8, 4, [25], [9]),            # MHA, mid-prompt resume
    (2, 8, 2, 16, 8, 5, [29, 11], [13, 0]),    # GQA, mixed resume points
    (2, 4, 1, 16, 4, 6, [23, 8], [0, 3]),      # MQA, whole-prompt row
    (3, 4, 2, 16, 16, 2, [17, 32, 5], [16, 15, 0]),  # single-token chunk row
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", PREFILL_SWEEP,
                         ids=[str(i) for i in range(len(PREFILL_SWEEP))])
@pytest.mark.parametrize("ppb,splits,q_block", [
    (1, 1, 1), (2, 1, 3), (1, 2, 4), (2, 3, 2), (3, 2, 128),
])
def test_prefill_kernel_matches_ref(case, backend, ppb, splits, q_block):
    q, kp, vp, tables, kv_lens, q_start = make_prefill_case(7, *case)
    ref = paged_prefill_ref(q, kp, vp, tables, kv_lens, q_start)
    out = paged_prefill(q, kp, vp, tables, kv_lens, q_start, impl="pallas",
                        interpret=True, backend=backend,
                        pages_per_block=ppb, num_splits=splits,
                        q_block=q_block)
    # only live chunk rows are specified (padding rows are don't-care)
    for b in range(q.shape[0]):
        ql = int(kv_lens[b] - q_start[b])
        assert_close(out[b, :ql], ref[b, :ql], rtol=1e-5, atol=1e-5)


def test_prefill_partials_match_partials_oracle():
    """The TPU lowering's raw (m, l, acc) partials agree with the split-K
    partials oracle — the shared contract, not just the combined output."""
    case = PREFILL_SWEEP[1]
    q, kp, vp, tables, kv_lens, q_start = make_prefill_case(3, *case)
    D = q.shape[-1]
    kw = dict(scale=1.0 / np.sqrt(D), pages_per_block=2, num_splits=2,
              q_block=3)
    m, l, acc = paged_prefill_partials(q, kp, vp, tables, kv_lens, q_start,
                                       interpret=True, **kw)
    m_r, l_r, acc_r = paged_prefill_partials_ref(q, kp, vp, tables, kv_lens,
                                                 q_start, **kw)
    # live-masked comparison via the combine (dead-partition m encodings
    # may differ in magnitude; what must agree is the merged result) ...
    out = combine_prefill_partials(m, l, acc, q.shape[1], 3)
    out_r = combine_prefill_partials(m_r, l_r, acc_r, q.shape[1], 3)
    for b in range(q.shape[0]):
        ql = int(kv_lens[b] - q_start[b])
        assert_close(out[b, :ql], out_r[b, :ql], rtol=1e-5, atol=1e-5)
    # ... and the per-split mass/max on fully-live rows agree directly
    assert_close(l[0, :, :, :, 0], l_r[0, :, :, :, 0], rtol=1e-5, atol=1e-5)
    assert_close(m[0, :, :, :, 0], m_r[0, :, :, :, 0], rtol=1e-5, atol=1e-5)


def test_prefill_single_token_chunk_equals_decode_oracle():
    """C == 1 with q_start == kv_lens - 1 degenerates to paged decode."""
    q, kp, vp, tables, kv_lens, q_start = make_prefill_case(
        11, 2, 4, 2, 16, 8, 3, [17, 9], [16, 8])
    pre = paged_prefill_ref(q, kp, vp, tables, kv_lens, q_start)
    dec = paged_attention_ref(q[:, 0], kp, vp, tables, kv_lens)
    assert_close(pre[:, 0], dec, rtol=1e-6, atol=1e-6)


def test_prefill_int8_dequant_matches_oracle():
    q, kp, vp, tables, kv_lens, q_start = make_prefill_case(
        5, 2, 4, 2, 16, 8, 4, [30, 12], [8, 0])
    kp8 = jnp.clip(jnp.round(kp / 0.05), -127, 127).astype(jnp.int8)
    vp8 = jnp.clip(jnp.round(vp / 0.05), -127, 127).astype(jnp.int8)
    ref = paged_prefill_ref(q, kp8, vp8, tables, kv_lens, q_start,
                            kv_scale=0.05)
    for backend in BACKENDS:
        out = paged_prefill(q, kp8, vp8, tables, kv_lens, q_start,
                            impl="pallas", interpret=True, backend=backend,
                            kv_scale=0.05, pages_per_block=2, num_splits=2)
        for b in range(q.shape[0]):
            ql = int(kv_lens[b] - q_start[b])
            assert_close(out[b, :ql], ref[b, :ql], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model-level: chunked == monolithic logits
# ---------------------------------------------------------------------------
def _mk_state(model, cfg, B, pages_per_seq=8):
    st = {"pos": jnp.zeros((B,), jnp.int32)}
    n_attn = getattr(model, "n_attn_layers", 0)
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    num_pages = B * pages_per_seq + 1
    st["k_pages"] = jnp.zeros((n_attn, num_pages, cfg.page_size, Hkv, hd))
    st["v_pages"] = jnp.zeros_like(st["k_pages"])
    st["tables"] = jnp.asarray(
        np.arange(B * pages_per_seq, dtype=np.int32
                  ).reshape(B, pages_per_seq))
    if cfg.family == "encdec":
        ck = (cfg.n_layers, B, cfg.n_audio_frames, Hkv, hd)
        st["cross_k"] = jnp.zeros(ck)
        st["cross_v"] = jnp.zeros(ck)
    elif getattr(model, "n_cross_layers", 0):
        ck = (model.n_cross_layers, B, cfg.n_image_tokens, Hkv, hd)
        st["cross_k"] = jnp.zeros(ck)
        st["cross_v"] = jnp.zeros(ck)
    return st


def _run_chunked(model, params, toks, lens, chunk, extra=None, impl="jnp",
                 state_fn=None):
    """Drive prefill_chunk to completion; returns each row's final-chunk
    logits (the chunked replacement for one monolithic prefill call)."""
    B, _ = toks.shape
    st = state_fn()
    L = np.asarray(lens)
    start = np.zeros((B,), np.int32)
    done = np.zeros((B,), bool)
    logits = None
    tn = np.asarray(toks)
    while not done.all():
        ql = np.maximum(np.minimum(chunk, L - start), 0)
        C = int(ql.max())
        batch = np.zeros((B, C), np.int32)
        for b in range(B):
            batch[b, :ql[b]] = tn[b, start[b]:start[b] + ql[b]]
        lg, st = model.prefill_chunk(
            params, jnp.asarray(batch), st, q_start=jnp.asarray(start),
            q_lens=jnp.asarray(ql), extra=extra, impl=impl)
        if logits is None:
            logits = np.zeros((B, lg.shape[-1]), np.float32)
        newly = (start + ql >= L) & ~done
        logits[newly] = np.asarray(lg)[newly]
        done |= newly
        start = start + ql
    return logits, st


def _page_chunks(ps):
    return [ps, 2 * ps, ps + 3]  # one page, two pages, odd non-aligned


@pytest.mark.parametrize("page_size", [4, 8])
def test_model_chunked_matches_monolithic_dense(page_size):
    cfg = get_smoke("llama2-7b").replace(page_size=page_size)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 21
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lens = jnp.array([S, S - 6], jnp.int32)
    pps = -(-S // page_size) + 1
    mk = lambda: _mk_state(model, cfg, B, pps)
    ref, ref_st = model.prefill(params, toks, mk(), lens=lens, impl="jnp")
    for chunk in _page_chunks(page_size):
        lg, st = _run_chunked(model, params, toks, lens, chunk,
                              state_fn=mk)
        assert_close(lg, ref, rtol=1e-5, atol=1e-5)
        assert_close(st["k_pages"], ref_st["k_pages"], rtol=1e-5, atol=1e-5)


def test_model_chunked_matches_monolithic_pallas_kernel():
    """The chunked path through the Pallas prefill kernel (TPU + GPU
    lowerings) reproduces the monolithic jnp prefill."""
    cfg = get_smoke("llama2-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 21
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lens = jnp.array([S, S - 6], jnp.int32)
    mk = lambda: _mk_state(model, cfg, B)
    ref, _ = model.prefill(params, toks, mk(), lens=lens, impl="jnp")
    lg, _ = _run_chunked(model, params, toks, lens, chunk=8, impl="pallas",
                         state_fn=mk)
    assert_close(lg, ref, rtol=1e-5, atol=1e-5)


def test_model_chunked_matches_monolithic_windowed():
    """'W' layers take the attend-then-write ring fallback — same logits."""
    cfg = get_smoke("llama2-7b").replace(layer_pattern="AW", window=12)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 21
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lens = jnp.array([S, S - 6], jnp.int32)
    mk = lambda: _mk_state(model, cfg, B)
    ref, _ = model.prefill(params, toks, mk(), lens=lens, impl="jnp")
    for chunk in _page_chunks(cfg.page_size):
        lg, _ = _run_chunked(model, params, toks, lens, chunk, state_fn=mk)
        assert_close(lg, ref, rtol=1e-5, atol=1e-5)


def test_model_chunked_matches_monolithic_encdec():
    cfg = get_smoke("whisper-medium")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 2, 13
    extra = {"frames": jax.random.normal(
        jax.random.PRNGKey(6), (B, cfg.n_audio_frames, cfg.d_model))}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lens = jnp.array([S, S - 4], jnp.int32)
    mk = lambda: _mk_state(model, cfg, B)
    ref, _ = model.prefill(params, toks, mk(), lens=lens, extra=extra,
                           impl="jnp")
    lg, _ = _run_chunked(model, params, toks, lens, 5, extra=extra,
                         state_fn=mk)
    assert_close(lg, ref, rtol=1e-5, atol=1e-5)


def test_model_chunked_encdec_encodes_only_first_chunk_rows():
    """Regression: the encoder gate is per row, not batch-wide.  One
    first-chunk row mixed into three resuming rows must encode a batch
    of exactly that one row's frames (the old gate re-encoded all four
    whenever any row was at chunk 0), and the scattered cross-K/V must
    leave every row's logits identical to the monolithic prefill."""
    cfg = get_smoke("whisper-medium")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    B, S = 4, 8
    extra = {"frames": jax.random.normal(
        jax.random.PRNGKey(6), (B, cfg.n_audio_frames, cfg.d_model))}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    lens = jnp.array([4, S, S, S], jnp.int32)  # row 0 is one chunk long
    mk = lambda: _mk_state(model, cfg, B)
    ref, _ = model.prefill(params, toks, mk(), lens=lens, extra=extra,
                           impl="jnp")

    enc_batches = []
    orig_encode = model.encode

    def spy(p, frames, impl="jnp"):
        enc_batches.append(int(frames.shape[0]))
        return orig_encode(p, frames, impl)

    model.encode = spy
    tn = np.asarray(toks)
    st = mk()
    # call 1: rows 1-3 take their first chunk; row 0 is not admitted yet
    # and poses as a dead resume (q_start=1, q_lens=0), exactly like the
    # engine's padding rows — it must NOT count as a first-chunk row
    b1 = np.zeros((B, 4), np.int32)
    b1[1:] = tn[1:, :4]
    _, st = model.prefill_chunk(
        params, jnp.asarray(b1), st,
        q_start=jnp.asarray([1, 0, 0, 0], jnp.int32),
        q_lens=jnp.asarray([0, 4, 4, 4], jnp.int32), extra=extra)
    # call 2: row 0's first (and only) chunk mixed into three resumes
    b2 = np.zeros((B, 4), np.int32)
    b2[0] = tn[0, :4]
    b2[1:] = tn[1:, 4:]
    lg, st = model.prefill_chunk(
        params, jnp.asarray(b2), st,
        q_start=jnp.asarray([0, 4, 4, 4], jnp.int32),
        q_lens=jnp.asarray([4, 4, 4, 4], jnp.int32), extra=extra)
    del model.encode
    assert enc_batches == [3, 1], (
        f"encoder batches {enc_batches}: per-row gate must encode only "
        "the first-chunk rows, not the whole sub-batch")
    assert_close(lg, ref, rtol=1e-5, atol=1e-5)


def test_model_chunked_rejects_recurrent():
    cfg = get_smoke("recurrentgemma-9b")  # pattern RW
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError):
        model.prefill_chunk(params, jnp.zeros((1, 4), jnp.int32), {},
                            jnp.zeros((1,), jnp.int32),
                            jnp.full((1,), 4, jnp.int32))


# ---------------------------------------------------------------------------
# engine-level: chunked continuous batching == monolithic outputs
# ---------------------------------------------------------------------------
PROMPTS = [[1, 2, 3, 4, 5, 6, 7] * 2, [11, 12, 13], [9] * 25, [4, 5]]


def _reqs(max_new=6):
    return [Request(prompt=list(p), max_new_tokens=max_new) for p in PROMPTS]


@pytest.fixture(scope="module")
def ref_engine():
    cfg = get_smoke("llama2-7b")
    eng = Engine(cfg, max_slots=4, max_seq_len=64, rng=jax.random.PRNGKey(7))
    reqs = _reqs()
    eng.generate(reqs)
    return eng, [list(r.output) for r in reqs]


@pytest.mark.parametrize("chunk", [8, 16, 11])  # page, 2 pages, odd
def test_engine_chunked_matches_monolithic(ref_engine, chunk):
    base, ref_out = ref_engine
    eng = Engine(base.cfg, params=base.params, max_slots=4, max_seq_len=64,
                 rng=jax.random.PRNGKey(7), prefill_chunk=chunk)
    reqs = _reqs()
    eng.generate(reqs, max_steps=500)
    assert [list(r.output) for r in reqs] == ref_out
    assert eng.mgr.used_pages == 0


def test_engine_chunked_bounds_prefill_work(ref_engine):
    """No chunked step prefills more than prefill_chunk tokens per
    request: a long prompt takes ceil(L/chunk) steps to its first token
    while the admitted decodes advance every one of those steps."""
    base, _ = ref_engine
    eng = Engine(base.cfg, params=base.params, max_slots=2, max_seq_len=64,
                 prefill_chunk=8)
    long_req = Request(prompt=[3] * 33, max_new_tokens=2)   # 5 chunks of 8
    short = Request(prompt=[5, 6], max_new_tokens=12)
    eng.add_request(short)
    eng.step()  # short admitted (1 chunk) + first decode
    eng.add_request(long_req)
    decoded_during_prefill = 0
    steps = 0
    while long_req.prefill_pos < len(long_req.prompt) and not long_req.done:
        before = len(short.output)
        eng.step()
        steps += 1
        decoded_during_prefill += len(short.output) - before
        assert long_req.prefill_pos <= steps * 8
        assert steps < 50
    assert steps >= 5  # 33 tokens / 8-token chunks
    assert decoded_during_prefill >= 4  # decode never stalled behind it


def test_engine_chunked_budget_spans_prefill_subbatch(ref_engine):
    """The prefill token budget is global across the prefill sub-batch:
    k concurrent PREFILLING rows split one ``prefill_chunk`` per step —
    they do not each cache a full chunk.  (The former per-request budget
    let a step's prefill work scale as k × chunk, defeating the
    bounded-per-step-work contract; this drives three concurrent
    prefills and asserts the *summed* per-step progress.)"""
    base, _ = ref_engine
    eng = Engine(base.cfg, params=base.params, max_slots=3, max_seq_len=64,
                 prefill_chunk=8, rng=jax.random.PRNGKey(3))
    reqs = [Request(prompt=[3 + i] * 40, max_new_tokens=2) for i in range(3)]
    for r in reqs:
        eng.add_request(r)
    # roomy pool (no preemption): prefill_pos only ever advances, so the
    # per-step delta of the summed positions is exactly the tokens the
    # prefill sub-batch cached that step
    concurrent_prefills = 0
    for _ in range(100):
        if all(r.done for r in reqs):
            break
        n_prefilling = sum(r.status is Status.PREFILLING
                           for r in eng.scheduler.running.values())
        concurrent_prefills = max(concurrent_prefills, n_prefilling)
        before = sum(min(r.prefill_pos, len(r.prompt)) for r in reqs)
        eng.step()
        after = sum(min(r.prefill_pos, len(r.prompt)) for r in reqs)
        assert after - before <= 8, (
            f"prefill sub-batch cached {after - before} tokens in one "
            "step — the chunk budget must span the sub-batch, not apply "
            "per request")
    assert all(r.done for r in reqs)
    assert eng.scheduler.preempted == 0
    assert concurrent_prefills >= 2, (
        "test never had concurrent prefills — the global budget was not "
        "exercised")
    assert eng.mgr.used_pages == 0


def test_engine_chunked_with_preemption_matches(ref_engine):
    """Preemption under an oversubscribed pool stays output-transparent
    with the chunked scheduler (preempted requests re-prefill
    chunk-by-chunk)."""
    base, _ = ref_engine
    # max_new=20 drives peak demand to ~17 pages against a 12-page pool —
    # preemption is guaranteed, not timing-dependent
    ref = _reqs(max_new=20)
    roomy = Engine(base.cfg, params=base.params, max_slots=4, max_seq_len=64,
                   rng=jax.random.PRNGKey(7))
    roomy.generate(ref)
    tight = Engine(base.cfg, params=base.params, max_slots=4, max_seq_len=64,
                   pool_tokens=96, prefill_chunk=8,
                   rng=jax.random.PRNGKey(7))
    reqs = _reqs(max_new=20)
    tight.generate(reqs, max_steps=1000)
    assert tight.scheduler.preempted >= 1, "pool pressure never materialised"
    for a, b in zip(ref, reqs):
        assert a.output == b.output
    assert tight.mgr.used_pages == 0


def test_engine_prefill_stall_resumes_from_cached_pages(ref_engine):
    """A prefill that cannot get its next chunk's pages stalls — keeping
    its cached pages — and resumes from mgr.lens once decode traffic
    frees space.  Output identical to the unconstrained engine, with the
    stall actually exercised and zero preemptions of the stalled
    request."""
    base, _ = ref_engine
    cfg = base.cfg
    long_prompt = [7] * 40
    ref = Request(prompt=list(long_prompt), max_new_tokens=3)
    roomy = Engine(cfg, params=base.params, max_slots=2, max_seq_len=64,
                   rng=jax.random.PRNGKey(9))
    roomy.generate([ref])

    # choreography on a 9-page pool (page_size 8): the short request
    # occupies exactly 5 pages for its whole life (33-token prompt + 7
    # tokens = 40 = page-aligned peak, so extend_for_decode never needs a
    # fresh page → no preemption pressure).  The long 40-token prompt
    # grows one page per 8-token chunk: pages 1..4 fit (9 total used),
    # the 5th chunk finds the pool dry and MUST stall until the short
    # request finishes and frees its pages.
    eng = Engine(cfg, params=base.params, max_slots=2, max_seq_len=64,
                 pool_tokens=72, prefill_chunk=8,
                 rng=jax.random.PRNGKey(9))
    short = Request(prompt=[2] * 33, max_new_tokens=7)
    eng.add_request(short)
    eng.step()
    long_req = Request(prompt=list(long_prompt), max_new_tokens=3)
    eng.add_request(long_req)
    progress = []
    for _ in range(300):
        if long_req.done and short.done:
            break
        eng.step()
        if long_req.status is Status.PREFILLING:
            progress.append(long_req.prefill_pos)
    assert long_req.done and short.done
    assert eng.scheduler.prefill_stalls >= 1, "stall never exercised"
    # resume-from-cached-pages, not restart: the prefill progressed
    # monotonically across the stall (a preempt/restart would reset
    # prefill_pos to 0) and nothing was ever preempted
    assert eng.scheduler.preempted == 0
    # leading zeros are fine: the chunk budget is global across the
    # prefill sub-batch, so the long request may wait while the older
    # short prefill drains its share
    assert progress == sorted(progress) and progress[-1] > 0
    assert max(progress) < 40, "prefill never actually paused mid-prompt"
    assert long_req.output == ref.output
    assert eng.mgr.used_pages == 0


def test_engine_concurrent_prefills_preempt_without_crashing(ref_engine):
    """Regression: several long prompts prefilling concurrently with
    nothing decoding on a tight pool — grow_prefill preempts the youngest
    PREFILLING request mid-loop, in a slot the chunk loop has not visited
    yet.  The loop must skip the vacated slot (it used to KeyError on the
    snapshotted slot list) and every request must still finish with the
    pool returned whole."""
    base, _ = ref_engine
    eng = Engine(base.cfg, params=base.params, max_slots=3, max_seq_len=64,
                 pool_tokens=56, prefill_chunk=8,
                 rng=jax.random.PRNGKey(5))
    reqs = [Request(prompt=[4 + i] * 50, max_new_tokens=2)
            for i in range(3)]  # 3 × 7 pages against an 8-page pool:
    # with the global chunk budget prefills serialise, so the pool must
    # be tight enough that one full prefill (7 pages) plus the two
    # admitted peers' first pages cannot coexist
    eng.generate(reqs, max_steps=600)
    assert all(r.done for r in reqs)
    assert eng.scheduler.preempted >= 1, "pool pressure never materialised"
    assert eng.mgr.used_pages == 0


def test_engine_chunked_rejects_recurrent_families():
    cfg = get_smoke("recurrentgemma-9b")
    with pytest.raises(ValueError, match="recurrent"):
        Engine(cfg, max_slots=2, max_seq_len=64, prefill_chunk=8)


def test_engine_chunked_vlm_with_extras_matches():
    """The modality path: chunked prefill with per-request image extras —
    cross-K/V computed on each request's first chunk, reused (from the
    engine-scattered state rows) on resume chunks."""
    cfg = get_smoke("llama-3.2-vision-11b")
    key = jax.random.PRNGKey(7)
    e1 = Engine(cfg, max_slots=2, max_seq_len=64, rng=key)
    img = np.asarray(jax.random.normal(
        jax.random.PRNGKey(5), (cfg.n_image_tokens, cfg.d_vision)))
    mk = lambda: ([Request(prompt=[3] * 11, max_new_tokens=5),
                   Request(prompt=[8] * 4, max_new_tokens=5)],
                  [{"image_embeds": img}, {"image_embeds": img * 0.5}])
    r1, x1 = mk()
    e1.generate(r1, extras=x1)
    e2 = Engine(cfg, params=e1.params, max_slots=2, max_seq_len=64,
                rng=key, prefill_chunk=4)
    r2, x2 = mk()
    e2.generate(r2, extras=x2, max_steps=300)
    for a, b in zip(r1, r2):
        assert a.output == b.output


def test_engine_chunked_windowed_model_matches():
    """Chunked prefill through a sliding-window model (ring pages take the
    attend-then-write fallback) matches the monolithic engine."""
    cfg = get_smoke("llama2-7b").replace(layer_pattern="AW", window=16)
    e1 = Engine(cfg, max_slots=2, max_seq_len=64, rng=jax.random.PRNGKey(3))
    r1 = [Request(prompt=[7, 11, 13] * 7, max_new_tokens=6)]
    e1.generate(r1)
    e2 = Engine(cfg, params=e1.params, max_slots=2, max_seq_len=64,
                rng=jax.random.PRNGKey(3), prefill_chunk=8)
    r2 = [Request(prompt=[7, 11, 13] * 7, max_new_tokens=6)]
    e2.generate(r2, max_steps=300)
    assert r1[0].output == r2[0].output
