"""Sampling-filter semantics, pinned (ISSUE 5 satellite).

Top-p (nucleus) boundary contract: the kept set is the smallest
probability-sorted prefix with cumulative mass >= p — the token whose
cumulative sum *crosses* p is INCLUDED (an exclusive mask would violate
the nucleus definition: the kept mass could fall below p).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.sampler import (SampleParams, sample, top_k_mask,
                                   top_p_mask)


def kept(masked):
    return set(np.where(np.isfinite(np.asarray(masked)))[0].tolist())


def logits_for(probs):
    return jnp.log(jnp.asarray(probs, jnp.float32))


def test_top_p_includes_crossing_token():
    lg = logits_for([0.5, 0.3, 0.2])
    # p=0.6: token 1 crosses (0.5 < 0.6 <= 0.8) and must be kept
    assert kept(top_p_mask(lg, jnp.float32(0.6))) == {0, 1}
    # p=0.4: token 0 alone crosses
    assert kept(top_p_mask(lg, jnp.float32(0.4))) == {0}
    # p just above a step adds exactly one token
    assert kept(top_p_mask(lg, jnp.float32(0.81))) == {0, 1, 2}


def test_top_p_exactly_on_cumulative_step():
    """p landing exactly on a cumulative step keeps exactly that prefix
    (mass == p is already >= p — the next token must NOT be added).
    The boundary value is taken from the mask's own cumsum so float
    rounding cannot turn the equality into an inequality."""
    lg = logits_for([0.5, 0.3, 0.2])
    csum = np.cumsum(np.asarray(jax.nn.softmax(jnp.sort(lg)[::-1])))
    p0 = jnp.float32(csum[0])  # exactly P(token 0)
    assert kept(top_p_mask(lg, p0)) == {0}
    p1 = jnp.float32(csum[1])  # exactly P(token 0) + P(token 1)
    assert kept(top_p_mask(lg, p1)) == {0, 1}


def test_top_p_one_keeps_everything():
    lg = logits_for([0.5, 0.3, 0.15, 0.05])
    assert kept(top_p_mask(lg, jnp.float32(1.0))) == {0, 1, 2, 3}


def test_top_p_tiny_keeps_argmax_only():
    lg = logits_for([0.5, 0.3, 0.2])
    assert kept(top_p_mask(lg, jnp.float32(1e-6))) == {0}


def test_top_p_ties_at_the_cutoff_are_kept_together():
    """Tokens tied in logit with the crossing token survive together: the
    cutoff is by value, so sort order cannot split a tie arbitrarily."""
    lg = logits_for([0.5, 0.25, 0.25])
    # p=0.6 crosses at one of the tied tokens — both stay
    assert kept(top_p_mask(lg, jnp.float32(0.6))) == {0, 1, 2}


def test_top_p_composes_with_top_k():
    lg = logits_for([0.4, 0.3, 0.2, 0.1])
    lg_k = top_k_mask(lg, jnp.int32(3))  # drop token 3
    assert kept(lg_k) == {0, 1, 2}
    # renormalised over the survivors: csum = 4/9, 7/9, 1 → p=0.5 keeps 2
    assert kept(top_p_mask(lg_k, jnp.float32(0.5))) == {0, 1}


def test_top_k_boundary_and_off():
    lg = logits_for([0.4, 0.3, 0.2, 0.1])
    assert kept(top_k_mask(lg, jnp.int32(1))) == {0}
    assert kept(top_k_mask(lg, jnp.int32(4))) == {0, 1, 2, 3}
    assert kept(top_k_mask(lg, jnp.int32(0))) == {0, 1, 2, 3}  # off


def test_sample_respects_top_p_support():
    """Sampled tokens never leave the nucleus (and p=1.0 still samples
    valid ids)."""
    logits = logits_for([0.45, 0.35, 0.15, 0.05])[None, :]
    for p, support in ((0.5, {0, 1}), (1.0, {0, 1, 2, 3})):
        params = SampleParams(temperature=jnp.ones((1,)),
                              top_k=jnp.zeros((1,), jnp.int32),
                              top_p=jnp.full((1,), p, jnp.float32))
        toks = set()
        for i in range(40):
            t = sample(jax.random.PRNGKey(i), logits, params)
            toks.add(int(t[0]))
        assert toks <= support, (p, toks)


def test_sample_greedy_at_zero_temperature():
    logits = logits_for([0.1, 0.7, 0.2])[None, :]
    params = SampleParams(temperature=jnp.zeros((1,)),
                          top_k=jnp.zeros((1,), jnp.int32),
                          top_p=jnp.ones((1,)))
    assert int(sample(jax.random.PRNGKey(0), logits, params)[0]) == 1
