"""Multi-device distributed tests (8 fake CPU devices, subprocess).

shard_map features can't run on the main process's single device, so each
test launches a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 and asserts numerical equivalence against the single-device
reference: EP MoE dispatch, ring attention, kvp flash-decoding, and the
weight-stationary decode plan.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.sharding import use_mesh, DEFAULT_RULES
    """) % os.path.abspath(SRC) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


@pytest.mark.slow
def test_ep_moe_matches_reference():
    run_sub("""
        from repro.configs import get_smoke
        from repro.models import moe
        from repro.models.api import build_model
        from repro.distributed import ep
        mesh = jax.make_mesh((4,2), ("data","model"))
        cfg = get_smoke('olmoe-1b-7b').replace(moe_capacity=0.0)
        rules = DEFAULT_RULES.extend(batch=("data",))
        m = build_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        p = jax.tree_util.tree_map(lambda a: a[0],
                                   params['groups']['0A'])['moe']
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        ref, _ = moe.apply_moe(p, x, cfg)
        with use_mesh(mesh, rules):
            out, _ = jax.jit(lambda p, x: ep.apply_moe_ep(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)
    """)


@pytest.mark.slow
def test_ring_attention_matches_dense():
    run_sub("""
        from repro.core.attention import prefill_attention
        mesh = jax.make_mesh((2,4), ("data","model"))
        rules = DEFAULT_RULES.extend(batch=("data",), seq=("model",),
                                     heads=None, kv_heads=None)
        rng = jax.random.PRNGKey(0)
        for (B,S,H,Hkv,D,window,lens) in [(2,64,8,2,16,0,None),
                                          (2,128,4,4,32,30,None),
                                          (2,64,8,4,16,0,[50,33])]:
            ks = jax.random.split(rng,4); rng = ks[0]
            q = jax.random.normal(ks[1],(B,S,H,D))
            k = jax.random.normal(ks[2],(B,S,Hkv,D))
            v = jax.random.normal(ks[3],(B,S,Hkv,D))
            l = jnp.asarray(lens,jnp.int32) if lens else None
            ref = prefill_attention(q,k,v,window=window,lens=l,impl='jnp')
            with use_mesh(mesh, rules):
                out = jax.jit(lambda q,k,v: prefill_attention(
                    q,k,v,window=window,lens=l,impl='ring'))(q,k,v)
            np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                       rtol=3e-5, atol=3e-5)
    """)


@pytest.mark.slow
def test_kvp_flash_decoding_matches_local():
    run_sub("""
        from repro.core.attention import decode_attention
        from repro.distributed.collectives import decode_attention_sharded
        mesh = jax.make_mesh((2,4), ("data","model"))
        B, Hkv, G, D, ps, pps, n_sh = 2, 2, 4, 16, 4, 8, 4
        num_pages = B * pps
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q4 = jax.random.normal(ks[0], (B, Hkv, G, D))
        kp = jax.random.normal(ks[1], (num_pages, ps, Hkv, D))
        vp = jax.random.normal(ks[2], (num_pages, ps, Hkv, D))
        lens = jnp.asarray([29, 17], jnp.int32)
        logical = jnp.arange(B*pps, dtype=jnp.int32).reshape(B, pps)
        ref = decode_attention(q4.reshape(B, Hkv*G, D), kp, vp, logical,
                               lens, impl='ref').reshape(B, Hkv, G, D)
        # kvp layout: batch over "data" (1 seq/shard), pages striped over
        # "model": shard (d, s) holds seq d's logical pages j*4+s in local
        # slot j. Physical pool reordered to that P(("data","model")) split.
        order = [d*pps + j*n_sh + s
                 for d in range(B) for s in range(n_sh)
                 for j in range(pps//n_sh)]
        kp2 = kp[jnp.asarray(order)]
        vp2 = vp[jnp.asarray(order)]
        local_tables = jnp.tile(
            jnp.arange(pps//n_sh, dtype=jnp.int32)[None, None],
            (B, n_sh, 1))
        from repro.distributed.sharding import use_mesh, DEFAULT_RULES
        rules = DEFAULT_RULES.extend(batch=("data",))
        with use_mesh(mesh, rules):
            out = jax.jit(lambda q4, kp, vp, t, l: decode_attention_sharded(
                q4, kp, vp, t, l, scheme='kvp', batch_axes=("data",),
                impl='ref'))(q4, kp2, vp2, local_tables, lens)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=2e-5, atol=2e-5)
    """)


@pytest.mark.slow
def test_serve_step_lowers_on_8dev_mesh():
    run_sub("""
        from repro.configs import get_smoke
        from repro.configs.base import RunConfig
        from repro.launch.steps import build_step, plan_for
        mesh = jax.make_mesh((2,4), ("data","model"))
        cfg = get_smoke('granite-8b')
        run = RunConfig(model=cfg, seq_len=64, global_batch=4, kind='decode')
        for ws in (False, True):
            plan = plan_for(run, mesh, ws_decode=ws)
            step, args, sh, model = build_step(run, plan, dtype=jnp.float32)
            names = list(args)
            with use_mesh(mesh, plan.rules):
                lowered = jax.jit(step, in_shardings=tuple(
                    sh[n] for n in names)).lower(*(args[n] for n in names))
            lowered.compile()
    """)
