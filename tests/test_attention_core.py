"""Core attention dispatch: chunked-flash oracle, paged decode paths,
cache read/write round-trips, and hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cache as kvcache
from repro.core.attention import (decode_attention,
                                  decode_attention_contiguous,
                                  prefill_attention)
from repro.kernels.paged_attention.ref import ring_slot_positions

from conftest import assert_close


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), S=st.integers(2, 80),
       hkv=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2, 4]),
       D=st.sampled_from([8, 32]), window=st.integers(0, 90))
def test_chunked_equals_dense_property(B, S, hkv, g, D, window):
    rng = jax.random.PRNGKey(S * 7 + B)
    ks = jax.random.split(rng, 3)
    H = hkv * g
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, hkv, D))
    v = jax.random.normal(ks[2], (B, S, hkv, D))
    a = prefill_attention(q, k, v, window=window, impl="jnp")
    b = prefill_attention(q, k, v, window=window, impl="chunked")
    assert_close(a, b, rtol=3e-5, atol=3e-5)


def test_prefill_writes_then_gather_roundtrip(rng):
    """write_layer_prefill ∘ gather_layer == identity on live positions."""
    B, S, Hkv, D, ps = 2, 37, 2, 16, 8
    pp = -(-S // ps)
    ks = jax.random.split(rng, 2)
    k = jax.random.normal(ks[0], (B, S, Hkv, D))
    v = jax.random.normal(ks[1], (B, S, Hkv, D))
    lens = jnp.asarray([S, 21], jnp.int32)
    pages = jnp.zeros((B * pp + 2, ps, Hkv, D))
    tables = (jnp.arange(B * pp, dtype=jnp.int32).reshape(B, pp) + 2)
    kp, vp = kvcache.write_layer_prefill(pages, pages, tables, k, v, lens)
    kg, vg = kvcache.gather_layer(kp, vp, tables, S)
    for b in range(B):
        L = int(lens[b])
        assert_close(kg[b, :L], k[b, :L])
        assert_close(vg[b, :L], v[b, :L])
        if L < kg.shape[1]:
            assert np.abs(np.asarray(kg[b, L:])).max() == 0.0


def test_decode_write_then_attend_matches_contiguous(rng):
    B, Hkv, H, D, ps, mp = 2, 2, 4, 16, 8, 4
    ks = jax.random.split(rng, 6)
    kp = jnp.zeros((B * mp, ps, Hkv, D))
    vp = jnp.zeros_like(kp)
    tables = jnp.arange(B * mp, dtype=jnp.int32).reshape(B, mp)
    kc = jnp.zeros((B, mp * ps, Hkv, D))
    vc = jnp.zeros_like(kc)
    lens = np.zeros(B, np.int32)
    for t in range(14):
        kn = jax.random.normal(jax.random.fold_in(ks[0], t), (B, Hkv, D))
        vn = jax.random.normal(jax.random.fold_in(ks[1], t), (B, Hkv, D))
        pos = jnp.full((B,), t, jnp.int32)
        kp, vp = kvcache.write_layer_decode(kp, vp, None, None, pos, kn, vn) \
            if False else kvcache.write_layer_decode(
                kp, vp,
                type("S", (), {"block_tables": tables})(), jnp.arange(B),
                pos, kn, vn)
        kc = kc.at[jnp.arange(B), pos].set(kn)
        vc = vc.at[jnp.arange(B), pos].set(vn)
        lens += 1
    q = jax.random.normal(ks[2], (B, H, D))
    a = decode_attention(q, kp, vp, tables, jnp.asarray(lens), impl="ref")
    b = decode_attention_contiguous(q, kc, vc, jnp.asarray(lens))
    assert_close(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(lens=st.lists(st.integers(1, 120), min_size=1, max_size=3),
       ps=st.sampled_from([4, 8]), window=st.integers(4, 40))
def test_ring_slot_positions_property(lens, ps, window):
    """Every live window position is represented exactly once in the ring."""
    ring = -(-window // ps) + 1
    n_slots = ring * ps
    pos = np.asarray(ring_slot_positions(jnp.asarray(lens, jnp.int32), ps,
                                         ring, n_slots))
    for b, L in enumerate(lens):
        live = pos[b][(pos[b] >= 0) & (pos[b] < L) & (pos[b] >= L - window)]
        expect = set(range(max(0, L - window), L))
        assert set(live.tolist()) == expect
        assert len(live) == len(expect)  # no duplicates


def test_decode_attention_window_vs_truncated_contiguous(rng):
    """Sliding-window paged decode == contiguous attention over the window."""
    B, Hkv, H, D, ps, window = 2, 2, 4, 16, 8, 16
    ring = -(-window // ps) + 1
    ks = jax.random.split(rng, 3)
    T = 40
    kc = jax.random.normal(ks[0], (B, T, Hkv, D))
    vc = jax.random.normal(ks[1], (B, T, Hkv, D))
    kp = jnp.zeros((B * ring, ps, Hkv, D))
    vp = jnp.zeros_like(kp)
    tables = jnp.arange(B * ring, dtype=jnp.int32).reshape(B, ring)
    state = type("S", (), {"block_tables": tables})()
    for t in range(T):
        kp, vp = kvcache.write_layer_decode(
            kp, vp, state, jnp.arange(B), jnp.full((B,), t, jnp.int32),
            kc[:, t], vc[:, t], window=window)
    q = jax.random.normal(ks[2], (B, H, D))
    lens = jnp.asarray([T, T - 3], jnp.int32)
    # rewrite len-3 for seq1: its last tokens differ; rebuild for honesty
    a = decode_attention(q, kp, vp, tables, jnp.full((B,), T, jnp.int32),
                         window=window, impl="ref")
    b = decode_attention_contiguous(q, kc, vc, jnp.full((B,), T, jnp.int32),
                                    window=window)
    assert_close(a, b, rtol=1e-5, atol=1e-5)


def test_copy_page_copy_on_write(rng):
    cache = kvcache.init_cache(n_layers=2, num_pages=6, page_size=4,
                               kv_heads=2, head_dim=8, max_seqs=2,
                               max_pages_per_seq=3)
    cache = cache._replace(k_pages=jax.random.normal(rng, cache.k_pages.shape))
    c2 = kvcache.copy_page(cache, jnp.int32(1), jnp.int32(4))
    assert_close(c2.k_pages[:, 4], cache.k_pages[:, 1])
    # NULL src/dst is a no-op
    c3 = kvcache.copy_page(cache, jnp.int32(-1), jnp.int32(2))
    assert_close(c3.k_pages, cache.k_pages)
