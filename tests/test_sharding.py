"""Sharding rules, sampler, and distributed decode-scheme plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (AxisRules, DEFAULT_RULES,
                                        logical_spec, use_mesh)
from repro.launch.mesh import make_local_mesh
from repro.serving.sampler import SampleParams, sample


# ---------------------------------------------------------------------------
# logical-axis rules
# ---------------------------------------------------------------------------
def test_logical_spec_basic():
    rules = AxisRules({"batch": ("pod", "data"), "heads": "model"})
    spec = logical_spec(("batch", None, "heads"), rules, mesh=None)
    assert spec == P(("pod", "data"), None, "model")


def test_logical_spec_drops_duplicate_mesh_axes():
    rules = AxisRules({"seq": ("model",), "vocab": ("model",)})
    spec = logical_spec(("seq", "vocab"), rules, mesh=None)
    # first occurrence wins, second is replicated
    assert spec == P("model")


def test_logical_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = AxisRules({"heads": ("model",)})
    # 1-device mesh: any size divides; now a fake check with shape
    spec = logical_spec(("heads",), rules, mesh=mesh, shape=(7,))
    assert spec == P("model")  # 7 % 1 == 0


def test_config_overrides_extend_rules():
    rules = DEFAULT_RULES.extend(embed=("data",))
    assert rules.physical("embed") == ("data",)
    assert rules.physical("heads") == ("model",)


def test_plan_scheme_selection():
    from repro.configs import get_config, make_run
    from repro.launch.steps import plan_for
    import os
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # kv=8 % model=1 == 0 -> tp on a 1-wide model axis
    run = make_run(get_config("granite-8b"), "decode_32k")
    assert plan_for(run, mesh).scheme == "tp"


def test_use_mesh_restores_context():
    from repro.distributed.sharding import current_mesh
    mesh = make_local_mesh()
    assert current_mesh() is None
    with use_mesh(mesh):
        assert current_mesh() is mesh
    assert current_mesh() is None


def test_train_step_under_local_mesh(rng):
    """The pjit path end-to-end on a 1-device mesh with production rules."""
    from repro.configs import get_smoke
    from repro.configs.base import RunConfig
    from repro.launch.steps import build_step, plan_for
    from repro.training.state import TrainState

    cfg = get_smoke("olmoe-1b-7b")
    run = RunConfig(model=cfg, seq_len=16, global_batch=2, kind="train")
    mesh = make_local_mesh()
    plan = plan_for(run, mesh, attn_impl="jnp")
    step, abstract, shardings, model = build_step(run, plan,
                                                  dtype=jnp.float32)
    with use_mesh(mesh, plan.rules):
        params = model.init_params(rng)
        state = TrainState.create(params)
        batch = {"inputs": jnp.ones((2, 16), jnp.int32),
                 "targets": jnp.ones((2, 16), jnp.int32)}
        state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))


def test_serve_step_under_local_mesh(rng):
    from repro.configs import get_smoke
    from repro.configs.base import RunConfig
    from repro.launch.steps import build_step, plan_for

    cfg = get_smoke("granite-8b")
    run = RunConfig(model=cfg, seq_len=32, global_batch=2, kind="decode")
    mesh = make_local_mesh()
    plan = plan_for(run, mesh)
    step, abstract, shardings, model = build_step(run, plan,
                                                  dtype=jnp.float32)
    with use_mesh(mesh, plan.rules):
        params = model.init_params(rng)
        state = model.init_decode_state(run, n_kv_shards=plan.n_kv_shards)
        b, n_sh, pps = state["tables"].shape
        state["tables"] = jnp.arange(b * n_sh * pps,
                                     dtype=jnp.int32).reshape(b, n_sh, pps)
        state["pos"] = jnp.asarray([5, 3], jnp.int32)
        logits, st = jax.jit(step)(params, jnp.asarray([1, 2], jnp.int32),
                                   state)
    assert logits.shape == (2, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()


# ---------------------------------------------------------------------------
# sampler
# ---------------------------------------------------------------------------
def test_sampler_greedy(rng):
    logits = jnp.asarray([[0.1, 3.0, -1.0], [2.0, 0.0, 1.0]])
    p = SampleParams(temperature=jnp.zeros(2), top_k=jnp.zeros(2, jnp.int32),
                     top_p=jnp.ones(2))
    toks = sample(rng, logits, p)
    assert toks.tolist() == [1, 0]


def test_sampler_top_k_restricts_support(rng):
    logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]] * 64, jnp.float32)
    p = SampleParams(temperature=jnp.full((64,), 1.0),
                     top_k=jnp.full((64,), 2, jnp.int32),
                     top_p=jnp.ones((64,)))
    toks = np.asarray(sample(rng, logits, p))
    assert set(toks.tolist()) <= {2, 3}


def test_sampler_top_p_keeps_argmax(rng):
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]] * 16, jnp.float32)
    p = SampleParams(temperature=jnp.full((16,), 1.0),
                     top_k=jnp.zeros((16,), jnp.int32),
                     top_p=jnp.full((16,), 0.1))
    toks = np.asarray(sample(rng, logits, p))
    assert (toks == 0).all()


def test_sampler_temperature_diversity(rng):
    logits = jnp.zeros((128, 8), jnp.float32)  # uniform
    p = SampleParams(temperature=jnp.full((128,), 1.0),
                     top_k=jnp.zeros((128,), jnp.int32),
                     top_p=jnp.ones((128,)))
    toks = np.asarray(sample(rng, logits, p))
    assert len(set(toks.tolist())) >= 4
