"""Seeded shape-contract violations (fixture — parsed, never executed).

One site per defect class the ``shapes`` abstract interpreter must catch:
rank mismatch, non-divisible block shape, out-of-range index_map, wrong
partial dtype, TPU/GPU partial-contract skew, plus a contractless site.
Contracts are declared inline (``REPLINT_KERNEL_CONTRACTS``) so the
fixture is self-contained.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

REPLINT_KERNEL_CONTRACTS = {
    "bad_rank": {
        "grid": ("S",),
        "operands": [
            {"name": "pool", "shape": ("P", "page_size", "H", "D"),
             "dtype": "float32"},
        ],
        "outputs": [{"shape": ("P", "page_size", "H", "D"),
                     "dtype": "float32"}],
        "samples": [{"S": 2, "P": 4, "page_size": 4, "H": 2, "D": 8}],
    },
    "bad_divisibility": {
        "grid": ("S",),
        "operands": [
            {"name": "pool", "shape": ("P", "page_size", "H", "D"),
             "dtype": "float32"},
        ],
        "outputs": [{"shape": ("P", "page_size", "H", "D"),
                     "dtype": "float32"}],
        "samples": [{"S": 2, "P": 4, "page_size": 4, "H": 2, "D": 8}],
    },
    "bad_index_range": {
        "grid": ("B", "S"),
        "num_scalar_prefetch": 1,
        "operands": [
            {"name": "tables", "shape": ("B", "S"), "dtype": "int32",
             "value_range": (0, "NPm1")},
            {"name": "pool", "shape": ("P", "page_size"),
             "dtype": "float32"},
        ],
        "outputs": [{"shape": ("B", "page_size"), "dtype": "float32"}],
        "samples": [{"B": 2, "S": 2, "P": 4, "page_size": 4, "NPm1": 3}],
    },
    "bad_partial_dtype": {
        "grid": ("B",),
        "operands": [
            {"name": "q", "shape": ("B", "G"), "dtype": "float32"},
        ],
        "outputs": [
            {"shape": ("B", "G"), "dtype": "float32"},   # m
            {"shape": ("B", "G"), "dtype": "float32"},   # l
        ],
        "partial_group": "fixture-partials",
        "samples": [{"B": 2, "G": 4, "_parity": True}],
    },
    # TPU/GPU skew: same partial group, but the "gpu" twin *declares* a
    # transposed acc — the declarations disagree under the parity sample.
    "skew_tpu": {
        "grid": ("B",),
        "operands": [{"name": "q", "shape": ("B", "G", "D"),
                      "dtype": "float32"}],
        "outputs": [{"shape": ("B", "G", "D"), "dtype": "float32"}],
        "partial_group": "skewed-partials",
        "samples": [{"B": 2, "G": 4, "D": 8, "_parity": True}],
    },
    "skew_gpu": {
        "grid": ("B",),
        "operands": [{"name": "q", "shape": ("B", "D", "G"),
                      "dtype": "float32"}],
        "outputs": [{"shape": ("B", "D", "G"), "dtype": "float32"}],
        "partial_group": "skewed-partials",
        "samples": [{"B": 2, "G": 4, "D": 8, "_parity": True}],
    },
}

REPLINT_PARTIAL_GROUPS = {
    "fixture-partials": {},
    "skewed-partials": {},
}


def _kernel(*refs):
    refs[-1][...] = refs[0][...]


def bad_rank(pool, S):
    # block shape is rank 3 against the rank-4 pool array
    return pl.pallas_call(
        _kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, 4, 2), lambda s: (s, 0, 0))],
        out_specs=pl.BlockSpec((1, 4, 2, 8), lambda s: (s, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(pool.shape, jnp.float32),
    )(pool)


def bad_divisibility(pool, S):
    # block dim 3 does not divide the page_size=4 axis
    return pl.pallas_call(
        _kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, 3, 1, 8), lambda s: (s, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, 4, 2, 8), lambda s: (s, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(pool.shape, jnp.float32),
    )(pool)


def bad_index_range(tables, pool, B, S):
    # the +1 pushes the table-driven page index past the pool extent
    def kv_map(b, s, tables):
        return (tables[b, s] + 1, 0)

    def out_map(b, s, tables):
        return (b, 0)

    return pl.pallas_call(
        _kernel,
        grid_spec=pl.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, S),
            in_specs=[pl.BlockSpec((1, 4), kv_map)],
            out_specs=pl.BlockSpec((1, 4), out_map),
        ),
        out_shape=jax.ShapeDtypeStruct((B, 4), jnp.float32),
    )(tables, pool)


def bad_partial_dtype(q, B, G):
    # split-K running max must stay f32; bf16 loses the carry
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, G), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((1, G), lambda b: (b, 0)),
                   pl.BlockSpec((1, G), lambda b: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, G), jnp.bfloat16),
                   jax.ShapeDtypeStruct((B, G), jnp.float32)],
    )(q)


def skew_tpu(q, B, G, D):
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, G, D), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, G, D), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, D), jnp.float32),
    )(q)


def skew_gpu(q, B, G, D):
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, D, G), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, D, G), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D, G), jnp.float32),
    )(q)


def no_contract(q, B):
    # a site the inline table forgot: itself a finding
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[pl.BlockSpec((1,), lambda b: (b,))],
        out_specs=pl.BlockSpec((1,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
    )(q)
