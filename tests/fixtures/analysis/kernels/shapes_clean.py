"""Clean shape-contract sites (fixture — parsed, never executed).

Exercises the idioms the live kernels use — spec-factory lambdas, list
comprehensions over ``range(ppb)``, ``functools.partial``-bound
index_maps, scalar-prefetch tables — all agreeing with their inline
contracts. The ``shapes`` rule must report nothing here.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

REPLINT_KERNEL_CONTRACTS = {
    "clean_gather": {
        "grid": ("B", "S", "bps"),
        "num_scalar_prefetch": 2,
        "operands": [
            {"name": "tables", "shape": ("B", "NB", "ppb"),
             "dtype": "int32", "value_range": (0, "NPm1")},
            {"name": "lens", "shape": ("B",), "dtype": "int32"},
            {"name": "q", "shape": ("B", "G", "D"), "dtype": "float32"},
            {"name": "k_pages", "shape": ("P", "page_size", "D"),
             "dtype": "float32", "repeat": "ppb"},
        ],
        "outputs": [
            {"shape": ("B", "S", "G"), "dtype": "float32"},
            {"shape": ("B", "S", "G", "D"), "dtype": "float32"},
        ],
        "partial_group": "clean-partials",
        "samples": [
            {"B": 2, "S": 2, "bps": 2, "ppb": 2, "NB": 4,
             "G": 4, "D": 8, "P": 16, "page_size": 4, "NPm1": 15,
             "_parity": True},
            {"B": 1, "S": 1, "bps": 1, "ppb": 1, "NB": 1,
             "G": 8, "D": 8, "P": 4, "page_size": 4, "NPm1": 3},
        ],
    },
    "clean_whole_array": {
        "grid": ("B", "S"),
        "operands": [
            {"name": "tables", "shape": ("B", "NB", "ppb"),
             "dtype": "int32", "value_range": (0, "NPm1")},
            {"name": "q", "shape": ("B", "G", "D"), "dtype": "float32"},
        ],
        "outputs": [
            {"shape": ("B", "S", "G"), "dtype": "float32"},
            {"shape": ("B", "S", "G", "D"), "dtype": "float32"},
        ],
        "partial_group": "clean-partials",
        "samples": [
            {"B": 2, "S": 2, "ppb": 2, "NB": 4, "G": 4, "D": 8,
             "NPm1": 15, "_parity": True},
        ],
    },
}

REPLINT_PARTIAL_GROUPS = {"clean-partials": {}}


def _kernel(*refs):
    refs[-1][...] = refs[0][...]


def clean_gather(tables, lens, q, k_pages, B, S, bps, ppb, G, D, page_size):
    # TPU idiom: prefetch tables drive a partial-bound per-page gather
    def kv_map(b, s, blk, tables, lens, *, j):
        del lens
        return (tables[b, s * bps + blk, j], 0, 0)

    kv_spec = lambda j: pl.BlockSpec(  # noqa: E731
        (1, page_size, D), functools.partial(kv_map, j=j))

    def m_map(b, s, blk, tables, lens):
        return (b, s, 0)

    def acc_map(b, s, blk, tables, lens):
        return (b, s, 0, 0)

    return pl.pallas_call(
        _kernel,
        grid_spec=pl.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, S, bps),
            in_specs=(
                [pl.BlockSpec((1, G, D), lambda b, s, blk, t, l: (b, 0, 0))]
                + [kv_spec(j) for j in range(ppb)]),
            out_specs=[pl.BlockSpec((1, 1, G), m_map),
                       pl.BlockSpec((1, 1, G, D), acc_map)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, S, G), jnp.float32),
            jax.ShapeDtypeStruct((B, S, G, D), jnp.float32),
        ],
    )(tables, lens, q, *([k_pages] * ppb))


def clean_whole_array(tables, q, B, S, G, D):
    # GPU idiom: whole-array factory specs, gathers happen in-kernel
    whole = lambda arr: pl.BlockSpec(  # noqa: E731
        arr.shape, lambda b, s: (0,) * arr.ndim)

    return pl.pallas_call(
        _kernel,
        grid=(B, S),
        in_specs=[whole(tables), whole(q)],
        out_specs=[
            pl.BlockSpec((1, 1, G), lambda b, s: (b, s, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, s: (b, s, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, G), jnp.float32),
            jax.ShapeDtypeStruct((B, S, G, D), jnp.float32),
        ],
    )(tables, q)
