"""Contract-respecting pallas_call idioms (fixture — parsed, never run).

Exercises the resolution paths the checker must handle without false
positives: module-constant dimension_semantics, grid_spec prefetch,
factory lambdas returning BlockSpecs, functools.partial-bound index maps,
list-concatenation in_specs, and vararg index maps absorbing prefetch.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DIM_SEMANTICS = ("parallel", "arbitrary")


def _kernel(q_ref, o_ref):
    o_ref[...] = q_ref[...]


def good_dim_semantics(q):
    return pl.pallas_call(
        _kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec(q.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec(q.shape, lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=DIM_SEMANTICS),
    )(q)


def _kv_map(i, j, tables, page=0):
    return (tables[i], j)


def good_prefetch(q, tables):
    # rank 2 + 1 prefetch = 3-arg maps; kv maps bound via partial,
    # in_specs built by list concatenation from a factory lambda
    whole = lambda arr: pl.BlockSpec(arr.shape, lambda i, j, t: (0, 0))
    kv_spec = lambda p: pl.BlockSpec(
        q.shape, functools.partial(_kv_map, page=p))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 4),
        in_specs=[whole(q)] + [kv_spec(p) for p in range(2)],
        out_specs=pl.BlockSpec(q.shape, lambda i, j, t: (0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(tables, q, q, q)


def good_vararg_maps(q, tables, lens):
    # *pref absorbs a trailing prefetch pack of unresolvable size
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(4, 4),
        in_specs=[pl.BlockSpec(q.shape, lambda i, j, *pref: (0, 0))],
        out_specs=pl.BlockSpec(q.shape, lambda i, j, *pref: (0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(tables, lens, q)


def _split_partials_kernel(q_ref, m_ref, l_ref, acc_ref):
    acc_ref[...] = q_ref[...]


def good_partials(q):
    return pl.pallas_call(
        _split_partials_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec(q.shape, lambda s: (0, 0))],
        out_specs=[pl.BlockSpec(q.shape, lambda s: (0, 0))] * 3,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
        ],
    )(q)


def combine_partials_like(m, l, acc):
    # "combine" consumes partials and emits ONE output — must not be
    # held to the three-output partials contract
    return pl.pallas_call(
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec(m.shape, lambda s: (0, 0))],
        out_specs=pl.BlockSpec(m.shape, lambda s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(m.shape, jnp.bfloat16),
    )(m)
