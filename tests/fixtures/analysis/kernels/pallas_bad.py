"""Seeded pallas-contract violations (fixture — parsed, never executed)."""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BAD_DIM_SEMANTICS = ("parallel", "parallel", "arbitrary")  # len 3, grid rank 2


def _kernel(q_ref, o_ref):
    o_ref[...] = q_ref[...]


def bad_dim_semantics(q):
    # grid rank 2 but dimension_semantics has 3 entries
    return pl.pallas_call(
        _kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec(q.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec(q.shape, lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=BAD_DIM_SEMANTICS),
    )(q)


def bad_index_map_arity(q):
    # grid rank 2, no scalar prefetch: index maps must take 2 params
    return pl.pallas_call(
        _kernel,
        grid=(4, 4),
        in_specs=[pl.BlockSpec(q.shape, lambda i, j, k: (0, 0))],
        out_specs=pl.BlockSpec(q.shape, lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(q)


def bad_prefetch_arity(q, tables):
    # rank 2 + 1 scalar prefetch: maps need 3 params, these take 2
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(4, 4),
        in_specs=[pl.BlockSpec(q.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec(q.shape, lambda i, j: (0, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(tables, q)


def _partials_kernel(q_ref, m_ref, l_ref):
    m_ref[...] = q_ref[...]


def two_output_partials(q):
    # split-K partials must emit three (m, l, acc) outputs, not two
    return pl.pallas_call(
        _partials_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec(q.shape, lambda s: (0, 0))],
        out_specs=[pl.BlockSpec(q.shape, lambda s: (0, 0))] * 2,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
        ],
    )(q)


def halfprec_partials(q):
    # three outputs but the accumulator is bf16, not f32
    return pl.pallas_call(
        _partials_kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec(q.shape, lambda s: (0, 0))],
        out_specs=[pl.BlockSpec(q.shape, lambda s: (0, 0))] * 3,
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
            jax.ShapeDtypeStruct(q.shape, jnp.float32),
            jax.ShapeDtypeStruct(q.shape, jnp.bfloat16),
        ],
    )(q)
