"""Tracer-safe kernels and steps (fixture — parsed, never executed)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _safe_kernel(q_ref, lens_ref, o_ref, *, page_size, window):
    # static kw-only params may drive Python control flow
    if window > 0:
        page_size = page_size + 0
    # shape math on a traced ref is host-side and static
    n_pages = q_ref.shape[0] // page_size
    L = lens_ref[0]
    # traced control flow goes through jnp/pl primitives
    o_ref[...] = jnp.where(L > page_size, q_ref[...], q_ref[...] * 0)

    @pl.when(L > 0)
    def _tail():
        o_ref[0] = q_ref[0]


def run_safe(q, lens):
    return pl.pallas_call(
        functools.partial(_safe_kernel, page_size=16, window=0),
        grid=(1,),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(q, lens)


def _scaled_int8_kernel(q_ref, k_ref, o_ref, *, kv_scale):
    k = k_ref[...].astype(jnp.float32) * kv_scale
    o_ref[...] = q_ref[...] * k


def run_scaled(q, k):
    return pl.pallas_call(
        functools.partial(_scaled_int8_kernel, kv_scale=0.5),
        grid=(1,),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(q, k)


@functools.partial(jax.jit, static_argnames=("chunk",))
def jitted_step(state, tok, *, chunk):
    # static_argnames drive host control flow legally
    if chunk > 1:
        tok = tok + 0
    # np on static shape-derived scalars is host-side planning
    scale = 1.0 / np.sqrt(state["k"].shape[-1])
    return state, tok * scale


def plain_host_helper(xs):
    # not jitted, not a kernel: host control flow is fine
    if xs[0] > 0:
        return float(xs[0])
    return 0.0


@jax.jit
def jitted_loop_clean(xs):
    # structured control flow on the carry stays inside the trace
    def body(i, carry):
        return carry + jnp.where(carry > 0, xs[i], 0.0)
    total = jax.lax.fori_loop(0, 4, body, 0.0)
    return jnp.where(total > 1.0, total, 0.0)


@jax.jit
def jitted_scan_clean(xs):
    def step(carry, x):
        return carry + x, jnp.tanh(carry)
    out, ys = jax.lax.scan(step, 0.0, xs)
    return out, ys
