"""Suppression-comment fixture: every violation here is disabled."""


def attention(q, backend=None):
    return (q, backend)


def trailing_comment(q, backend=None):
    return attention(q)  # replint: disable=knob-threading -- fixture: trailing

def preceding_comment(q, backend=None):
    # replint: disable=knob-threading -- fixture: preceding line
    return attention(q)


def multi_rule(q, backend=None):
    # replint: disable=knob-threading,allocator-discipline -- fixture: list
    return attention(q)
