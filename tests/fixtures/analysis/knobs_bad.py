"""Seeded knob-threading violations (fixture — parsed, never executed)."""


def attention(q, kv, backend=None, combine_mode=None, pages_per_block=None):
    return (q, kv, backend, combine_mode, pages_per_block)


def drops_backend(q, kv, backend=None):
    # accepts `backend` but calls a backend-accepting callee without it
    return attention(q, kv)


def drops_one_of_two(q, kv, backend=None, combine_mode=None):
    # forwards backend, silently drops combine_mode
    return attention(q, kv, backend=backend)


class Engine:
    def decode(self, q, kv, pages_per_block=None):
        # method call: the knob vanishes at the last hop
        return self._inner(q, kv)

    def _inner(self, q, kv, pages_per_block=None):
        return attention(q, kv, pages_per_block=pages_per_block)
