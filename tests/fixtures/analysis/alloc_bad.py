"""Seeded allocator-discipline violations (fixture — parsed, never run)."""


class Scheduler:
    def __init__(self, mgr):
        self.mgr = mgr

    def sneaky_admit(self, req):
        # refcount mutated outside the allocator classes
        self.mgr.refcount[req.first_page] += 1
        return req

    def sneaky_free(self, req):
        self.mgr.state.refcount[req.first_page] = 0
        return req

    def leaky_admit(self, req, prompt):
        # reserve + attach, then a raise with no rollback path: the
        # reserved pages leak when the raise fires
        self.mgr.reserve(req.rid, len(prompt))
        self.mgr.attach(req.rid, prompt)
        if req.rid < 0:
            raise KeyError("bad rid")
        return req
