"""Taxonomy-respecting error handling (fixture — parsed, never executed)."""
from repro import errors
from repro.errors import EngineConfigError, EngineError, InvalidRequest


class LocalEngineError(EngineError):
    """In-file subclass of a taxonomy type — counts as structured."""


def structured(x):
    if x < 0:
        raise EngineConfigError(f"negative: {x}", value=x)
    return x


def structured_with_rid(rid, n):
    if n > 8:
        raise InvalidRequest(f"too many forks: {n}", rid=rid)
    return n


def structured_module_alias(seq_id):
    raise errors.PoolExhausted("dry", rid=seq_id, resource="pages")


def structured_local_subclass():
    raise LocalEngineError("still routable")


def handled(xs):
    try:
        return xs[0]
    except IndexError:
        return None


def counted(xs, stats):
    try:
        return xs[0]
    except IndexError:
        stats["misses"] += 1
        return None
