"""Historical scheduler/allocator bugs re-seeded as model-checker
fixtures.

Each driver subclasses the live ``LifecycleDriver`` and overrides one
``_do_*`` method with the *pre-fix* transition relation; the
``statemachine`` rule loads this file by path and must rediscover both
defects with a minimal counterexample trace (gated by
``tests/test_statemachine.py``):

* ``ExtendAfterPreemptDriver`` — PR 4's extend-after-preempt aliasing:
  the decode loop iterates a snapshot of the running set without
  re-checking membership, so ``mgr.extend`` runs on a victim preempted
  earlier in the same pass, re-reserving pages under a PREEMPTED rid
  (the stale row then survives ``tables.setdefault`` on re-admission —
  silent KV aliasing).
* ``ForkNoRollbackDriver`` — the fork refcount-rollback bug: on a dry
  pool the child row is deleted but the shared-prefix refcount bumps
  are kept, desyncing ``refcount`` from table occupancy.
"""

from repro.analysis.statemachine import (FORK_RID_BASE, LifecycleDriver,
                                         ModelConfig)
from repro.serving.request import Status


class ExtendAfterPreemptDriver(LifecycleDriver):
    """Decode with the pre-fix loop: no preemption-safety re-check."""

    def _do_decode(self):
        sched = self.sched
        # BUG (pre-PR4): the RUNNING filter runs once, up front — a
        # victim preempted by an earlier iteration of this very loop is
        # still extended, and mgr.extend re-reserves pages under its
        # now-PREEMPTED rid
        order = [r for r in sorted(sched.running.values(),
                                   key=lambda r: r.rid)
                 if r.status is Status.RUNNING]
        for req in order:
            while not sched.mgr.extend(req.rid, 1):
                cand = [r for r in sched.running.values()
                        if r.status in (Status.RUNNING, Status.PREFILLING)
                        and r is not req]
                if not cand:
                    break
                sched._preempt(max(cand, key=lambda r: r.rid))
        for req in list(sched.running.values()):
            if (req.status is Status.RUNNING
                    and len(req.output) < self.cfg.max_new):
                req.output.append(7)


class ForkNoRollbackDriver(LifecycleDriver):
    """Fork with the pre-fix failure path: bumps kept, row deleted."""

    def _do_fork(self, src_rid):
        mgr = self.sched.mgr
        dst = FORK_RID_BASE + self.fork_count
        self.fork_count += 1
        src_len = mgr.lens[src_rid]
        full = src_len // mgr.page_size
        row = mgr.tables[src_rid][:full]
        for p in row:
            mgr.refcount[p] += 1
        mgr.tables[dst] = list(row)
        mgr.lens[dst] = full * mgr.page_size
        if src_len % mgr.page_size:
            if not mgr.reserve(dst, src_len):
                # BUG (pre-fix): the shared-prefix refcount bumps are
                # not rolled back with the row
                del mgr.tables[dst]
                del mgr.lens[dst]
                return
        self.forked = self.forked | {dst}


# two RUNNING rows on an exactly-full pool: the first extend must
# preempt, and the buggy loop then extends the victim it just preempted
_EXTEND_CFG = ModelConfig(
    name="extend-after-preempt", num_pages=2, page_size=2, max_slots=2,
    prompts=((1, 2), (3, 4)), cancel_budget=0, fail_budget=0)

# a parent with a partial tail page on a dry pool: fork's tail
# reservation must fail and roll the prefix bumps back
_FORK_CFG = ModelConfig(
    name="fork-no-rollback", num_pages=2, page_size=2, max_slots=1,
    prompts=((1, 2, 3),), fork=True, cancel_budget=0, fail_budget=0)

REPLINT_STATEMACHINE_CASES = [
    ("extend-after-preempt",
     lambda: ExtendAfterPreemptDriver(_EXTEND_CFG)),
    ("fork-no-rollback", lambda: ForkNoRollbackDriver(_FORK_CFG)),
]
