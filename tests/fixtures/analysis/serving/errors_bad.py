"""Seeded error-discipline violations (fixture — parsed, never executed)."""
from repro.errors import EngineError, InvalidRequest


class LocalOops(Exception):
    """Not derived from the taxonomy."""


def bare_builtin(x):
    if x < 0:
        raise ValueError(f"negative: {x}")
    return x


def runtime_builtin():
    raise RuntimeError("backend exploded")


def off_taxonomy():
    raise LocalOops("not routable by the engine")


def missing_rid(rid, n):
    if n > 8:
        raise InvalidRequest(f"too many forks: {n}")
    return n


def swallow(xs):
    total = 0
    for x in xs:
        try:
            total += int(x)
        except Exception:
            pass
    return total


def swallow_with_docstring(xs):
    try:
        return xs[0]
    except IndexError:
        """nothing to see here"""


def fine_reraise(rid):
    try:
        return 1
    except EngineError as e:
        raise  # bare re-raise is fine
    except ValueError as e:
        raise e  # re-raising the caught name is fine
