"""Allocator-disciplined callers (fixture — parsed, never executed)."""


class HostPageManager:
    def __init__(self, n):
        self.refcount = [0] * n
        self.lens = {}

    def reserve(self, rid, n):
        # mutation inside the owning class is the sanctioned path
        self.refcount[0] += 1
        return True

    def free(self, rid):
        self.refcount[0] -= 1

    def fork(self, src, dst):
        for p in range(2):
            self.refcount[p] += 1
        if src not in self.lens:
            # rollback before raise: undo the bumps
            for p in range(2):
                self.refcount[p] -= 1
            raise KeyError(src)  # replint: disable=error-discipline -- fixture
        return True


class Scheduler:
    def __init__(self, mgr):
        self.mgr = mgr

    def admit(self, req, prompt):
        self.mgr.reserve(req.rid, len(prompt))
        ok = self.mgr.attach(req.rid, prompt)
        if not ok:
            # undo call before the raise: disciplined
            self.mgr.free(req.rid)
            raise KeyError("attach failed")
        return req

    def functional_read(self, state, pages):
        # .at[...] is a functional *read* producing a new array
        return state.refcount.at[pages].add(1)
