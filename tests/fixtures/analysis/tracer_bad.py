"""Seeded tracer-safety violations (fixture — parsed, never executed)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _branchy_kernel(q_ref, lens_ref, o_ref, *, page_size):
    L = lens_ref[0]
    if L > page_size:  # Python `if` on a traced value
        o_ref[...] = q_ref[...]
    s = float(L)  # host escape on a traced value
    o_ref[0] = np.tanh(q_ref[0])  # np.* fed a traced value
    n = q_ref[...].item()  # .item() forces a device sync


def run_branchy(q, lens):
    return pl.pallas_call(
        functools.partial(_branchy_kernel, page_size=16),
        grid=(1,),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(q, lens)


def _unscaled_int8_kernel(q_ref, k_ref, o_ref, *, kv_scale):
    # declares kv_scale but never applies it: int8 reads stay unscaled
    o_ref[...] = q_ref[...] * k_ref[...]


def run_unscaled(q, k):
    return pl.pallas_call(
        functools.partial(_unscaled_int8_kernel, kv_scale=0.5),
        grid=(1,),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
    )(q, k)


@jax.jit
def jitted_step(state, tok):
    pos = state["pos"]
    while pos > 0:  # Python `while` on a traced value
        pos = pos - 1
    return state, tok


@jax.jit
def jitted_loop_carry(xs):
    # the fori_loop carry is traced even though init is a constant —
    # branching on the body parameter and on the loop result both escape
    def body(i, carry):
        if carry > 0:  # Python `if` on a traced loop carry
            return carry + xs[i]
        return carry
    total = jax.lax.fori_loop(0, 4, body, 0.0)
    if total > 1.0:  # Python `if` on a traced loop result
        return total
    return float(total)  # host escape on the traced result


@jax.jit
def jitted_scan_carry(xs):
    def step(carry, x):
        return carry + x, np.tanh(carry)  # np.* on a traced scan carry
    out, ys = jax.lax.scan(step, 0.0, xs)
    return out, ys
