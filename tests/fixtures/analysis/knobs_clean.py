"""Knob-respecting call chains (fixture — parsed, never executed)."""


def attention(q, kv, backend=None, combine_mode=None, pages_per_block=None):
    return (q, kv, backend, combine_mode, pages_per_block)


def forwards_kw(q, kv, backend=None, combine_mode=None):
    return attention(q, kv, backend=backend, combine_mode=combine_mode)


def forwards_splat(q, kv, backend=None, **kw):
    return attention(q, kv, backend=backend, **kw)


def forwards_positionally(q, kv, backend=None):
    return attention(q, kv, backend)


def unrelated_callee(q, backend=None):
    # callee takes no knobs: nothing to forward
    return helper(q)


def helper(q):
    return q


class Engine:
    def decode(self, q, kv, pages_per_block=None):
        return self._inner(q, kv, pages_per_block=pages_per_block)

    def _inner(self, q, kv, pages_per_block=None):
        return attention(q, kv, pages_per_block=pages_per_block)
