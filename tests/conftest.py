import jax
import numpy as np
import pytest

try:  # property tests use hypothesis when available ...
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # ... else a deterministic-sampling stand-in
    import _hypothesis_stub
    _hypothesis_stub._install()

# Smoke tests and benches run on the single real CPU device; ONLY the
# dry-run (repro.launch.dryrun, run as its own process) forces 512 devices.
jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


def assert_close(a, b, rtol=2e-5, atol=2e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=rtol, atol=atol)
