"""replint (ISSUE 8): the project-native static-analysis suite.

Covers, per the acceptance contract:

  * every rule fires on its seeded-violation fixture and stays silent on
    the matching contract-respecting fixture;
  * suppression comments (trailing, preceding-line, multi-rule lists)
    silence findings at the source;
  * the checked-in baseline grandfathers findings by line-independent key
    (rule, path, symbol, message) — unrelated line shifts don't resurrect
    them, *new* findings still gate;
  * the JSON report schema is stable (versioned, fixed key set);
  * the live tree is clean: ``python -m repro.analysis`` on src/repro
    exits 0 with the checked-in baseline;
  * the structured error types this PR introduced keep their double
    inheritance (old ``except ValueError`` callers stay green) and the
    converted raise sites emit them.
"""

import json
from pathlib import Path

import pytest

from repro.analysis import (RULES, active, analyze_paths, apply_baseline,
                            load_baseline, render_json, render_text,
                            write_baseline)
from repro.analysis.__main__ import main as replint_main
from repro.errors import (DistributedSetupError, EngineConfigError,
                          EngineError, UnsupportedFeature)

ROOT = Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "analysis"


def run_on(*names, rules=None):
    files = [FIXTURES / n for n in names]
    return analyze_paths([], ROOT, rules=rules, files=files)


def gating(findings):
    return [f for f in findings if not f.suppressed and not f.baselined]


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------
def test_all_five_rules_registered():
    assert set(RULES) >= {"pallas-contract", "knob-threading",
                          "error-discipline", "tracer-safety",
                          "allocator-discipline"}
    for rule in RULES.values():
        assert rule.doc  # --list-rules has something to print


# ---------------------------------------------------------------------------
# pallas-contract
# ---------------------------------------------------------------------------
def test_pallas_contract_fires_on_seeded_violations():
    msgs = [f.message for f in run_on("kernels/pallas_bad.py")]
    assert any("dimension_semantics has 3" in m for m in msgs)
    assert sum("index_map takes" in m for m in msgs) >= 3
    assert any("exactly three (m, l, acc)" in m for m in msgs)
    assert any("must be f32" in m for m in msgs)


def test_pallas_contract_clean_on_contract_respecting_idioms():
    # factory lambdas, partial-bound maps, vararg prefetch packs,
    # list-concatenated in_specs: none may false-positive
    assert run_on("kernels/pallas_clean.py") == []


def test_pallas_contract_scoped_to_kernels_dirs():
    # the same violations outside a kernels/ dir are out of scope
    assert RULES["pallas-contract"].applies("src/repro/kernels/x.py")
    assert not RULES["pallas-contract"].applies("src/repro/serving/x.py")


# ---------------------------------------------------------------------------
# knob-threading
# ---------------------------------------------------------------------------
def test_knob_threading_fires_on_dropped_knobs():
    findings = run_on("knobs_bad.py")
    by_symbol = {f.symbol: f.message for f in findings}
    assert "'drops_backend' accepts knob 'backend'" in \
        by_symbol["drops_backend"]
    assert "combine_mode" in by_symbol["drops_one_of_two"]
    assert "pages_per_block" in by_symbol["Engine.decode"]
    assert len(findings) == 3


def test_knob_threading_accepts_kw_splat_and_positional_forwarding():
    assert run_on("knobs_clean.py") == []


# ---------------------------------------------------------------------------
# error-discipline
# ---------------------------------------------------------------------------
def test_error_discipline_fires_on_seeded_violations():
    msgs = [f.message for f in run_on("serving/errors_bad.py")]
    assert any("bare `raise ValueError`" in m for m in msgs)
    assert any("bare `raise RuntimeError`" in m for m in msgs)
    assert any("LocalOops" in m for m in msgs)
    assert any("does not pass rid=" in m for m in msgs)
    assert sum("silent except-swallow" in m for m in msgs) == 2


def test_error_discipline_accepts_taxonomy_and_subclasses():
    # direct imports, module-alias raises, in-file EngineError subclasses,
    # rid-carrying raises, and handlers that actually handle
    assert run_on("serving/errors_clean.py") == []


def test_error_discipline_scoped_to_engine_layers():
    rule = RULES["error-discipline"]
    assert rule.applies("src/repro/serving/engine.py")
    assert rule.applies("src/repro/core/paging.py")
    assert not rule.applies("src/repro/training/loop.py")


# ---------------------------------------------------------------------------
# tracer-safety
# ---------------------------------------------------------------------------
def test_tracer_safety_fires_on_host_escapes():
    msgs = [f.message for f in run_on("tracer_bad.py")]
    assert any("Python `if` on a traced value" in m for m in msgs)
    assert any("Python `while` on a traced value" in m for m in msgs)
    assert any("`float()` on a traced value" in m for m in msgs)
    assert any("np.tanh() on a traced value" in m for m in msgs)
    assert any("`.item()` host escape" in m for m in msgs)
    assert any("never applies it" in m for m in msgs)  # unused kv_scale


def test_tracer_safety_clean_on_static_control_flow():
    # kw-only kernel params, static_argnames, .shape math, np on static
    # scalars, pl.when/jnp.where, and plain host helpers: all legal
    assert run_on("tracer_clean.py") == []


# ---------------------------------------------------------------------------
# allocator-discipline
# ---------------------------------------------------------------------------
def test_allocator_discipline_fires_on_outside_mutation_and_leaks():
    findings = run_on("alloc_bad.py")
    msgs = [f.message for f in findings]
    assert sum("refcount mutated outside" in m for m in msgs) == 2
    assert any("no rollback path" in m for m in msgs)


def test_allocator_discipline_accepts_owned_mutations_and_rollbacks():
    assert run_on("alloc_clean.py") == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def test_suppression_comments_silence_findings():
    findings = run_on("suppressed.py")
    assert len(findings) == 3  # still *reported* as suppressed...
    assert all(f.suppressed for f in findings)
    assert gating(findings) == []  # ...but none gate


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def test_baseline_grandfathers_by_line_independent_key(tmp_path):
    findings = run_on("knobs_bad.py")
    assert findings
    bl = tmp_path / "baseline.json"
    write_baseline(findings, bl)

    again = run_on("knobs_bad.py")
    for f in again:
        f.line += 40  # simulate unrelated edits shifting every line
    apply_baseline(again, load_baseline(bl))
    assert all(f.baselined for f in again)
    assert gating(again) == []

    # a NEW finding (different message/symbol) still gates
    fresh = run_on("alloc_bad.py")
    apply_baseline(fresh, load_baseline(bl))
    assert gating(fresh)


def test_baseline_file_format_is_versioned(tmp_path):
    bl = tmp_path / "baseline.json"
    write_baseline(run_on("knobs_bad.py"), bl)
    data = json.loads(bl.read_text())
    assert data["version"] == 1
    assert {"rule", "path", "symbol", "message"} == set(
        data["findings"][0])


# ---------------------------------------------------------------------------
# reporters
# ---------------------------------------------------------------------------
def test_json_report_schema_is_stable():
    findings = run_on("alloc_bad.py", "suppressed.py")
    payload = json.loads(render_json(findings, sorted(RULES)))
    assert set(payload) == {"version", "tool", "rules", "findings",
                            "summary"}
    assert payload["version"] == 1
    assert payload["tool"] == "replint"
    assert set(payload["summary"]) == {"total", "suppressed", "baselined",
                                       "gating"}
    assert payload["summary"]["gating"] == 3
    assert payload["summary"]["suppressed"] == 3
    for f in payload["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "symbol",
                          "message", "suppressed", "baselined"}


def test_text_report_counts_and_locations():
    findings = run_on("knobs_bad.py")
    text = render_text(findings)
    assert "knobs_bad.py:10:" in text
    assert "replint: 3 finding(s)" in text


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def test_driver_list_rules(capsys):
    assert replint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in RULES:
        assert name in out


def test_driver_unknown_rule_is_usage_error(capsys):
    assert replint_main(["--rules", "no-such-rule"]) == 2


def test_driver_exit_codes_on_fixture(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    bad = str(FIXTURES / "knobs_bad.py")
    assert replint_main([bad, "--baseline", ""]) == 1
    clean = str(FIXTURES / "knobs_clean.py")
    assert replint_main([clean, "--baseline", ""]) == 0


def test_driver_rule_selection(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    bad = str(FIXTURES / "alloc_bad.py")
    # selecting an unrelated rule sees no findings in this fixture
    assert replint_main([bad, "--rules", "pallas-contract",
                         "--baseline", ""]) == 0
    assert replint_main([bad, "--rules", "allocator-discipline",
                         "--baseline", ""]) == 1


# ---------------------------------------------------------------------------
# the live tree is clean (the `make lint` gate, in-process)
# ---------------------------------------------------------------------------
def test_live_tree_is_clean_under_checked_in_baseline(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    assert replint_main(["src/repro"]) == 0


# ---------------------------------------------------------------------------
# regression: the structured errors this PR introduced at real raise sites
# ---------------------------------------------------------------------------
def test_config_errors_keep_valueerror_compatibility():
    # double inheritance: new structured types remain catchable by the
    # builtin supertypes pre-existing callers except on
    assert issubclass(EngineConfigError, ValueError)
    assert issubclass(EngineConfigError, EngineError)
    assert issubclass(UnsupportedFeature, NotImplementedError)
    assert issubclass(UnsupportedFeature, EngineError)
    assert issubclass(DistributedSetupError, RuntimeError)
    assert issubclass(DistributedSetupError, EngineError)


def test_unknown_backend_is_structured():
    from repro.kernels import resolve_backend
    with pytest.raises(EngineConfigError, match="backend must be one of"):
        resolve_backend("cuda-graphs")
    try:
        resolve_backend("cuda-graphs")
    except EngineConfigError as e:
        assert e.context["backend"] == "cuda-graphs"


def test_unknown_combine_mode_is_structured():
    from repro.kernels.paged_attention.paged_attention import \
        resolve_combine_mode
    with pytest.raises(EngineConfigError, match="combine_mode"):
        resolve_combine_mode("fused", 2)


def test_unknown_family_is_structured():
    import dataclasses

    from repro.configs import get_smoke
    from repro.models.api import build_model
    cfg = dataclasses.replace(get_smoke("llama2-7b"), family="mamba")
    with pytest.raises(EngineConfigError, match="unknown family"):
        build_model(cfg)


def test_recurrent_chunked_prefill_is_structured():
    from repro.configs import get_smoke
    from repro.serving.engine import Engine
    cfg = get_smoke("recurrentgemma-9b")
    with pytest.raises(EngineConfigError, match="recurrent"):
        Engine(cfg, max_slots=2, max_seq_len=64, prefill_chunk=8)


def test_fault_plan_errors_are_structured():
    from repro.serving.faults import FaultPlan, FaultRule
    with pytest.raises(EngineConfigError, match="unknown fault site"):
        FaultPlan([FaultRule(site="warp", kind="nan")])
    try:
        FaultPlan([FaultRule(site="warp", kind="nan")])
    except EngineConfigError as e:
        assert e.context["site"] == "warp"
