"""replint (ISSUE 8): the project-native static-analysis suite.

Covers, per the acceptance contract:

  * every rule fires on its seeded-violation fixture and stays silent on
    the matching contract-respecting fixture;
  * suppression comments (trailing, preceding-line, multi-rule lists)
    silence findings at the source;
  * the checked-in baseline grandfathers findings by line-independent key
    (rule, path, symbol, message) — unrelated line shifts don't resurrect
    them, *new* findings still gate;
  * the JSON report schema is stable (versioned, fixed key set);
  * the live tree is clean: ``python -m repro.analysis`` on src/repro
    exits 0 with the checked-in baseline;
  * the structured error types this PR introduced keep their double
    inheritance (old ``except ValueError`` callers stay green) and the
    converted raise sites emit them.
"""

import json
from pathlib import Path

import pytest

from repro.analysis import (RULES, active, analyze_paths, apply_baseline,
                            load_baseline, render_json, render_text,
                            write_baseline)
from repro.analysis.core import render_sarif, stale_baseline_entries
from repro.analysis.__main__ import _merge_base_files, main as replint_main
from repro.errors import (DistributedSetupError, EngineConfigError,
                          EngineError, UnsupportedFeature)

ROOT = Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "analysis"


def run_on(*names, rules=None):
    files = [FIXTURES / n for n in names]
    return analyze_paths([], ROOT, rules=rules, files=files)


def gating(findings):
    return [f for f in findings if not f.suppressed and not f.baselined]


# ---------------------------------------------------------------------------
# rule registry
# ---------------------------------------------------------------------------
def test_all_rules_registered():
    assert set(RULES) >= {"pallas-contract", "knob-threading",
                          "error-discipline", "tracer-safety",
                          "allocator-discipline", "shapes",
                          "statemachine"}
    for rule in RULES.values():
        assert rule.doc  # --list-rules has something to print


# ---------------------------------------------------------------------------
# pallas-contract
# ---------------------------------------------------------------------------
def test_pallas_contract_fires_on_seeded_violations():
    msgs = [f.message for f in run_on("kernels/pallas_bad.py")]
    assert any("dimension_semantics has 3" in m for m in msgs)
    assert sum("index_map takes" in m for m in msgs) >= 3
    assert any("exactly three (m, l, acc)" in m for m in msgs)
    assert any("must be f32" in m for m in msgs)


def test_pallas_contract_clean_on_contract_respecting_idioms():
    # factory lambdas, partial-bound maps, vararg prefetch packs,
    # list-concatenated in_specs: none may false-positive
    assert run_on("kernels/pallas_clean.py") == []


def test_pallas_contract_scoped_to_kernels_dirs():
    # the same violations outside a kernels/ dir are out of scope
    assert RULES["pallas-contract"].applies("src/repro/kernels/x.py")
    assert not RULES["pallas-contract"].applies("src/repro/serving/x.py")


# ---------------------------------------------------------------------------
# knob-threading
# ---------------------------------------------------------------------------
def test_knob_threading_fires_on_dropped_knobs():
    findings = run_on("knobs_bad.py")
    by_symbol = {f.symbol: f.message for f in findings}
    assert "'drops_backend' accepts knob 'backend'" in \
        by_symbol["drops_backend"]
    assert "combine_mode" in by_symbol["drops_one_of_two"]
    assert "pages_per_block" in by_symbol["Engine.decode"]
    assert len(findings) == 3


def test_knob_threading_accepts_kw_splat_and_positional_forwarding():
    assert run_on("knobs_clean.py") == []


# ---------------------------------------------------------------------------
# error-discipline
# ---------------------------------------------------------------------------
def test_error_discipline_fires_on_seeded_violations():
    msgs = [f.message for f in run_on("serving/errors_bad.py")]
    assert any("bare `raise ValueError`" in m for m in msgs)
    assert any("bare `raise RuntimeError`" in m for m in msgs)
    assert any("LocalOops" in m for m in msgs)
    assert any("does not pass rid=" in m for m in msgs)
    assert sum("silent except-swallow" in m for m in msgs) == 2


def test_error_discipline_accepts_taxonomy_and_subclasses():
    # direct imports, module-alias raises, in-file EngineError subclasses,
    # rid-carrying raises, and handlers that actually handle
    assert run_on("serving/errors_clean.py") == []


def test_error_discipline_scoped_to_engine_layers():
    rule = RULES["error-discipline"]
    assert rule.applies("src/repro/serving/engine.py")
    assert rule.applies("src/repro/core/paging.py")
    assert not rule.applies("src/repro/training/loop.py")


# ---------------------------------------------------------------------------
# tracer-safety
# ---------------------------------------------------------------------------
def test_tracer_safety_fires_on_host_escapes():
    msgs = [f.message for f in run_on("tracer_bad.py")]
    assert any("Python `if` on a traced value" in m for m in msgs)
    assert any("Python `while` on a traced value" in m for m in msgs)
    assert any("`float()` on a traced value" in m for m in msgs)
    assert any("np.tanh() on a traced value" in m for m in msgs)
    assert any("`.item()` host escape" in m for m in msgs)
    assert any("never applies it" in m for m in msgs)  # unused kv_scale


def test_tracer_safety_taints_loop_carries():
    # fori_loop/scan carries are traced even from a constant init: the
    # body's parameters and the loop's result both carry taint
    by_sym = {}
    for f in run_on("tracer_bad.py"):
        by_sym.setdefault(f.symbol, []).append(f.message)
    assert any("`if` on a traced value" in m
               for m in by_sym["jitted_loop_carry"])
    assert any("`float()` on a traced value" in m
               for m in by_sym["jitted_loop_carry"])
    assert any("np.tanh() on a traced value" in m
               for m in by_sym["jitted_scan_carry"])


def test_tracer_safety_clean_on_static_control_flow():
    # kw-only kernel params, static_argnames, .shape math, np on static
    # scalars, pl.when/jnp.where, loop carries consumed with jnp ops,
    # and plain host helpers: all legal
    assert run_on("tracer_clean.py") == []


# ---------------------------------------------------------------------------
# shapes (ISSUE 9): abstract interpretation of pallas_call launches
# ---------------------------------------------------------------------------
def test_shapes_fires_on_all_five_defect_classes():
    findings = run_on("kernels/shapes_bad.py", rules=["shapes"])
    msgs = [f.message for f in findings]
    # 1. BlockSpec rank mismatch vs the pool array
    assert any("has rank" in m and "operand" in m for m in msgs)
    # 2. non-divisible block shape
    assert any("does not divide operand" in m for m in msgs)
    # 3. index_map addressing out-of-range blocks at some grid point
    assert any("beyond operand" in m for m in msgs)
    # 4. wrong split-K partial dtype (with the group tag in the message)
    assert any("split-K" in m and "must be" in m for m in msgs)
    # 5. TPU/GPU partial-contract skew
    assert any("parity broken" in m for m in msgs)
    # and a launch with no declared contract is itself a finding
    assert any("no declared kernel contract" in m for m in msgs)


def test_shapes_clean_on_contract_respecting_idioms():
    # prefetch-driven index maps, spec-factory lambdas, comprehension
    # in_specs, whole-array specs: none may false-positive
    assert run_on("kernels/shapes_clean.py", rules=["shapes"]) == []


def test_shapes_verifies_every_live_kernel_launch(monkeypatch):
    # the acceptance bar: every pallas_call site in src/repro/kernels
    # (both backends) is visited against a declared contract, and the
    # live tree is clean
    from repro.analysis import shapes
    visited = []
    orig = shapes._check_site

    def spy(ctx, call, site, contract):
        visited.append(site)
        return orig(ctx, call, site, contract)

    monkeypatch.setattr(shapes, "_check_site", spy)
    findings = analyze_paths(["src/repro/kernels"], ROOT, rules=["shapes"])
    assert gating(findings) == []
    assert set(visited) == {
        "paged_attention_partials", "paged_prefill_partials",
        "combine_partials_pallas", "paged_attention_partials_gpu",
        "paged_prefill_partials_gpu", "flex_attention_kernel"}


def test_shapes_scoped_to_kernels_dirs():
    assert RULES["shapes"].applies("src/repro/kernels/x.py")
    assert not RULES["shapes"].applies("src/repro/serving/x.py")


# ---------------------------------------------------------------------------
# allocator-discipline
# ---------------------------------------------------------------------------
def test_allocator_discipline_fires_on_outside_mutation_and_leaks():
    findings = run_on("alloc_bad.py")
    msgs = [f.message for f in findings]
    assert sum("refcount mutated outside" in m for m in msgs) == 2
    assert any("no rollback path" in m for m in msgs)


def test_allocator_discipline_accepts_owned_mutations_and_rollbacks():
    assert run_on("alloc_clean.py") == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def test_suppression_comments_silence_findings():
    findings = run_on("suppressed.py")
    assert len(findings) == 3  # still *reported* as suppressed...
    assert all(f.suppressed for f in findings)
    assert gating(findings) == []  # ...but none gate


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def test_baseline_grandfathers_by_line_independent_key(tmp_path):
    findings = run_on("knobs_bad.py")
    assert findings
    bl = tmp_path / "baseline.json"
    write_baseline(findings, bl)

    again = run_on("knobs_bad.py")
    for f in again:
        f.line += 40  # simulate unrelated edits shifting every line
    apply_baseline(again, load_baseline(bl))
    assert all(f.baselined for f in again)
    assert gating(again) == []

    # a NEW finding (different message/symbol) still gates
    fresh = run_on("alloc_bad.py")
    apply_baseline(fresh, load_baseline(bl))
    assert gating(fresh)


def test_baseline_file_format_is_versioned(tmp_path):
    bl = tmp_path / "baseline.json"
    write_baseline(run_on("knobs_bad.py"), bl)
    data = json.loads(bl.read_text())
    assert data["version"] == 1
    assert {"rule", "path", "symbol", "message"} == set(
        data["findings"][0])


def test_write_baseline_roundtrip_with_live_suppressions(tmp_path, capsys,
                                                         monkeypatch):
    # --write-baseline over a tree containing in-source suppressions:
    # suppressed findings are NOT grandfathered (deleting the comment
    # must surface them again), and the written file round-trips to a
    # green run
    monkeypatch.chdir(ROOT)
    bl = tmp_path / "bl.json"
    paths = [str(FIXTURES / "alloc_bad.py"), str(FIXTURES / "suppressed.py")]
    assert replint_main([*paths, "--baseline", str(bl),
                         "--write-baseline"]) == 0
    data = json.loads(bl.read_text())
    assert len(data["findings"]) == 3  # alloc_bad only, none suppressed
    assert all(f["path"].endswith("alloc_bad.py")
               for f in data["findings"])
    capsys.readouterr()
    assert replint_main([*paths, "--baseline", str(bl)]) == 0


def test_stale_baseline_entry_is_flagged_not_gating(tmp_path, capsys,
                                                    monkeypatch):
    monkeypatch.chdir(ROOT)
    rel = "tests/fixtures/analysis/knobs_bad.py"
    bl = tmp_path / "bl.json"
    assert replint_main([rel, "--baseline", str(bl),
                         "--write-baseline"]) == 0
    data = json.loads(bl.read_text())
    data["findings"].append({"rule": "knob-threading", "path": rel,
                             "symbol": "long_gone",
                             "message": "a finding that was fixed"})
    bl.write_text(json.dumps(data))
    capsys.readouterr()
    # still exit 0 (stale detection warns, never gates) with the
    # warning on stderr naming the dead entry
    assert replint_main([rel, "--baseline", str(bl)]) == 0
    err = capsys.readouterr().err
    assert "stale baseline entry" in err
    assert "long_gone" in err


def test_stale_baseline_entries_scoped_to_analyzed_paths():
    findings = run_on("knobs_bad.py")
    live = {f.key() for f in findings}
    path = findings[0].path
    stale_here = ("knob-threading", path, "gone", "old msg")
    stale_elsewhere = ("knob-threading", "src/repro/other.py", "x", "m")
    baseline = live | {stale_here, stale_elsewhere}
    # full run (analyzed_paths=None): every dead entry is in scope
    assert stale_baseline_entries(findings, baseline) == \
        sorted([stale_here, stale_elsewhere])
    # --changed-only run: entries for unanalyzed files stay quiet
    assert stale_baseline_entries(findings, baseline, [path]) == \
        [stale_here]
    # live entries are never stale
    assert stale_baseline_entries(findings, live) == []


# ---------------------------------------------------------------------------
# reporters
# ---------------------------------------------------------------------------
def test_json_report_schema_is_stable():
    findings = run_on("alloc_bad.py", "suppressed.py")
    payload = json.loads(render_json(findings, sorted(RULES)))
    assert set(payload) == {"version", "tool", "rules", "findings",
                            "summary"}
    assert payload["version"] == 1
    assert payload["tool"] == "replint"
    assert set(payload["summary"]) == {"total", "suppressed", "baselined",
                                       "gating"}
    assert payload["summary"]["gating"] == 3
    assert payload["summary"]["suppressed"] == 3
    for f in payload["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "symbol",
                          "message", "suppressed", "baselined"}


def test_text_report_counts_and_locations():
    findings = run_on("knobs_bad.py")
    text = render_text(findings)
    assert "knobs_bad.py:10:" in text
    assert "replint: 3 finding(s)" in text


def test_text_report_matches_problem_matcher():
    # the CI lint leg turns report lines into PR annotations through
    # .github/replint-problem-matcher.json — the formats must agree
    import re
    matcher = json.loads(
        (ROOT / ".github" / "replint-problem-matcher.json").read_text())
    pattern = matcher["problemMatcher"][0]["pattern"][0]
    rx = re.compile(pattern["regexp"])
    findings = run_on("knobs_bad.py")
    lines = [ln for ln in render_text(findings).splitlines()
             if not ln.startswith("replint:")]
    assert lines
    for ln in lines:
        m = rx.match(ln)
        assert m, f"problem matcher missed: {ln!r}"
        assert m.group(pattern["code"]) in RULES


def test_sarif_report_schema():
    findings = run_on("alloc_bad.py", "suppressed.py")
    payload = json.loads(render_sarif(findings, sorted(RULES)))
    assert payload["version"] == "2.1.0"
    run = payload["runs"][0]
    assert run["tool"]["driver"]["name"] == "replint"
    rule_ids = [r["id"] for r in run["tool"]["driver"]["rules"]]
    assert set(rule_ids) >= set(RULES)
    results = run["results"]
    assert len(results) == 6
    # suppressed findings travel with a suppressions entry, mirroring
    # the gating semantics instead of silently vanishing
    assert sum("suppressions" in r for r in results) == 3
    for r in results:
        assert r["ruleId"] == rule_ids[r["ruleIndex"]]
        region = r["locations"][0]["physicalLocation"]["region"]
        assert region["startLine"] >= 1 and region["startColumn"] >= 1


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def test_driver_list_rules(capsys):
    assert replint_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in RULES:
        assert name in out


def test_driver_unknown_rule_is_usage_error(capsys):
    assert replint_main(["--rules", "no-such-rule"]) == 2


def test_driver_exit_codes_on_fixture(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    bad = str(FIXTURES / "knobs_bad.py")
    assert replint_main([bad, "--baseline", ""]) == 1
    clean = str(FIXTURES / "knobs_clean.py")
    assert replint_main([clean, "--baseline", ""]) == 0


def test_driver_sarif_flag(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    assert replint_main(["--json", "--sarif"]) == 2
    capsys.readouterr()
    bad = str(FIXTURES / "knobs_bad.py")
    assert replint_main([bad, "--sarif", "--baseline", ""]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["version"] == "2.1.0"
    assert len(payload["runs"][0]["results"]) == 3


def test_changed_only_resolves_merge_base(tmp_path):
    import subprocess

    def git(*argv):
        subprocess.run(["git", "-c", "user.name=t", "-c",
                        "user.email=t@t", *argv], cwd=tmp_path,
                       check=True, capture_output=True)

    git("init", "-q", "-b", "main")
    (tmp_path / "a.py").write_text("A = 1\n")
    git("add", "a.py")
    git("commit", "-qm", "base")
    # no origin/main yet: merge-base resolution silently contributes
    # nothing (fresh clone / detached CI checkout)
    assert _merge_base_files(tmp_path) == []
    # mark the current tip as origin/main, then commit past it
    git("update-ref", "refs/remotes/origin/main", "HEAD")
    (tmp_path / "b.py").write_text("B = 2\n")
    git("add", "b.py")
    git("commit", "-qm", "feature")
    assert _merge_base_files(tmp_path) == ["b.py"]
    # the --changed-only set is the union: committed-since-merge-base
    # plus the dirty worktree
    from repro.analysis.__main__ import _changed_files
    (tmp_path / "c.py").write_text("C = 3\n")
    changed = {p.name for p in _changed_files(tmp_path)}
    assert changed == {"b.py", "c.py"}


def test_driver_rule_selection(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    bad = str(FIXTURES / "alloc_bad.py")
    # selecting an unrelated rule sees no findings in this fixture
    assert replint_main([bad, "--rules", "pallas-contract",
                         "--baseline", ""]) == 0
    assert replint_main([bad, "--rules", "allocator-discipline",
                         "--baseline", ""]) == 1


# ---------------------------------------------------------------------------
# the live tree is clean (the `make lint` gate, in-process)
# ---------------------------------------------------------------------------
def test_live_tree_is_clean_under_checked_in_baseline(capsys, monkeypatch):
    monkeypatch.chdir(ROOT)
    assert replint_main(["src/repro"]) == 0


# ---------------------------------------------------------------------------
# regression: the structured errors this PR introduced at real raise sites
# ---------------------------------------------------------------------------
def test_config_errors_keep_valueerror_compatibility():
    # double inheritance: new structured types remain catchable by the
    # builtin supertypes pre-existing callers except on
    assert issubclass(EngineConfigError, ValueError)
    assert issubclass(EngineConfigError, EngineError)
    assert issubclass(UnsupportedFeature, NotImplementedError)
    assert issubclass(UnsupportedFeature, EngineError)
    assert issubclass(DistributedSetupError, RuntimeError)
    assert issubclass(DistributedSetupError, EngineError)


def test_unknown_backend_is_structured():
    from repro.kernels import resolve_backend
    with pytest.raises(EngineConfigError, match="backend must be one of"):
        resolve_backend("cuda-graphs")
    try:
        resolve_backend("cuda-graphs")
    except EngineConfigError as e:
        assert e.context["backend"] == "cuda-graphs"


def test_unknown_combine_mode_is_structured():
    from repro.kernels.paged_attention.paged_attention import \
        resolve_combine_mode
    with pytest.raises(EngineConfigError, match="combine_mode"):
        resolve_combine_mode("fused", 2)


def test_unknown_family_is_structured():
    import dataclasses

    from repro.configs import get_smoke
    from repro.models.api import build_model
    cfg = dataclasses.replace(get_smoke("llama2-7b"), family="mamba")
    with pytest.raises(EngineConfigError, match="unknown family"):
        build_model(cfg)


def test_recurrent_chunked_prefill_is_structured():
    from repro.configs import get_smoke
    from repro.serving.engine import Engine
    cfg = get_smoke("recurrentgemma-9b")
    with pytest.raises(EngineConfigError, match="recurrent"):
        Engine(cfg, max_slots=2, max_seq_len=64, prefill_chunk=8)


def test_fault_plan_errors_are_structured():
    from repro.serving.faults import FaultPlan, FaultRule
    with pytest.raises(EngineConfigError, match="unknown fault site"):
        FaultPlan([FaultRule(site="warp", kind="nan")])
    try:
        FaultPlan([FaultRule(site="warp", kind="nan")])
    except EngineConfigError as e:
        assert e.context["site"] == "warp"
