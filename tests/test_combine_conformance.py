"""Differential conformance suite for the fused split-K combine (kernel v3).

The gate for shipping the two-kernel decode pipeline: the Pallas combine
kernel must match `ref.combine_partials_ref` within 1e-5 across the full
ppb × splits × {window, softcap, int8 kv_scale, GQA} sweep — including
partitions whose last split is entirely ragged padding blocks — and the
end-to-end pallas-combined decode must match the split-K partials oracle
(`ref.paged_attention_partials_ref` + ref combine).

The end-to-end gates run per *backend*: the TPU decode kernel and the
GPU/Triton decode kernel feed the identical combine (the combine kernel
and both oracles are backend-independent and unchanged), so one
conformance bar covers both lowerings — interpret mode off the target
hardware, compiled on real TPUs/GPUs.

Property-based tests (hypothesis; `tests/_hypothesis_stub.py` when the
real package is absent) pin the combine *algebra*: permutation
invariance over splits, associativity of pairwise merges, all-dead-split
handling (l == 0), and agreement with a single-split run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.kernels.paged_attention.paged_attention import (
    COMBINE_DIM_SEMANTICS, DECODE_DIM_SEMANTICS, NEG_INF,
    _combine_partials_jnp, combine_partials, combine_partials_pallas,
    resolve_combine_mode)
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import (
    combine_partials_ref, paged_attention_partials_ref)

from conftest import assert_close
from test_kernels_paged import BACKENDS, make_case

TOL = 1e-5  # acceptance bar: bit-for-bit within tolerance


# ---------------------------------------------------------------------------
# case builders — every attention variant the kernel supports, with ragged
# lens so the last split covers padding blocks and seq 1 leaves whole
# splits dead
# ---------------------------------------------------------------------------
VARIANTS = ["plain", "gqa", "mqa", "window", "softcap", "int8"]


def _conformance_case(rng, variant):
    page = 8
    if variant == "window":
        window, mp = 20, -(-20 // page) + 1  # bounded ring cache
        B, H, Hkv, D = 2, 8, 4, 32
        num_pages = B * mp
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (num_pages, page, Hkv, D))
        vp = jax.random.normal(ks[2], (num_pages, page, Hkv, D))
        tables = jnp.arange(num_pages, dtype=jnp.int32).reshape(B, mp)
        lens = jnp.asarray([65, 9], jnp.int32)
        return q, kp, vp, tables, lens, dict(window=window)
    shapes = {  # B, H, Hkv, D — GQA ratios per the acceptance sweep
        "plain": (2, 8, 8, 32),   # MHA
        "gqa": (2, 8, 2, 32),     # 4:1
        "mqa": (2, 8, 1, 64),     # 8:1
        "softcap": (2, 8, 4, 32),
        "int8": (2, 8, 4, 32),
    }
    B, H, Hkv, D = shapes[variant]
    # ragged: seq 0 fills 9 pages minus a partial tail; seq 1 leaves every
    # later split's whole page range dead
    q, kp, vp, tables, lens = make_case(rng, B, H, Hkv, D, page, 9, [65, 9])
    if variant == "softcap":
        return q, kp, vp, tables, lens, dict(softcap=30.0)
    if variant == "int8":
        scale = 0.035
        kp8 = jnp.clip(jnp.round(kp / scale), -127, 127).astype(jnp.int8)
        vp8 = jnp.clip(jnp.round(vp / scale), -127, 127).astype(jnp.int8)
        return q, kp8, vp8, tables, lens, dict(kv_scale=scale)
    return q, kp, vp, tables, lens, {}


def _flat_heads(m):
    """(B, Hkv, S, G) partials → the flat (B, H) head layout ref uses."""
    B, Hkv, _, G = m.shape
    return B, Hkv * G


# ---------------------------------------------------------------------------
# differential sweep: Pallas combine vs ref.combine_partials_ref
# ---------------------------------------------------------------------------
PPB_SPLITS = [(ppb, ns) for ppb in (1, 2, 4) for ns in (2, 3, 4)]


@pytest.mark.parametrize("ppb,ns", PPB_SPLITS)
@pytest.mark.parametrize("variant", VARIANTS)
def test_pallas_combine_matches_ref(rng, ppb, ns, variant):
    """The acceptance gate: kernel combine == oracle combine <= 1e-5 across
    the full ppb × splits × variant sweep (ragged last splits included)."""
    q, kp, vp, tables, lens, kw = _conformance_case(rng, variant)
    m, l, acc = paged_attention_partials_ref(
        q, kp, vp, tables, lens, num_splits=ns, pages_per_block=ppb, **kw)
    B, H = _flat_heads(m)
    out = combine_partials_pallas(m, l, acc).reshape(B, H, -1)
    ref = combine_partials_ref(m, l, acc)
    assert float(jnp.max(jnp.abs(out - ref))) <= TOL


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("ppb,ns", [(2, 3), (4, 2)])
@pytest.mark.parametrize("variant", VARIANTS)
def test_end_to_end_pallas_combine_matches_oracle(rng, ppb, ns, variant,
                                                  backend):
    """Full two-kernel pipeline (decode partials + fused combine) vs the
    split-K oracle pair, end to end — per decode backend, one oracle."""
    q, kp, vp, tables, lens, kw = _conformance_case(rng, variant)
    out = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                          interpret=True, pages_per_block=ppb,
                          num_splits=ns, combine_mode="pallas",
                          backend=backend, **kw)
    m, l, acc = paged_attention_partials_ref(
        q, kp, vp, tables, lens, num_splits=ns, pages_per_block=ppb, **kw)
    ref = combine_partials_ref(m, l, acc)
    assert float(jnp.max(jnp.abs(out - ref))) <= TOL


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("ppb,ns", [(1, 2), (2, 4)])
def test_combine_modes_agree_end_to_end(rng, ppb, ns, backend):
    """jnp-epilogue and fused-kernel decodes are interchangeable."""
    q, kp, vp, tables, lens, _ = _conformance_case(rng, "gqa")
    o_jnp = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                            interpret=True, pages_per_block=ppb,
                            num_splits=ns, combine_mode="jnp",
                            backend=backend)
    o_pal = paged_attention(q, kp, vp, tables, lens, impl="pallas",
                            interpret=True, pages_per_block=ppb,
                            num_splits=ns, combine_mode="pallas",
                            backend=backend)
    assert float(jnp.max(jnp.abs(o_jnp - o_pal))) <= TOL


def test_megacore_dimension_semantics():
    """(batch, kv_head, split) are parallel; only the scratch-accumulating
    block axis is sequential.  The combine grid is fully parallel."""
    assert DECODE_DIM_SEMANTICS == ("parallel", "parallel", "parallel",
                                    "arbitrary")
    assert COMBINE_DIM_SEMANTICS == ("parallel", "parallel")


def test_resolve_combine_mode():
    assert resolve_combine_mode(None, 1) == "jnp"
    assert resolve_combine_mode(None, 4) == "pallas"
    assert resolve_combine_mode("auto", 8) == "pallas"
    assert resolve_combine_mode("jnp", 8) == "jnp"
    assert resolve_combine_mode("pallas", 1) == "pallas"
    with pytest.raises(ValueError):
        resolve_combine_mode("triton", 2)


# ---------------------------------------------------------------------------
# property-based algebra tests (hypothesis / deterministic stub)
# ---------------------------------------------------------------------------
def _random_partials(seed, B, Hkv, S, G, D, dead_splits=()):
    """Plausible split-K partials: m ~ N(0,1)·sqrt(D), l > 0, acc free;
    listed splits are dead ((NEG_INF, 0, 0) — the kernel's empty-partition
    contract)."""
    r = np.random.RandomState(seed)
    m = r.randn(B, Hkv, S, G).astype(np.float32) * np.sqrt(D)
    l = np.abs(r.randn(B, Hkv, S, G)).astype(np.float32) + 0.1
    acc = r.randn(B, Hkv, S, G, D).astype(np.float32)
    for s in dead_splits:
        m[:, :, s] = NEG_INF
        l[:, :, s] = 0.0
        acc[:, :, s] = 0.0
    return jnp.asarray(m), jnp.asarray(l), jnp.asarray(acc)


def _merge2(a, b):
    """Pairwise stable merge of two partials — the associativity witness."""
    m1, l1, a1 = a
    m2, l2, a2 = b
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.integers(2, 6),
       G=st.integers(1, 4), rnd=st.randoms())
def test_combine_permutation_invariant(seed, S, G, rnd):
    """Split order is an implementation detail of the grid walk — any
    permutation of the split axis must combine to the same output."""
    m, l, acc = _random_partials(seed, 2, 2, S, G, 8, dead_splits=(S - 1,))
    perm = list(range(S))
    rnd.shuffle(perm)
    p = jnp.asarray(perm)
    base = combine_partials_pallas(m, l, acc)
    shuf = combine_partials_pallas(m[:, :, p], l[:, :, p], acc[:, :, p])
    assert_close(base, shuf, rtol=TOL, atol=TOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.integers(3, 6))
def test_pairwise_merge_associative(seed, S):
    """Left-fold, right-fold and one-shot combines agree: the merge is
    associative, so megacore may reduce splits in any tree shape."""
    m, l, acc = _random_partials(seed, 1, 2, S, 2, 8)
    parts = [(m[:, :, s], l[:, :, s], acc[:, :, s]) for s in range(S)]
    left = parts[0]
    for p in parts[1:]:
        left = _merge2(left, p)
    right = parts[-1]
    for p in reversed(parts[:-1]):
        right = _merge2(p, right)
    o_left = left[2] / jnp.maximum(left[1], 1e-30)[..., None]
    o_right = right[2] / jnp.maximum(right[1], 1e-30)[..., None]
    assert_close(o_left, o_right, rtol=TOL, atol=TOL)
    one_shot = combine_partials_pallas(m, l, acc)
    assert_close(one_shot, o_left, rtol=TOL, atol=TOL)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), S=st.integers(1, 5))
def test_all_dead_splits_yield_zero(seed, S):
    """A (b, h, g) slot whose every split is dead (l == 0) is a masked row:
    exact zeros, never NaN — in both combine implementations."""
    m, l, acc = _random_partials(seed, 2, 2, S, 2, 8,
                                 dead_splits=tuple(range(S)))
    for out in (combine_partials_pallas(m, l, acc),
                _combine_partials_jnp(m, l, acc)):
        a = np.asarray(out)
        assert not np.isnan(a).any()
        assert np.abs(a).max() == 0.0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), ns=st.integers(2, 5),
       ppb=st.sampled_from([1, 2, 4]))
def test_split_run_agrees_with_single_split(seed, ns, ppb):
    """Combining ns-way partials of a real attention case reproduces the
    single-split (no split-K) result."""
    rng = jax.random.PRNGKey(seed)
    q, kp, vp, tables, lens = make_case(rng, 2, 4, 2, 16, 8, 6, [41, 3])
    m1, l1, a1 = paged_attention_partials_ref(
        q, kp, vp, tables, lens, num_splits=1, pages_per_block=ppb)
    mn, ln, an = paged_attention_partials_ref(
        q, kp, vp, tables, lens, num_splits=ns, pages_per_block=ppb)
    single = combine_partials_pallas(m1, l1, a1)
    multi = combine_partials_pallas(mn, ln, an)
    assert_close(single, multi, rtol=TOL, atol=TOL)


def test_combine_dispatcher_auto():
    """combine_partials(None) routes by split count and both routes agree."""
    m, l, acc = _random_partials(0, 2, 2, 4, 2, 8)
    auto = combine_partials(m, l, acc)  # S=4 → pallas
    assert_close(auto, _combine_partials_jnp(m, l, acc), rtol=TOL, atol=TOL)
    m1, l1, a1 = _random_partials(1, 2, 2, 1, 2, 8)
    assert_close(combine_partials(m1, l1, a1),
                 combine_partials_pallas(m1, l1, a1), rtol=TOL, atol=TOL)
