"""Minimal stand-in for `hypothesis` when it isn't installed.

The container image doesn't ship hypothesis; without it the two
property-test modules fail at *collection* and take the whole tier-1 run
down with them.  This stub implements just the surface those modules use
(`given`, `settings`, `strategies.{integers,sampled_from,lists,tuples,
randoms}`) as deterministic random sampling: each `@given` test runs
``max_examples`` drawn examples from a fixed seed.  No shrinking, no
database — if an example fails, the raw failing inputs are in the
traceback.  Installed into ``sys.modules`` by conftest only when the real
package is missing, so environments with hypothesis are unaffected.
"""

from __future__ import annotations

import random
import sys
import types
from typing import Any, Callable, List

_SEED = 0x5EED
_DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample: Callable[[random.Random], Any]):
        self._sample = sample

    def sample(self, rnd: random.Random) -> Any:
        return self._sample(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda r: r.choice(elements))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    return _Strategy(lambda r: [elements.sample(r)
                                for _ in range(r.randint(min_size, max_size))])


def tuples(*strategies: _Strategy) -> _Strategy:
    return _Strategy(lambda r: tuple(s.sample(r) for s in strategies))


def randoms() -> _Strategy:
    return _Strategy(lambda r: random.Random(r.randint(0, 2**31 - 1)))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored) -> Callable:
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy) -> Callable:
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(_SEED)
            for _ in range(n):
                args = [s.sample(rnd) for s in arg_strategies]
                kwargs = {k: s.sample(rnd) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)

        # plain __name__/__doc__ copy on purpose: functools.wraps would set
        # __wrapped__ and pytest would then demand fixtures for the
        # strategy-bound parameters of the original signature.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


def _install() -> None:
    if "hypothesis" in sys.modules:  # pragma: no cover — real package wins
        return
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "lists", "tuples", "randoms"):
        setattr(strat, name, globals()[name])
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
