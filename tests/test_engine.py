"""Serving-engine integration tests: continuous batching over paged KV.

Covers the paper's system claims: paged == contiguous outputs (C1),
oversubscription + preemption correctness, <5% memory overhead (objective
§I-B), scheduler fairness, and mixed-length batches (§IV scenario b).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.serving import Engine, Request, Status
from repro.serving.scheduler import Scheduler
from repro.core.paging import HostPageManager


def make_engine(arch="llama2-7b", **kw):
    cfg = get_smoke(arch)
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_seq_len", 64)
    return Engine(cfg, **kw)


def test_paged_equals_contiguous_generation():
    cfg = get_smoke("llama2-7b")
    e1 = Engine(cfg, max_slots=2, max_seq_len=64, rng=jax.random.PRNGKey(7))
    e2 = Engine(cfg, params=e1.params, paged=False, max_slots=2,
                max_seq_len=64, rng=jax.random.PRNGKey(7))
    prompts = [[1, 2, 3, 4, 5, 6, 7], [11, 12, 13]]
    r1 = [Request(prompt=list(p), max_new_tokens=10) for p in prompts]
    r2 = [Request(prompt=list(p), max_new_tokens=10) for p in prompts]
    e1.generate(r1)
    e2.generate(r2)
    for a, b in zip(r1, r2):
        assert a.output == b.output


def test_oversubscribed_pool_preempts_and_recovers():
    eng = make_engine(pool_tokens=128)  # 4 slots x 64 would need 256
    reqs = [Request(prompt=[1] * 40, max_new_tokens=8) for _ in range(4)]
    eng.generate(reqs, max_steps=400)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 8 for r in reqs)
    assert eng.scheduler.preempted >= 1  # pressure actually happened
    assert eng.mgr.used_pages == 0  # everything reclaimed


def test_preempted_request_output_is_unchanged():
    """Preemption must be output-transparent (recompute path)."""
    cfg = get_smoke("llama2-7b")
    key = jax.random.PRNGKey(3)
    roomy = Engine(cfg, max_slots=4, max_seq_len=64, rng=key)
    tight = Engine(cfg, params=roomy.params, max_slots=4, max_seq_len=64,
                   pool_tokens=96, rng=key)
    mk = lambda: [Request(prompt=[7] * (20 + 5 * i), max_new_tokens=6)
                  for i in range(4)]
    a, b = mk(), mk()
    roomy.generate(a)
    tight.generate(b, max_steps=500)
    assert tight.scheduler.preempted >= 1
    for ra, rb in zip(a, b):
        assert ra.output == rb.output


def test_memory_overhead_objective():
    """<5% overhead vs theoretical minimum while serving (paper §I-B)."""
    eng = make_engine(max_slots=4, max_seq_len=256)
    reqs = [Request(prompt=[1] * n, max_new_tokens=4)
            for n in (100, 150, 200, 220)]
    for r in reqs:
        eng.add_request(r)
    eng.step()  # admit + prefill
    rep = eng.memory_report()
    assert rep["overhead_frac"] < 0.05
    # the contiguous baseline for the same batch wastes >50%
    base = Engine(eng.cfg, params=eng.params, paged=False, max_slots=4,
                  max_seq_len=256)
    for r in [Request(prompt=[1] * n, max_new_tokens=4)
              for n in (100, 150, 200, 220)]:
        base.add_request(r)
    base.step()
    assert base.memory_report()["overhead_frac"] > 0.5


def test_memory_report_uses_pool_dtype():
    """int8 pools must be accounted at their own itemsize: sizing them by
    the f32 activation dtype overstated pool_bytes/reserved_bytes 4× and
    skewed the paper's <5% overhead metric."""
    import dataclasses

    import jax.numpy as jnp

    cfg = get_smoke("llama2-7b")
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    eng = Engine(cfg, max_slots=2, max_seq_len=64)
    eng8 = Engine(cfg8, max_slots=2, max_seq_len=64)
    assert eng8.state["k_pages"].dtype == jnp.int8
    for e in (eng, eng8):
        e.add_request(Request(prompt=[1] * 20, max_new_tokens=4))
        e.step()
    rep, rep8 = eng.memory_report(), eng8.memory_report()
    ratio = jnp.dtype(eng.dtype).itemsize  # f32 pools vs 1-byte int8 pools
    assert rep8["pool_bytes"] * ratio == rep["pool_bytes"]
    assert rep8["reserved_bytes"] * ratio == rep["reserved_bytes"]
    assert rep8["theoretical_min_bytes"] * ratio == rep["theoretical_min_bytes"]
    # the ratio metric is itemsize-invariant once accounting is consistent
    assert abs(rep8["overhead_frac"] - rep["overhead_frac"]) < 1e-9


def test_ttft_and_throughput_metrics():
    eng = make_engine()
    reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5)]
    eng.generate(reqs)
    m = reqs[0].metrics
    assert m["ttft_s"] > 0 and m["tok_s"] > 0


def test_eos_stops_generation():
    eng = make_engine()
    # eos_id impossible (vocab) vs guaranteed: use a token the model will
    # emit by forcing max_new_tokens large and eos from the first sample
    r = Request(prompt=[1, 2, 3], max_new_tokens=40)
    eng.generate([r])
    eos = r.output[0]
    r2 = Request(prompt=[1, 2, 3], max_new_tokens=40, eos_id=eos)
    eng2 = Engine(eng.cfg, params=eng.params, max_slots=4, max_seq_len=64)
    eng2.generate([r2])
    assert len(r2.output) == 1 and r2.output[0] == eos


def test_many_waves_through_few_slots():
    """More requests than slots: continuous batching drains the queue."""
    eng = make_engine(max_slots=2)
    reqs = [Request(prompt=[i + 1] * (5 + i), max_new_tokens=4)
            for i in range(7)]
    eng.generate(reqs, max_steps=500)
    assert all(r.done for r in reqs)
    assert eng.mgr.used_pages == 0


def test_engine_fuzz_random_waves():
    """Property: any mix of request lengths/budgets completes under an
    oversubscribed pool, and every page is reclaimed afterwards."""
    import numpy as np
    cfg = get_smoke("llama2-7b")
    eng = Engine(cfg, max_slots=3, max_seq_len=96, pool_tokens=192)
    rng = np.random.default_rng(42)
    reqs = []
    for wave in range(3):
        wave_reqs = [
            Request(prompt=[int(x) for x in
                            rng.integers(1, 200, size=rng.integers(1, 80))],
                    max_new_tokens=int(rng.integers(1, 10)),
                    temperature=float(rng.choice([0.0, 1.0])))
            for _ in range(4)
        ]
        reqs += wave_reqs
        eng.generate(wave_reqs, max_steps=800)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    assert eng.mgr.used_pages == 0
    assert not eng.scheduler.running and not eng.scheduler.waiting
    # refcounts all zero, free list complete
    assert sorted(eng.mgr.free_list) == list(range(eng.num_pages))
    assert all(c == 0 for c in eng.mgr.refcount)


def test_fork_prefix_sharing_is_exact_and_copy_on_write():
    """Paper §III contribution 1: fork aliases full pages (no recompute,
    no copy) and the forked branch produces exactly what a fresh request
    with the same prefix would."""
    cfg = get_smoke("llama2-7b")
    key = jax.random.PRNGKey(11)
    eng = Engine(cfg, max_slots=3, max_seq_len=96, rng=key)
    parent = Request(prompt=[5] * 20, max_new_tokens=24)
    eng.add_request(parent)
    # run until the parent has generated half its budget
    while len(parent.output) < 12:
        eng.step()
    pages_before = eng.mgr.used_pages
    child = eng.fork_request(parent, max_new_tokens=6)
    # alias accounting: at most one fresh (tail) page was allocated
    assert eng.mgr.used_pages - pages_before <= 1
    seq_at_fork = list(child.prompt)
    while not child.done:
        eng.step()
    # reference: a fresh engine continuing the same prefix greedily
    ref_eng = Engine(cfg, params=eng.params, max_slots=1, max_seq_len=96)
    ref = Request(prompt=seq_at_fork, max_new_tokens=6)
    ref_eng.generate([ref])
    assert child.output == ref.output
    # parent unaffected and still correct
    while not parent.done:
        eng.step()
    ref2 = Request(prompt=[5] * 20, max_new_tokens=24)
    ref_eng2 = Engine(cfg, params=eng.params, max_slots=1, max_seq_len=96)
    ref_eng2.generate([ref2])
    assert parent.output == ref2.output


def test_tables_array_refuses_silent_truncation():
    """ISSUE 5 satellite: a sequence whose page row outgrows the device
    table width must be a hard error.  The former code silently did
    ``row[:pages_per_seq]`` — the sequence attended over a dropped KV
    tail and produced wrong output with no signal."""
    eng = make_engine(max_slots=2, max_seq_len=32)  # pages_per_seq = 4
    req = Request(prompt=[1] * 10, max_new_tokens=4)
    eng.add_request(req)
    eng.step()
    # force the host row past the device table width (the overflow a
    # mis-sized fork or an unchecked extend would produce)
    assert eng.mgr.reserve(req.rid, eng.max_seq_len + 1)
    with pytest.raises(RuntimeError, match="refusing to truncate"):
        eng._tables_array()


def test_tables_array_ring_models_still_truncate_by_design():
    """Windowed models are the sanctioned exception: their row is a ring
    and row[:ring] IS the device table (slots overwritten in place)."""
    cfg = get_smoke("llama2-7b").replace(layer_pattern="W", window=16)
    eng = Engine(cfg, max_slots=2, max_seq_len=64)
    assert eng.pages_per_seq == 3  # ceil(16/8) + 1
    req = Request(prompt=[1] * 30, max_new_tokens=4)
    eng.add_request(req)
    eng.step()  # host row is 4 pages > ring 3 — must NOT raise
    t = eng._tables_array()
    assert (t[req.slot, 0] >= 0).all()


def test_fork_exceeding_max_seq_len_raises():
    """The overflow path that used to reach the silent truncation: a fork
    whose child would outgrow max_seq_len mid-decode."""
    eng = make_engine(max_slots=3, max_seq_len=32)
    parent = Request(prompt=[1] * 20, max_new_tokens=4)
    eng.add_request(parent)
    eng.step()
    with pytest.raises(ValueError, match="max_seq_len"):
        eng.fork_request(parent, max_new_tokens=32)


# ---------------------------------------------------------------------------
# scheduler unit tests
# ---------------------------------------------------------------------------
def test_scheduler_fifo_admission():
    mgr = HostPageManager(num_pages=8, page_size=8)
    sch = Scheduler(mgr, max_slots=2, max_seq_len=64)
    r1 = Request(prompt=[0] * 30)   # 4 pages + 1 headroom
    r2 = Request(prompt=[0] * 30)
    r3 = Request(prompt=[0] * 8)
    for r in (r1, r2, r3):
        sch.add(r)
    admitted = sch.admit()
    # r1 fits (5), r2 doesn't (only 3 pages left) and BLOCKS r3 (FIFO)
    assert [r.rid for _, r in admitted] == [r1.rid]
    assert r2.status == Status.WAITING and r3.status == Status.WAITING


def test_scheduler_preempts_youngest():
    mgr = HostPageManager(num_pages=4, page_size=8)
    sch = Scheduler(mgr, max_slots=2, max_seq_len=64, headroom_pages=0)
    r1 = Request(prompt=[0] * 16)  # 2 pages
    r2 = Request(prompt=[0] * 16)  # 2 pages
    sch.add(r1)
    sch.add(r2)
    assert len(sch.admit()) == 2
    # both full; extending forces preemption of the youngest (r2)
    victims = sch.extend_for_decode()
    assert [v.rid for v in victims] == [r2.rid]
    assert r2.status == Status.PREEMPTED
    assert r1.status == Status.RUNNING
    assert sch.waiting[0] is r2  # re-queued at the front


def test_pallas_decode_engine_matches_ref_engine():
    """The serving decode path with the blocked/split-K Pallas kernel
    (explicit knobs) generates the same tokens as the jnp-oracle engine."""
    from repro.configs import get_smoke

    cfg = get_smoke("llama2-7b")
    outs = []
    for kw in (dict(impl="ref"),
               dict(impl="pallas", pages_per_block=2, num_splits=2)):
        eng = Engine(cfg, max_slots=2, max_seq_len=64,
                     rng=jax.random.PRNGKey(3), **kw)
        req = Request(prompt=[7, 11, 13] * 4, max_new_tokens=8,
                      temperature=0.0)
        eng.generate([req])
        outs.append(list(req.output))
    assert outs[0] == outs[1]
