"""Fault-tolerant serving (ISSUE 6): error taxonomy, per-request fault
isolation, cancellation in every lifecycle state, admission backpressure,
deadlines, deterministic fault injection, and the chaos soak.

The acceptance contract this suite gates:

  * a seeded ``FaultPlan`` injecting NaN logits / allocation failure into
    one request of a mixed prefill+decode batch fails THAT request, its
    pages return to the free list, and the surviving requests' token
    streams are bit-identical to the same schedule without injection;
  * ``Engine.cancel_request`` safely tears a request down in every state
    (WAITING, PREFILLING mid-chunk, RUNNING, PREEMPTED, stalled on a dry
    pool) — no ghost table row reaches the next decode sub-batch;
  * the chaos soak runs 300+ steps of random admit/cancel/fail/preempt/
    stall under injected faults with the allocator invariants asserted
    after every step and no unstructured exception escaping
    ``Engine.step()``.

Run via ``make test-faults`` (CI leg ``faults``).
"""

import random

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.paging import HostPageManager
from repro.errors import (Backpressure, DeadlineExceeded, EngineError,
                          InternalError, InvalidRequest, NumericsError,
                          PoolExhausted, RequestTooLong,
                          SchedulerInvariantError, TransientDeviceError)
from repro.serving import Engine, Request, Status
from repro.serving.faults import FaultPlan, FaultRule, FaultyPageManager
from repro.serving.scheduler import LIVE, Scheduler

from test_scheduler_preempt import check_allocator_invariants

SOAK_SEED = 0xFA57  # pinned: `make test-faults` must replay exactly


@pytest.fixture(scope="module")
def donor():
    """Shared params donor (model init dominates per-test cost)."""
    cfg = get_smoke("llama2-7b")
    eng = Engine(cfg, max_slots=1, max_seq_len=16)
    return cfg, eng.params


# ---------------------------------------------------------------------------
# taxonomy + allocator hardening
# ---------------------------------------------------------------------------
def test_taxonomy_refines_builtin_exceptions():
    """The structured hierarchy must not break legacy `except` clauses."""
    assert issubclass(InvalidRequest, ValueError)
    assert issubclass(RequestTooLong, InvalidRequest)
    assert issubclass(PoolExhausted, RuntimeError)
    assert issubclass(SchedulerInvariantError, RuntimeError)
    assert issubclass(InternalError, RuntimeError)
    for cls in (InvalidRequest, RequestTooLong, PoolExhausted,
                NumericsError, SchedulerInvariantError, DeadlineExceeded,
                TransientDeviceError, InternalError, Backpressure):
        assert issubclass(cls, EngineError)
    err = PoolExhausted("dry", rid=7, resource="pages")
    assert err.rid == 7 and err.context["resource"] == "pages"
    assert "rid=7" in str(err)


def test_free_unknown_rid_raises():
    """Satellite: freeing a rid with no table row must raise, not silently
    no-op (the old `tables.pop(rid, [])` hid scheduler double-frees)."""
    mgr = HostPageManager(num_pages=4, page_size=4)
    with pytest.raises(SchedulerInvariantError, match="unknown rid"):
        mgr.free(7)
    # a full free cycle, then a second free of the same rid: caught
    assert mgr.reserve(0, 6)
    mgr.free(0)
    with pytest.raises(SchedulerInvariantError):
        mgr.free(0)
    assert sorted(mgr.free_list) == list(range(4))  # no corruption


def test_double_free_of_page_detected():
    """Satellite: a page freed while its refcount is already 0 is the
    free-list-corruption signature (the page would be handed out twice) —
    must raise instead of pushing the duplicate."""
    mgr = HostPageManager(num_pages=4, page_size=4)
    assert mgr.reserve(0, 6)
    stale_row = list(mgr.tables[0])
    mgr.free(0)
    # forge the stale row back (what a buggy scheduler would do): its
    # pages are on the free list at refcount 0, so freeing must trip
    mgr.tables[1] = stale_row
    mgr.lens[1] = 6
    with pytest.raises(SchedulerInvariantError, match="double free"):
        mgr.free(1)


@pytest.mark.parametrize("kw", [
    dict(temperature=-0.5),
    dict(temperature=float("nan")),
    dict(top_p=1.5),
    dict(top_p=-0.1),
    dict(top_p=float("nan")),
    dict(top_k=-1),
    dict(max_new_tokens=0),
])
def test_invalid_sample_params_rejected_at_add(donor, kw):
    """Satellite: malformed sampling knobs raise a structured
    InvalidRequest at add_request time instead of NaN-ing downstream."""
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=32)
    req = Request(prompt=[1, 2, 3], **kw)
    with pytest.raises(InvalidRequest):
        eng.add_request(req)
    assert not eng.scheduler.waiting, "rejected request must hold nothing"
    # still a ValueError for legacy callers
    with pytest.raises(ValueError):
        eng.add_request(Request(prompt=[1], **kw))


def test_request_too_long_is_structured(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=16)
    with pytest.raises(RequestTooLong, match="max_seq_len"):
        eng.add_request(Request(prompt=[1] * 12, max_new_tokens=8))


# ---------------------------------------------------------------------------
# backpressure + deadlines
# ---------------------------------------------------------------------------
def test_bounded_queue_sheds_with_retry_hint(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=1, max_seq_len=32,
                 max_waiting=2)
    eng.add_request(Request(prompt=[1] * 4, max_new_tokens=2))
    eng.add_request(Request(prompt=[2] * 4, max_new_tokens=2))
    with pytest.raises(Backpressure) as ei:
        eng.add_request(Request(prompt=[3] * 4, max_new_tokens=2))
    bp = ei.value
    assert bp.reason == "queue_full"
    assert bp.retry_after_steps >= 1
    assert bp.queue_depth == 2
    assert eng.scheduler.shed == 1
    assert eng.robustness_report()["shed"] == 1


def test_pool_watermark_sheds_instead_of_thrashing(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=4, max_seq_len=64,
                 pool_tokens=64, admit_watermark=0.5)
    eng.add_request(Request(prompt=[1] * 40, max_new_tokens=4))
    eng.step()  # admit + prefill: well past 50% of the 64-token pool
    util = eng.mgr.used_pages / eng.mgr.num_pages
    assert util >= 0.5
    with pytest.raises(Backpressure) as ei:
        eng.add_request(Request(prompt=[2] * 8, max_new_tokens=2))
    assert ei.value.reason == "pool_watermark"
    assert ei.value.pool_util == pytest.approx(util)
    assert ei.value.retry_after_steps >= 1
    # preemption pressure was never created: shedding happened at the door
    assert eng.scheduler.preempted == 0


def test_deadline_exceeded_fails_request_and_spares_batchmates(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=64)
    slow = Request(prompt=[1] * 4, max_new_tokens=40, deadline_steps=5)
    ok = Request(prompt=[2] * 4, max_new_tokens=8)
    eng.add_request(slow)
    eng.add_request(ok)
    for _ in range(40):
        if slow.done and ok.done:
            break
        eng.step()
    assert slow.status is Status.FAILED
    assert isinstance(slow.error, DeadlineExceeded)
    assert len(slow.output) < 40
    assert slow.rid not in eng.mgr.tables, "expired request must free pages"
    assert ok.status is Status.FINISHED and len(ok.output) == 8
    assert eng.robustness_report()["deadline_misses"] == 1


def test_ttft_deadline_cuts_stuck_prefill(donor):
    """A request that cannot produce its first token inside the TTFT
    budget (long chunked prefill) is failed; the short one finishes."""
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=128,
                 prefill_chunk=4)
    long_req = Request(prompt=[1] * 60, max_new_tokens=4,
                       ttft_deadline_steps=4)  # needs 15 chunks: hopeless
    short = Request(prompt=[2] * 4, max_new_tokens=6)
    eng.add_request(long_req)
    eng.add_request(short)
    for _ in range(60):
        if long_req.done and short.done:
            break
        eng.step()
    assert long_req.status is Status.FAILED
    assert isinstance(long_req.error, DeadlineExceeded)
    assert long_req.output == []
    assert long_req.rid not in eng.mgr.tables
    assert short.status is Status.FINISHED
    check_allocator_invariants(eng.mgr, eng.scheduler)


def test_step_returns_failed_requests(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=64)
    req = Request(prompt=[1] * 4, max_new_tokens=40, deadline_steps=2)
    eng.add_request(req)
    terminal = []
    for _ in range(6):
        terminal += eng.step()
        if req.done:
            break
    assert req in terminal, "step() must report deadline failures"


# ---------------------------------------------------------------------------
# cancellation in every lifecycle state (satellite)
# ---------------------------------------------------------------------------
def test_cancel_waiting_request(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=1, max_seq_len=32)
    first = Request(prompt=[1] * 4, max_new_tokens=6)
    queued = Request(prompt=[2] * 4, max_new_tokens=6)
    eng.add_request(first)
    eng.add_request(queued)
    eng.step()  # first admitted; queued still WAITING
    assert queued.status is Status.WAITING
    assert eng.cancel_request(queued.rid) is True
    assert queued.status is Status.CANCELLED and queued.done
    assert queued not in eng.scheduler.waiting
    assert queued.rid not in eng.mgr.tables
    # unknown/terminal rids: no-op, not an exception
    assert eng.cancel_request(queued.rid) is False
    assert eng.cancel_request(999_999) is False
    while not first.done:
        eng.step()
    assert first.status is Status.FINISHED
    assert eng.scheduler.cancelled == 1


def test_cancel_running_request_spares_batchmates(donor):
    """Cancelling mid-decode frees the slot+pages and leaves the
    co-batched requests' outputs bit-identical to an uncancelled run."""
    cfg, params = donor
    key = jax.random.PRNGKey(9)
    prompts = [[3 + i] * (4 + 2 * i) for i in range(3)]

    ref = Engine(cfg, params=params, max_slots=3, max_seq_len=64, rng=key)
    ref_reqs = [Request(prompt=list(p), max_new_tokens=10) for p in prompts]
    ref.generate(ref_reqs)

    eng = Engine(cfg, params=params, max_slots=3, max_seq_len=64, rng=key)
    reqs = [Request(prompt=list(p), max_new_tokens=10) for p in prompts]
    for r in reqs:
        eng.add_request(r)
    eng.step()
    eng.step()
    victim = reqs[1]
    assert victim.status is Status.RUNNING
    assert eng.cancel_request(victim.rid)
    assert victim.status is Status.CANCELLED
    assert victim.rid not in eng.mgr.tables
    check_allocator_invariants(eng.mgr, eng.scheduler)
    for _ in range(100):
        if all(r.done for r in reqs):
            break
        eng.step()
    for i in (0, 2):
        assert reqs[i].status is Status.FINISHED
        assert reqs[i].output == ref_reqs[i].output, (
            "cancellation must not disturb co-batched outputs")
    assert eng.mgr.used_pages == 0


def test_cancel_prefilling_mid_chunk_no_ghost_row(donor):
    """Cancel between two prefill chunks: pages released immediately and
    the next decode sub-batch carries no ghost table row for the slot."""
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=128,
                 prefill_chunk=4)
    long_req = Request(prompt=[1] * 40, max_new_tokens=4)
    short = Request(prompt=[2] * 4, max_new_tokens=12)
    eng.add_request(long_req)
    eng.add_request(short)
    for _ in range(8):
        eng.step()
        if (long_req.status is Status.PREFILLING and long_req.prefill_pos
                and short.status is Status.RUNNING):
            break
    assert long_req.status is Status.PREFILLING
    assert 0 < long_req.prefill_pos < long_req.total_len, "mid-chunk"
    slot = long_req.slot
    assert eng.cancel_request(long_req.rid)
    assert long_req.status is Status.CANCELLED
    assert long_req.rid not in eng.mgr.tables
    assert slot not in eng.scheduler.running
    # the decode-facing table row for the freed slot must be blank
    tables = np.asarray(eng._tables_array(decode=True))
    assert (tables[slot] == -1).all(), "ghost table row after cancel"
    check_allocator_invariants(eng.mgr, eng.scheduler)
    while not short.done:
        eng.step()
    assert short.status is Status.FINISHED
    assert len(short.output) == 12


def test_cancel_preempted_request(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=4, max_seq_len=64,
                 pool_tokens=96)  # oversubscribed: preemption guaranteed
    reqs = [Request(prompt=[1] * 40, max_new_tokens=24) for _ in range(4)]
    for r in reqs:
        eng.add_request(r)
    victim = None
    for _ in range(200):
        eng.step()
        victim = next((r for r in reqs
                       if r.status is Status.PREEMPTED), None)
        if victim is not None:
            break
    assert victim is not None, "pressure never preempted anyone"
    assert victim in eng.scheduler.waiting
    assert eng.cancel_request(victim.rid)
    assert victim.status is Status.CANCELLED
    assert victim not in eng.scheduler.waiting
    check_allocator_invariants(eng.mgr, eng.scheduler)
    for _ in range(300):
        if all(r.done for r in reqs):
            break
        eng.step()
    assert all(r.done for r in reqs)
    assert eng.mgr.used_pages == 0


def test_cancel_stalled_on_dry_pool_unblocks_peer(donor):
    """A prefill stalled on a dry pool is cancellable; cancelling the
    *decoding* page-holder instead frees the pages the stalled prefill
    was waiting on, so it resumes without recompute."""
    cfg, params = donor
    ps = cfg.page_size
    # pool == one max-length sequence (8 pages): a short decoder plus a
    # 6-page prompt cannot coexist, so b's third chunk must stall while
    # a (RUNNING) keeps the preemption path off (stall, don't preempt)
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=8 * ps,
                 pool_tokens=8 * ps, prefill_chunk=2 * ps)
    a = Request(prompt=[1] * (2 * ps), max_new_tokens=2 * ps)
    b = Request(prompt=[2] * (6 * ps), max_new_tokens=2)
    eng.add_request(a)
    eng.add_request(b)
    for _ in range(12):
        eng.step()
        if eng.scheduler.prefill_stalls:
            break
    assert eng.scheduler.prefill_stalls, "pool never ran dry mid-prefill"
    assert b.status is Status.PREFILLING
    assert 0 < b.prefill_pos < b.total_len, "stalled mid-prompt"
    assert eng.cancel_request(a.rid)  # free the decoder's pages
    check_allocator_invariants(eng.mgr, eng.scheduler)
    for _ in range(40):
        if b.done:
            break
        eng.step()
    assert b.status is Status.FINISHED, \
        "cancel must unblock the stalled prefill"
    assert len(b.output) == 2
    assert eng.mgr.used_pages == 0


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------
def test_fault_plan_validates_and_replays():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultPlan([FaultRule(site="warp", kind="nan")])
    with pytest.raises(ValueError, match="invalid at site"):
        FaultPlan([FaultRule(site="reserve", kind="nan")])

    def drive(plan):
        out = []
        for i in range(50):
            out.append(plan.fire("extend", rid=i % 3))
            out.append(plan.fire("decode"))
        return out

    rules = lambda: [FaultRule(site="extend", kind="alloc_fail", prob=0.3,
                               times=None),
                     FaultRule(site="decode", kind="transient", prob=0.2,
                               times=None)]
    a = drive(FaultPlan(rules(), seed=123))
    b = drive(FaultPlan(rules(), seed=123))
    c = drive(FaultPlan(rules(), seed=124))
    assert a == b, "same seed + schedule must replay identically"
    assert a != c
    assert any(a), "plan never fired at these probabilities"


def test_fault_rule_nth_and_rid_targeting():
    plan = FaultPlan([FaultRule(site="extend", kind="alloc_fail",
                                rid=5, nth=2)])
    mgr = FaultyPageManager(num_pages=8, page_size=4, plan=plan)
    assert mgr.reserve(5, 4)
    assert mgr.reserve(6, 4)
    assert mgr.extend(6, 1)   # other rid: rule not consulted
    assert mgr.extend(5, 1)   # victim's 1st extend: passes
    assert not mgr.extend(5, 1)  # 2nd: injected dry pool
    assert mgr.extend(5, 1)   # rule exhausted (times=1): recovers
    assert plan.log == [("extend", 5, "alloc_fail", 3)]
    # injected failure mutated nothing: lens reflects the two successes
    assert mgr.lens[5] == 6


def test_injected_free_fault_is_structured(donor):
    cfg, params = donor
    plan = FaultPlan([FaultRule(site="free", kind="error", nth=1)])
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=32,
                 faults=plan)
    req = Request(prompt=[1] * 4, max_new_tokens=4)
    eng.add_request(req)
    eng.step()
    with pytest.raises(SchedulerInvariantError, match="injected"):
        eng.cancel_request(req.rid)


# ---------------------------------------------------------------------------
# fault isolation: the acceptance proof
# ---------------------------------------------------------------------------
def _mixed_batch_engines(cfg, params, plan, key):
    """Two engines, same params/rng/schedule; one with the fault plan.
    Batch shape: three deciders + one long prompt mid-prefill (mixed
    prefill+decode continuous batching)."""
    mk = lambda: ([Request(prompt=[3 + i] * (4 + 2 * i), max_new_tokens=10)
                   for i in range(3)]
                  + [Request(prompt=[9] * 36, max_new_tokens=4)])
    clean_reqs, fault_reqs = mk(), mk()
    clean = Engine(cfg, params=params, max_slots=4, max_seq_len=64,
                   prefill_chunk=4, rng=key)
    faulty = Engine(cfg, params=params, max_slots=4, max_seq_len=64,
                    prefill_chunk=4, rng=key, faults=plan)
    return clean, clean_reqs, faulty, fault_reqs


def test_nan_injection_isolated_and_survivors_bit_identical(donor):
    """Acceptance: NaN logits injected into one decoding request of a
    mixed prefill+decode batch → that request FAILED (NumericsError),
    pages back on the free list, survivors' token streams bit-identical
    to the uninjected run."""
    cfg, params = donor
    key = jax.random.PRNGKey(21)
    plan_rules = [FaultRule(site="sample", kind="nan", nth=3)]
    clean, clean_reqs, faulty, fault_reqs = _mixed_batch_engines(
        cfg, params, FaultPlan(plan_rules), key)
    victim = fault_reqs[1]
    plan_rules[0].rid = victim.rid  # rule list is owned by the plan

    clean.generate(clean_reqs, max_steps=300)
    assert all(r.status is Status.FINISHED for r in clean_reqs)

    faulty.generate(fault_reqs, max_steps=300)
    assert victim.status is Status.FAILED
    assert isinstance(victim.error, NumericsError)
    assert victim.error.rid == victim.rid
    assert len(victim.output) == 2, "failed on its 3rd sample"
    assert victim.rid not in faulty.mgr.tables, "pages must be released"
    for i in (0, 2, 3):
        assert fault_reqs[i].status is Status.FINISHED
        assert fault_reqs[i].output == clean_reqs[i].output, (
            f"survivor {i} diverged from the uninjected run")
    assert faulty.mgr.used_pages == 0
    assert sorted(faulty.mgr.free_list) == list(range(faulty.num_pages))
    assert all(c == 0 for c in faulty.mgr.refcount)
    assert faulty.robustness_report()["failed"] == 1
    assert faulty.faults.log[0][:3] == ("sample", victim.rid, "nan")


def test_alloc_failure_injection_recovers_transparently(donor):
    """Acceptance (allocation-failure half): a forced extend failure on
    one request triggers the normal dry-pool recovery (preempt + replay)
    and every request's output still matches the uninjected run."""
    cfg, params = donor
    key = jax.random.PRNGKey(22)
    plan_rules = [FaultRule(site="extend", kind="alloc_fail", nth=2)]
    clean, clean_reqs, faulty, fault_reqs = _mixed_batch_engines(
        cfg, params, FaultPlan(plan_rules), key)
    victim = fault_reqs[0]
    plan_rules[0].rid = victim.rid

    clean.generate(clean_reqs, max_steps=300)
    faulty.generate(fault_reqs, max_steps=300)
    assert faulty.faults.fires == 1, "injection never hit"
    # graceful degradation: the injected dry pool preempted someone (and
    # recompute made it transparent) — nobody failed
    assert faulty.scheduler.preempted >= 1
    assert faulty.robustness_report()["failed"] == 0
    for rc, rf in zip(clean_reqs, fault_reqs):
        assert rf.status is Status.FINISHED
        assert rf.output == rc.output
    assert faulty.mgr.used_pages == 0


def test_transient_device_error_retried_to_identical_output(donor):
    cfg, params = donor
    key = jax.random.PRNGKey(23)
    clean = Engine(cfg, params=params, max_slots=2, max_seq_len=48, rng=key)
    c = Request(prompt=[5] * 6, max_new_tokens=8)
    clean.generate([c])

    plan = FaultPlan([FaultRule(site="decode", kind="transient", nth=3)])
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=48, rng=key,
                 faults=plan)
    r = Request(prompt=[5] * 6, max_new_tokens=8)
    eng.generate([r])
    assert eng.stats["transient_retries"] == 1
    assert r.status is Status.FINISHED
    assert r.output == c.output, "retried step must be transparent"


def test_transient_retries_exhaust_to_structured_error(donor):
    cfg, params = donor
    plan = FaultPlan([FaultRule(site="decode", kind="transient", prob=1.0,
                                times=None)])
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=48,
                 faults=plan, max_step_retries=2)
    req = Request(prompt=[5] * 6, max_new_tokens=4)
    eng.add_request(req)
    # monolithic prefill + decode share a step: the prefill lands the
    # first token, then the decode dispatch exhausts its retries
    with pytest.raises(TransientDeviceError):
        eng.step()
    assert eng.stats["transient_retries"] == 3  # 1 try + 2 retries
    assert len(req.output) == 1, "prefill's token must survive the fault"
    # the engine survives: clearing the (dispatch-site) plan lets the
    # same request finish untouched
    eng.faults = None
    for _ in range(10):
        if req.done:
            break
        eng.step()
    assert req.status is Status.FINISHED


def test_unstructured_step_failure_wrapped_as_internal_error(donor):
    cfg, params = donor
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=32)
    eng.add_request(Request(prompt=[1] * 4, max_new_tokens=4))
    boom = ValueError("boom")

    def exploding(*a, **k):
        raise boom

    eng._decode = exploding
    with pytest.raises(InternalError) as ei:
        eng.step()  # prefill lands; the decode call then explodes
    assert ei.value.__cause__ is boom


# ---------------------------------------------------------------------------
# chaos soak (acceptance: >= 300 steps, invariants every step)
# ---------------------------------------------------------------------------
def _soak_plan():
    return FaultPlan(seed=SOAK_SEED, rules=[
        FaultRule(site="extend", kind="alloc_fail", prob=0.02, times=None),
        FaultRule(site="reserve", kind="alloc_fail", prob=0.01, times=None),
        FaultRule(site="sample", kind="nan", prob=0.004, times=None),
        FaultRule(site="decode", kind="transient", prob=0.01, times=None),
        FaultRule(site="prefill", kind="transient", prob=0.01, times=None),
    ])


def test_chaos_soak_engine(donor):
    """300+ steps of random admit/cancel under injected allocator, device
    and numerics faults: allocator invariants after every step, engine
    liveness after every step, only structured errors ever escape."""
    cfg, params = donor
    rnd = random.Random(SOAK_SEED)
    plan = _soak_plan()
    eng = Engine(cfg, params=params, max_slots=3, max_seq_len=64,
                 pool_tokens=120, prefill_chunk=8, faults=plan,
                 max_waiting=6, admit_watermark=0.95, max_step_retries=6)
    all_reqs, shed = [], 0
    # bounded prompt-length menu keeps eager-compile shapes finite
    lens = (5, 9, 14, 26)

    def submit():
        nonlocal shed
        r = Request(prompt=[1 + rnd.randrange(50)] * rnd.choice(lens),
                    max_new_tokens=rnd.randint(2, 8),
                    deadline_steps=(rnd.randint(15, 60)
                                    if rnd.random() < 0.3 else None))
        try:
            eng.add_request(r)
            all_reqs.append(r)
        except Backpressure:
            shed += 1

    for _ in range(2):
        submit()
    structured_escapes = 0
    for step in range(310):
        if rnd.random() < 0.5:
            submit()
        live = [r for r in all_reqs if not r.done]
        if live and rnd.random() < 0.06:
            eng.cancel_request(rnd.choice(live).rid)
        try:
            eng.step()
        except InternalError as e:
            pytest.fail(f"wrapped internal failure in step(): {e!r} "
                        f"(cause: {e.__cause__!r})")
        except EngineError:
            structured_escapes += 1  # allowed; engine must stay alive
        except Exception as e:  # noqa: BLE001
            pytest.fail(f"unstructured exception escaped step(): {e!r}")
        # allocator agreement, every step
        check_allocator_invariants(eng.mgr, eng.scheduler)
        # engine liveness: scheduler state coherent, reports computable
        assert all(r.status in LIVE
                   for r in eng.scheduler.running.values())
        assert all(r.status in (Status.WAITING, Status.PREEMPTED)
                   for r in eng.scheduler.waiting)
        eng.robustness_report()
        eng.memory_report()

    # drain: disable injection, let the tail finish
    eng.faults = None
    eng.mgr.plan = FaultPlan([])  # allocator sites off too
    for _ in range(600):
        if all(r.done for r in all_reqs):
            break
        eng.step()
        check_allocator_invariants(eng.mgr, eng.scheduler)
    assert all(r.done for r in all_reqs)
    # every page home, every refcount zero
    assert eng.mgr.used_pages == 0
    assert sorted(eng.mgr.free_list) == list(range(eng.num_pages))
    assert all(c == 0 for c in eng.mgr.refcount)
    # the soak must actually have exercised the failure surface
    # (read fires off the plan object: eng.faults was cleared for the
    # drain, so the report's fault_fires is 0 by then)
    rep = eng.robustness_report()
    assert plan.fires >= 5, "plan barely fired; raise the probs"
    assert rep["cancelled"] >= 3
    assert rep["failed"] >= 1
    assert shed == rep["shed"]
    statuses = {r.status for r in all_reqs}
    assert Status.FINISHED in statuses
    # terminal states partition the wave — nothing is left in limbo
    assert statuses <= {Status.FINISHED, Status.FAILED, Status.CANCELLED}


def test_chaos_soak_scheduler_level():
    """Model-free soak at 10x the step count: the scheduler + faulty
    allocator alone, driving admit/grow/extend/cancel/fail/finish."""
    rnd = random.Random(SOAK_SEED + 1)
    plan = FaultPlan(seed=SOAK_SEED + 1, rules=[
        FaultRule(site="extend", kind="alloc_fail", prob=0.05, times=None),
        FaultRule(site="reserve", kind="alloc_fail", prob=0.03, times=None),
    ])
    mgr = FaultyPageManager(num_pages=20, page_size=4, plan=plan)
    sched = Scheduler(mgr, max_slots=4, max_seq_len=256, headroom_pages=1,
                      prefill_chunk=8, max_waiting=5, admit_watermark=0.98)
    all_reqs = []

    def submit():
        r = Request(prompt=[1] * rnd.randint(6, 30),
                    max_new_tokens=rnd.randint(3, 12),
                    deadline_steps=(rnd.randint(20, 80)
                                    if rnd.random() < 0.4 else None))
        try:
            sched.add(r)
            r.metrics["step_arrive"] = step
            all_reqs.append(r)
        except Backpressure:
            pass

    step = 0
    for step in range(3000):
        if rnd.random() < 0.5:
            submit()
        sched.check_deadlines(step)
        sched.admit()
        check_allocator_invariants(mgr, sched)
        for r in sorted(sched.running.values(), key=lambda x: x.rid):
            if r.status is not Status.PREFILLING:
                continue
            if sched.running.get(r.slot) is not r:
                continue
            if sched.grow_prefill(r):
                if sched.running.get(r.slot) is not r:
                    continue
                r.prefill_pos = min(r.prefill_pos + 8, r.total_len)
                if r.prefill_pos >= r.total_len:
                    r.status = Status.RUNNING
        check_allocator_invariants(mgr, sched)
        if any(r.status is Status.RUNNING for r in sched.running.values()):
            sched.extend_for_decode()
            for r in sched.running.values():
                if r.status is Status.RUNNING:
                    r.output.append(0)
            check_allocator_invariants(mgr, sched)
        live = [r for r in all_reqs if not r.done
                and r.status is not Status.PREEMPTED]
        if live and rnd.random() < 0.05:
            sched.cancel(rnd.choice(live))
            check_allocator_invariants(mgr, sched)
        for r in list(sched.running.values()):
            if (r.status is Status.RUNNING
                    and len(r.output) >= r.max_new_tokens):
                sched.finish(r)
        check_allocator_invariants(mgr, sched)
        sched.failed_events.clear()

    assert sched.preempted >= 3
    assert sched.cancelled >= 5
    assert plan.fires >= 10
    # drain with injection off
    mgr.plan = FaultPlan([])
    for step in range(step, step + 2000):
        if not sched.has_work:
            break
        sched.check_deadlines(step)
        sched.admit()
        for r in sorted(sched.running.values(), key=lambda x: x.rid):
            if r.status is Status.PREFILLING \
                    and sched.running.get(r.slot) is r \
                    and sched.grow_prefill(r) \
                    and sched.running.get(r.slot) is r:
                r.prefill_pos = min(r.prefill_pos + 8, r.total_len)
                if r.prefill_pos >= r.total_len:
                    r.status = Status.RUNNING
        if any(r.status is Status.RUNNING for r in sched.running.values()):
            sched.extend_for_decode()
            for r in sched.running.values():
                if r.status is Status.RUNNING:
                    r.output.append(0)
        for r in list(sched.running.values()):
            if (r.status is Status.RUNNING
                    and len(r.output) >= r.max_new_tokens):
                sched.finish(r)
        check_allocator_invariants(mgr, sched)
    assert not sched.has_work
    assert len(mgr.free_list) == mgr.num_pages
    assert all(c == 0 for c in mgr.refcount)


# ---------------------------------------------------------------------------
# prefix-cache fault sites (satellite of the prefix-cache PR)
# ---------------------------------------------------------------------------
def test_injected_attach_evict_degrades_to_cold_prefill(donor):
    """An `evict` injected at the `attach` site models the cached chain
    disappearing between lookup and attach: the admission must degrade to
    a plain cold prefill (same output), never a partial attach."""
    from test_prefix_cache import check_cache_invariants

    cfg, params = donor
    ps = cfg.page_size
    mk = lambda: Request(prompt=[5] * 3 * ps, max_new_tokens=4)

    # un-faulted cache-on reference: warm once, then the hit run
    ref_eng = Engine(cfg, params=params, max_slots=2, max_seq_len=64,
                     prefix_cache=True, rng=jax.random.PRNGKey(3))
    ref_eng.generate([mk()], max_steps=200)
    ref = ref_eng.generate([mk()], max_steps=200)[0]
    assert ref.cached_prefix > 0, "reference run must actually hit"

    plan = FaultPlan([FaultRule(site="attach", kind="evict", nth=1)])
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=64,
                 prefix_cache=True, faults=plan, rng=jax.random.PRNGKey(3))
    warm = eng.generate([mk()], max_steps=200)[0]  # cold: attach never
    assert plan.fires == 0                         # matches, rule unpolled
    hit = eng.generate([mk()], max_steps=200)[0]   # match -> injected evict
    assert plan.fires == 1
    assert eng.prefix_cache.attach_faults == 1
    assert hit.status is Status.FINISHED
    assert hit.cached_prefix == 0, "faulted attach must degrade to cold"
    assert hit.output == warm.output == ref.output
    check_cache_invariants(eng.mgr, eng.prefix_cache, eng.scheduler)

    # the plan is spent: the next identical prompt hits again (the cold
    # run re-seeded the evicted chain on release)
    again = eng.generate([mk()], max_steps=200)[0]
    assert again.cached_prefix > 0
    assert again.output == ref.output
    check_cache_invariants(eng.mgr, eng.prefix_cache, eng.scheduler)


def test_reserve_refusal_after_attach_rolls_back_and_retries(donor):
    """An injected reserve refusal *after* a successful attach exercises
    the admission rollback: the attached pages must return to cache-only
    residency (nothing leaked, nothing evicted) and the retry next step
    must hit again and produce the reference output."""
    from test_prefix_cache import check_cache_invariants

    cfg, params = donor
    ps = cfg.page_size
    mk = lambda: Request(prompt=[6] * 3 * ps, max_new_tokens=4)

    ref_eng = Engine(cfg, params=params, max_slots=2, max_seq_len=64,
                     prefix_cache=True, rng=jax.random.PRNGKey(4))
    ref_eng.generate([mk()], max_steps=200)
    ref = ref_eng.generate([mk()], max_steps=200)[0]

    b = mk()
    plan = FaultPlan([FaultRule(site="reserve", kind="alloc_fail",
                                rid=b.rid, nth=1)])
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=64,
                 prefix_cache=True, faults=plan, rng=jax.random.PRNGKey(4))
    eng.generate([mk()], max_steps=200)
    resident_before = eng.prefix_cache.resident_pages
    assert resident_before >= 3

    eng.add_request(b)
    eng.step()  # admission: attach hits, injected reserve refusal
    assert plan.fires == 1
    assert b.status is Status.WAITING, "refused admission must re-queue"
    assert b.rid not in eng.mgr.tables, "rollback must free the attach"
    assert eng.prefix_cache.resident_pages == resident_before, (
        "rolled-back pages must stay cache-resident")
    check_cache_invariants(eng.mgr, eng.prefix_cache, eng.scheduler)

    for _ in range(200):
        if b.done:
            break
        eng.step()
    assert b.status is Status.FINISHED
    assert b.cached_prefix > 0, "retry must re-attach to the same chain"
    assert eng.prefix_cache.hits == 2  # rolled-back attach + the retry
    assert b.output == ref.output
    check_cache_invariants(eng.mgr, eng.prefix_cache, eng.scheduler)
