"""Preemption/fork stress test: allocator invariants under an
oversubscribed pool.

PagedAttention's serving half is only correct if the scheduler that frees
pages "instantly" under memory pressure and the allocator that hands them
out agree at every step.  Two historical bugs broke that agreement:

  * `Scheduler.extend_for_decode` iterated a *snapshot* list while
    preempting — the rebinding ``order = [...]`` never affected the
    active ``for`` loop — so ``mgr.extend`` ran on victims whose pages
    were just freed, re-reserving pages under PREEMPTED rids; the stale
    table row survived ``tables.setdefault`` on re-admission and aliased
    pages concurrently allocated to other sequences.
  * `HostPageManager.fork` ignored the ``bool`` from ``reserve`` — on a
    dry pool the child kept the shared-prefix refcount bumps but got no
    tail page (and pre-fix returned ``None``, so callers could not even
    tell).

This suite fails on the pre-fix scheduler/manager and gates the fixed
ones: every step of an interleaved admit/extend/preempt/fork/finish
schedule must preserve the allocator invariants below.
"""

import random

import pytest

from repro.core.paging import HostPageManager
from repro.serving.request import Request, Status
from repro.serving.scheduler import Scheduler


def check_allocator_invariants(mgr: HostPageManager, sched: Scheduler):
    """The host-allocator ↔ scheduler agreement, asserted exhaustively."""
    live_rids = {r.rid for r in sched.running.values()}

    # 1. pages are only ever held under RUNNING rids — a table row under a
    #    preempted/finished rid is a ghost reservation (the extend-after-
    #    preempt bug's signature) that admission control cannot see.
    assert set(mgr.tables) == live_rids, (
        f"table rows exist for non-running rids: "
        f"{set(mgr.tables) - live_rids}")
    assert set(mgr.lens) == live_rids

    # 2. refcounts match table occurrences exactly.
    occ = {}
    for row in mgr.tables.values():
        for p in row:
            occ[p] = occ.get(p, 0) + 1
    for p in range(mgr.num_pages):
        assert mgr.refcount[p] == occ.get(p, 0), (
            f"page {p}: refcount {mgr.refcount[p]} != "
            f"{occ.get(p, 0)} table occurrences")

    # 3. no physical page referenced by two live block tables unless its
    #    refcount says so (prefix sharing) — refcount 1 means sole owner.
    for p, n in occ.items():
        if n >= 2:
            assert mgr.refcount[p] >= 2, f"page {p} aliased at refcount 1"

    # 4. free-list conservation: every page is free xor referenced, no
    #    duplicates, and the used/free split covers the whole pool.
    free = set(mgr.free_list)
    assert len(free) == len(mgr.free_list), "duplicate pages on free list"
    assert not (free & set(occ)), "page simultaneously free and referenced"
    assert mgr.used_pages + len(mgr.free_list) == mgr.num_pages
    assert len(occ) + len(mgr.free_list) == mgr.num_pages

    # 5. table rows cover exactly ceil(len / page_size) pages.
    for rid, row in mgr.tables.items():
        want = -(-mgr.lens[rid] // mgr.page_size)
        assert len(row) == want, (
            f"rid {rid}: {len(row)} pages for len {mgr.lens[rid]}")


def _drain_running_decode_token(sched: Scheduler):
    """Mirror the engine: every surviving RUNNING request gains the token
    the extend reserved space for."""
    for r in sched.running.values():
        r.output.append(0)


def test_preempted_victim_is_never_extended():
    """Targeted regression for the extend-after-preempt bug: the victim
    preempted mid-loop sits *later* in the rid-sorted iteration order, so
    the buggy loop reached it after its pages were freed and re-reserved
    a page under the PREEMPTED rid."""
    mgr = HostPageManager(num_pages=6, page_size=4)
    sched = Scheduler(mgr, max_slots=2, max_seq_len=64, headroom_pages=1)
    r0 = Request(prompt=[1] * 8, max_new_tokens=32)
    r1 = Request(prompt=[1] * 8, max_new_tokens=32)
    sched.add(r0)
    sched.add(r1)
    assert len(sched.admit()) == 2

    victims = []
    for _ in range(8):
        victims += sched.extend_for_decode()
        _drain_running_decode_token(sched)
        check_allocator_invariants(mgr, sched)
        if victims:
            break
    assert victims == [r1], "youngest running request must be the victim"
    assert r1.status is Status.PREEMPTED
    # the freed rid must hold nothing: no table row, no len, no pages —
    # pre-fix, mgr.tables[r1.rid] re-appeared with one freshly-popped page
    assert r1.rid not in mgr.tables
    assert r1.rid not in mgr.lens
    # and the survivor keeps decoding with a consistent allocator
    assert r0.rid in mgr.tables
    check_allocator_invariants(mgr, sched)


def test_fork_on_dry_pool_rolls_back():
    """`HostPageManager.fork` must be all-or-nothing: a fork whose tail
    page cannot be served returns False and leaves no trace (pre-fix it
    returned None, kept the refcount bumps, and left a tail-less child
    row behind)."""
    mgr = HostPageManager(num_pages=3, page_size=4)
    assert mgr.reserve(0, 9)  # 3 pages: 2 full + partial tail; pool now dry
    before_ref = list(mgr.refcount)
    ok = mgr.fork(0, 1)
    assert ok is False
    assert 1 not in mgr.tables and 1 not in mgr.lens
    assert mgr.refcount == before_ref, "failed fork must roll back refcounts"
    assert len(mgr.free_list) == 0

    # page-aligned src (no tail needed) forks fine even on a dry pool
    mgr2 = HostPageManager(num_pages=2, page_size=4)
    assert mgr2.reserve(0, 8)
    assert mgr2.fork(0, 1) is True
    assert mgr2.tables[1] == mgr2.tables[0]
    assert all(mgr2.refcount[p] == 2 for p in mgr2.tables[0])


def test_preempt_fork_stress_invariants():
    """The acceptance stress: oversubscribed pool, N steps of interleaved
    admits / decode-extends (with preemption) / forks / finishes, with the
    full allocator-invariant check after every step."""
    rnd = random.Random(0xC0FFEE)
    mgr = HostPageManager(num_pages=24, page_size=4)
    sched = Scheduler(mgr, max_slots=4, max_seq_len=256, headroom_pages=1)

    all_reqs = []

    def submit(n_tokens):
        r = Request(prompt=[1] * n_tokens, max_new_tokens=rnd.randint(4, 24))
        all_reqs.append(r)
        sched.add(r)

    for _ in range(3):
        submit(rnd.randint(4, 24))

    preempted_total = 0
    forked_total = 0
    fork_failed_total = 0
    for step in range(200):
        # keep pressure on: top the queue up so admission always has work
        if len(sched.waiting) < 2 and rnd.random() < 0.5:
            submit(rnd.randint(4, 28))

        sched.admit()
        check_allocator_invariants(mgr, sched)

        if sched.running:
            preempted_total += len(sched.extend_for_decode())
            _drain_running_decode_token(sched)
            check_allocator_invariants(mgr, sched)

        # fork: child aliases a running parent's full pages (refcount++).
        # On a dry pool the fork must fail atomically — either way the
        # invariants hold.  The child enters the running batch directly
        # (no re-prefill), mirroring Engine.fork_request.
        free_slots = sched.free_slots()
        if sched.running and free_slots and rnd.random() < 0.35:
            parent = rnd.choice(list(sched.running.values()))
            child = Request(prompt=list(parent.prompt) + list(parent.output),
                            max_new_tokens=rnd.randint(2, 8))
            all_reqs.append(child)
            ok = mgr.fork(parent.rid, child.rid)
            assert ok in (True, False), "fork must report success"
            if ok:
                child.status = Status.RUNNING
                child.slot = free_slots[0]
                sched.running[child.slot] = child
                forked_total += 1
            else:
                fork_failed_total += 1
                assert child.rid not in mgr.tables
            check_allocator_invariants(mgr, sched)

        # finish requests that hit their budget (frees pages → churn)
        for r in list(sched.running.values()):
            if len(r.output) >= r.max_new_tokens:
                sched.finish(r)
        check_allocator_invariants(mgr, sched)

    # the schedule must actually have exercised the hard paths
    assert preempted_total >= 3, "stress never triggered preemption"
    assert forked_total >= 3, "stress never forked"
    assert sched.preempted == preempted_total

    # drain: let everything finish; the pool must come back whole
    for _ in range(600):
        if not sched.has_work:
            break
        sched.admit()
        if sched.running:
            sched.extend_for_decode()
            _drain_running_decode_token(sched)
        for r in list(sched.running.values()):
            if len(r.output) >= r.max_new_tokens:
                sched.finish(r)
        check_allocator_invariants(mgr, sched)
    assert not sched.has_work
    assert len(mgr.free_list) == mgr.num_pages
    assert all(c == 0 for c in mgr.refcount)


def test_cascaded_preemption_keeps_invariants():
    """Several sequences hitting page boundaries in the same step force
    multiple victims in one extend pass; each later extend must see the
    post-preemption allocator, never a stale snapshot."""
    mgr = HostPageManager(num_pages=9, page_size=4)
    sched = Scheduler(mgr, max_slots=3, max_seq_len=128, headroom_pages=1)
    reqs = [Request(prompt=[1] * 8, max_new_tokens=64) for _ in range(3)]
    for r in reqs:
        sched.add(r)
    assert len(sched.admit()) == 3  # 6 pages used, 3 free

    victims = []
    for _ in range(10):
        victims += sched.extend_for_decode()
        _drain_running_decode_token(sched)
        check_allocator_invariants(mgr, sched)
        if len(victims) >= 2:
            break
    assert len(victims) >= 2, "pool pressure must force multiple victims"
    for v in victims:
        assert v.status is Status.PREEMPTED
        assert v.rid not in mgr.tables and v.rid not in mgr.lens
    # exactly one survivor decodes on
    assert len(sched.running) == 1
    check_allocator_invariants(mgr, sched)
