"""Preemption/fork stress test: allocator invariants under an
oversubscribed pool.

PagedAttention's serving half is only correct if the scheduler that frees
pages "instantly" under memory pressure and the allocator that hands them
out agree at every step.  Two historical bugs broke that agreement:

  * `Scheduler.extend_for_decode` iterated a *snapshot* list while
    preempting — the rebinding ``order = [...]`` never affected the
    active ``for`` loop — so ``mgr.extend`` ran on victims whose pages
    were just freed, re-reserving pages under PREEMPTED rids; the stale
    table row survived ``tables.setdefault`` on re-admission and aliased
    pages concurrently allocated to other sequences.
  * `HostPageManager.fork` ignored the ``bool`` from ``reserve`` — on a
    dry pool the child kept the shared-prefix refcount bumps but got no
    tail page (and pre-fix returned ``None``, so callers could not even
    tell).

This suite fails on the pre-fix scheduler/manager and gates the fixed
ones: every step of an interleaved admit/extend/preempt/fork/finish
schedule must preserve the allocator invariants below.
"""

import random

import pytest

from repro.core.paging import HostPageManager
from repro.serving.request import Request, Status
from repro.serving.scheduler import Scheduler


def check_allocator_invariants(mgr: HostPageManager, sched: Scheduler):
    """The host-allocator ↔ scheduler agreement, asserted exhaustively."""
    live_rids = {r.rid for r in sched.running.values()}

    # 1. pages are only ever held under RUNNING rids — a table row under a
    #    preempted/finished rid is a ghost reservation (the extend-after-
    #    preempt bug's signature) that admission control cannot see.
    assert set(mgr.tables) == live_rids, (
        f"table rows exist for non-running rids: "
        f"{set(mgr.tables) - live_rids}")
    assert set(mgr.lens) == live_rids

    # 2. refcounts match table occurrences exactly.
    occ = {}
    for row in mgr.tables.values():
        for p in row:
            occ[p] = occ.get(p, 0) + 1
    for p in range(mgr.num_pages):
        assert mgr.refcount[p] == occ.get(p, 0), (
            f"page {p}: refcount {mgr.refcount[p]} != "
            f"{occ.get(p, 0)} table occurrences")

    # 3. no physical page referenced by two live block tables unless its
    #    refcount says so (prefix sharing) — refcount 1 means sole owner.
    for p, n in occ.items():
        if n >= 2:
            assert mgr.refcount[p] >= 2, f"page {p} aliased at refcount 1"

    # 4. free-list conservation: every page is free xor referenced, no
    #    duplicates, and the used/free split covers the whole pool.
    free = set(mgr.free_list)
    assert len(free) == len(mgr.free_list), "duplicate pages on free list"
    assert not (free & set(occ)), "page simultaneously free and referenced"
    assert mgr.used_pages + len(mgr.free_list) == mgr.num_pages
    assert len(occ) + len(mgr.free_list) == mgr.num_pages

    # 5. table rows cover exactly ceil(len / page_size) pages.
    for rid, row in mgr.tables.items():
        want = -(-mgr.lens[rid] // mgr.page_size)
        assert len(row) == want, (
            f"rid {rid}: {len(row)} pages for len {mgr.lens[rid]}")


def _drain_running_decode_token(sched: Scheduler):
    """Mirror the engine: every surviving RUNNING request gains the token
    the extend reserved space for.  (PREFILLING requests are still caching
    their prompt — they neither extend nor sample.)"""
    for r in sched.running.values():
        if r.status is Status.RUNNING:
            r.output.append(0)


def test_preempted_victim_is_never_extended():
    """Targeted regression for the extend-after-preempt bug: the victim
    preempted mid-loop sits *later* in the rid-sorted iteration order, so
    the buggy loop reached it after its pages were freed and re-reserved
    a page under the PREEMPTED rid."""
    mgr = HostPageManager(num_pages=6, page_size=4)
    sched = Scheduler(mgr, max_slots=2, max_seq_len=64, headroom_pages=1)
    r0 = Request(prompt=[1] * 8, max_new_tokens=32)
    r1 = Request(prompt=[1] * 8, max_new_tokens=32)
    sched.add(r0)
    sched.add(r1)
    assert len(sched.admit()) == 2

    victims = []
    for _ in range(8):
        victims += sched.extend_for_decode()
        _drain_running_decode_token(sched)
        check_allocator_invariants(mgr, sched)
        if victims:
            break
    assert victims == [r1], "youngest running request must be the victim"
    assert r1.status is Status.PREEMPTED
    # the freed rid must hold nothing: no table row, no len, no pages —
    # pre-fix, mgr.tables[r1.rid] re-appeared with one freshly-popped page
    assert r1.rid not in mgr.tables
    assert r1.rid not in mgr.lens
    # and the survivor keeps decoding with a consistent allocator
    assert r0.rid in mgr.tables
    check_allocator_invariants(mgr, sched)


def test_fork_on_dry_pool_rolls_back():
    """`HostPageManager.fork` must be all-or-nothing: a fork whose tail
    page cannot be served returns False and leaves no trace (pre-fix it
    returned None, kept the refcount bumps, and left a tail-less child
    row behind)."""
    mgr = HostPageManager(num_pages=3, page_size=4)
    assert mgr.reserve(0, 9)  # 3 pages: 2 full + partial tail; pool now dry
    before_ref = list(mgr.refcount)
    ok = mgr.fork(0, 1)
    assert ok is False
    assert 1 not in mgr.tables and 1 not in mgr.lens
    assert mgr.refcount == before_ref, "failed fork must roll back refcounts"
    assert len(mgr.free_list) == 0

    # page-aligned src (no tail needed) forks fine even on a dry pool
    mgr2 = HostPageManager(num_pages=2, page_size=4)
    assert mgr2.reserve(0, 8)
    assert mgr2.fork(0, 1) is True
    assert mgr2.tables[1] == mgr2.tables[0]
    assert all(mgr2.refcount[p] == 2 for p in mgr2.tables[0])


def test_fork_from_unknown_src_raises_invariant_error():
    """`fork` from a rid with no table row (never reserved, already
    freed, or preempted) is a scheduler invariant violation and must
    raise a structured error naming the rid — pre-fix it escaped as a
    bare ``KeyError`` from the table lookup, indistinguishable from an
    allocator bug."""
    from repro.errors import SchedulerInvariantError

    mgr = HostPageManager(num_pages=4, page_size=4)
    with pytest.raises(SchedulerInvariantError, match="unknown rid 99"):
        mgr.fork(99, 1)

    # fork-after-free is the same violation (the preempt/fork race)
    assert mgr.reserve(0, 8)
    mgr.free(0)
    with pytest.raises(SchedulerInvariantError, match="unknown rid 0"):
        mgr.fork(0, 1)
    # nothing leaked by the refused forks
    assert len(mgr.free_list) == mgr.num_pages
    assert not mgr.tables and not mgr.lens


def test_double_free_after_fork():
    """Freeing a fork child twice must fail loudly on the second free and
    leave the parent's shared pages (and the pool accounting) intact."""
    from repro.errors import SchedulerInvariantError

    mgr = HostPageManager(num_pages=4, page_size=4)
    assert mgr.reserve(0, 8)
    assert mgr.fork(0, 1) is True
    parent_pages = list(mgr.tables[0])
    mgr.free(1)
    assert all(mgr.refcount[p] == 1 for p in parent_pages)
    with pytest.raises(SchedulerInvariantError):
        mgr.free(1)
    # the double free must not have touched the parent's pages
    assert mgr.tables[0] == parent_pages
    assert all(mgr.refcount[p] == 1 for p in parent_pages)
    assert mgr.used_pages == 2
    mgr.free(0)
    assert len(mgr.free_list) == mgr.num_pages


def test_preempt_fork_stress_invariants():
    """The acceptance stress: oversubscribed pool, N steps of interleaved
    admits / decode-extends (with preemption) / forks / finishes, with the
    full allocator-invariant check after every step."""
    rnd = random.Random(0xC0FFEE)
    mgr = HostPageManager(num_pages=24, page_size=4)
    sched = Scheduler(mgr, max_slots=4, max_seq_len=256, headroom_pages=1)

    all_reqs = []

    def submit(n_tokens):
        r = Request(prompt=[1] * n_tokens, max_new_tokens=rnd.randint(4, 24))
        all_reqs.append(r)
        sched.add(r)

    for _ in range(3):
        submit(rnd.randint(4, 24))

    preempted_total = 0
    forked_total = 0
    fork_failed_total = 0
    for step in range(200):
        # keep pressure on: top the queue up so admission always has work
        if len(sched.waiting) < 2 and rnd.random() < 0.5:
            submit(rnd.randint(4, 28))

        sched.admit()
        check_allocator_invariants(mgr, sched)

        if sched.running:
            preempted_total += len(sched.extend_for_decode())
            _drain_running_decode_token(sched)
            check_allocator_invariants(mgr, sched)

        # fork: child aliases a running parent's full pages (refcount++).
        # On a dry pool the fork must fail atomically — either way the
        # invariants hold.  The child enters the running batch directly
        # (no re-prefill), mirroring Engine.fork_request.
        free_slots = sched.free_slots()
        if sched.running and free_slots and rnd.random() < 0.35:
            parent = rnd.choice(list(sched.running.values()))
            child = Request(prompt=list(parent.prompt) + list(parent.output),
                            max_new_tokens=rnd.randint(2, 8))
            all_reqs.append(child)
            ok = mgr.fork(parent.rid, child.rid)
            assert ok in (True, False), "fork must report success"
            if ok:
                child.status = Status.RUNNING
                child.slot = free_slots[0]
                sched.running[child.slot] = child
                forked_total += 1
            else:
                fork_failed_total += 1
                assert child.rid not in mgr.tables
            check_allocator_invariants(mgr, sched)

        # finish requests that hit their budget (frees pages → churn)
        for r in list(sched.running.values()):
            if len(r.output) >= r.max_new_tokens:
                sched.finish(r)
        check_allocator_invariants(mgr, sched)

    # the schedule must actually have exercised the hard paths
    assert preempted_total >= 3, "stress never triggered preemption"
    assert forked_total >= 3, "stress never forked"
    assert sched.preempted == preempted_total

    # drain: let everything finish; the pool must come back whole
    for _ in range(600):
        if not sched.has_work:
            break
        sched.admit()
        if sched.running:
            sched.extend_for_decode()
            _drain_running_decode_token(sched)
        for r in list(sched.running.values()):
            if len(r.output) >= r.max_new_tokens:
                sched.finish(r)
        check_allocator_invariants(mgr, sched)
    assert not sched.has_work
    assert len(mgr.free_list) == mgr.num_pages
    assert all(c == 0 for c in mgr.refcount)


def test_chunked_admission_reserves_chunkwise_not_total():
    """ISSUE 5 satellite: admission must reserve prompt pages chunk-wise.
    The former all-at-front reservation head-of-line-blocked the whole
    queue on a long prompt's full page count even though chunked prefill
    grows incrementally."""
    # 8 pages of 8 tokens.  A 50-token prompt needs 7 pages + headroom
    # monolithically — more than the pool ever has once anything else
    # runs; chunk-wise it needs 1 page + headroom.
    mono_mgr = HostPageManager(num_pages=8, page_size=8)
    mono = Scheduler(mono_mgr, max_slots=3, max_seq_len=128)
    chunk_mgr = HostPageManager(num_pages=8, page_size=8)
    chunked = Scheduler(chunk_mgr, max_slots=3, max_seq_len=128,
                        prefill_chunk=8)
    for sched in (mono, chunked):
        sched.add(Request(prompt=[1] * 24))  # 3 pages, admitted by both
        sched.add(Request(prompt=[1] * 50))  # long
        sched.add(Request(prompt=[1] * 8))   # short, behind the long one

    a_mono = mono.admit()
    # monolithic: long blocks (needs 7+1 of the 5 remaining) and FIFO
    # blocks the short one behind it
    assert len(a_mono) == 1
    assert mono.waiting[0].prompt_len == 50
    assert mono.waiting[1].status is Status.WAITING

    a_chunk = chunked.admit()
    # chunk-wise: the long prompt is admitted on one chunk's pages, so
    # the short request behind it is admitted sooner (same step)
    assert len(a_chunk) == 3
    assert all(r.status is Status.PREFILLING for _, r in a_chunk)
    check_allocator_invariants(chunk_mgr, chunked)


def _drive_prefill_chunks(sched: Scheduler):
    """Mirror Engine._prefill_chunk_step against the scheduler alone:
    grow each PREFILLING request by one chunk (stall on a dry pool) and
    flip it to RUNNING when its last chunk lands."""
    progressed = []
    for r in sorted(sched.running.values(), key=lambda x: x.rid):
        if r.status is not Status.PREFILLING:
            continue
        if sched.running.get(r.slot) is not r:
            continue  # preempted by an earlier grow_prefill this step
        if not sched.grow_prefill(r):
            continue  # stalled: keeps pages, resumes later
        if sched.running.get(r.slot) is not r:
            continue  # grow_prefill preempted it to make progress
        r.prefill_pos = min(r.prefill_pos + sched.prefill_chunk,
                            r.total_len)
        if r.prefill_pos >= r.total_len:
            r.status = Status.RUNNING
            progressed.append(r)
    return progressed


def test_chunked_preempt_midprefill_readmit_finish_stress():
    """ISSUE 5 satellite: the preemption stress with the chunked-prefill
    state machine in the loop — admit (chunk-wise) → grow/stall chunks →
    decode-extend (preempting PREFILLING victims too) → re-admit → finish
    — asserting the same allocator invariants every step."""
    rnd = random.Random(0xBEEF)
    mgr = HostPageManager(num_pages=20, page_size=4)
    sched = Scheduler(mgr, max_slots=4, max_seq_len=256, headroom_pages=1,
                      prefill_chunk=8)

    all_reqs = []

    def submit(n_tokens):
        r = Request(prompt=[1] * n_tokens,
                    max_new_tokens=rnd.randint(4, 16))
        all_reqs.append(r)
        sched.add(r)

    for _ in range(3):
        submit(rnd.randint(12, 40))

    preempted_midprefill = 0
    finished = 0
    for step in range(300):
        if len(sched.waiting) < 2 and rnd.random() < 0.6:
            submit(rnd.randint(12, 48))

        sched.admit()
        check_allocator_invariants(mgr, sched)

        pre_prefilling = {r.rid: r.prefill_pos
                          for r in sched.running.values()
                          if r.status is Status.PREFILLING}
        _drive_prefill_chunks(sched)
        check_allocator_invariants(mgr, sched)

        if any(r.status is Status.RUNNING for r in sched.running.values()):
            victims = sched.extend_for_decode()
            preempted_midprefill += sum(
                1 for v in victims if v.rid in pre_prefilling)
            _drain_running_decode_token(sched)
            check_allocator_invariants(mgr, sched)

        for r in list(sched.running.values()):
            if r.status is Status.RUNNING and \
                    len(r.output) >= r.max_new_tokens:
                sched.finish(r)
                finished += 1
        check_allocator_invariants(mgr, sched)

    # the schedule must have exercised the chunked hard paths
    assert sched.preempted >= 3, "stress never preempted"
    assert preempted_midprefill >= 1, \
        "no request was ever preempted mid-prefill"
    assert sched.prefill_stalls >= 1, "no prefill ever stalled"
    assert finished >= 5

    # a mid-prefill preemptee must re-admit from chunk 0 and finish
    for _ in range(800):
        if not sched.has_work:
            break
        sched.admit()
        _drive_prefill_chunks(sched)
        if any(r.status is Status.RUNNING for r in sched.running.values()):
            sched.extend_for_decode()
            _drain_running_decode_token(sched)
        for r in list(sched.running.values()):
            if r.status is Status.RUNNING and \
                    len(r.output) >= r.max_new_tokens:
                sched.finish(r)
        check_allocator_invariants(mgr, sched)
    assert not sched.has_work
    assert all(r.status is Status.FINISHED for r in all_reqs)
    assert len(mgr.free_list) == mgr.num_pages
    assert all(c == 0 for c in mgr.refcount)


def test_grow_prefill_stalls_then_resumes_without_losing_pages():
    """A prefill stalled on a dry pool keeps its reservation (mgr.lens
    unchanged) and continues from it — never from zero — once pages free."""
    mgr = HostPageManager(num_pages=6, page_size=4)
    sched = Scheduler(mgr, max_slots=2, max_seq_len=128, headroom_pages=1,
                      prefill_chunk=8)
    decoder = Request(prompt=[1] * 12, max_new_tokens=4)  # 3 pages
    long_req = Request(prompt=[1] * 40, max_new_tokens=4)
    sched.add(decoder)
    sched.add(long_req)
    assert len(sched.admit()) == 2
    # decoder's prompt caches in two chunks (8 then 4): 3 pages total
    assert sched.grow_prefill(decoder)
    decoder.prefill_pos = 8
    assert sched.grow_prefill(decoder)
    decoder.prefill_pos = 12
    decoder.status = Status.RUNNING

    # admission already reserved the first chunk (8 tokens = 2 pages)
    assert sched.grow_prefill(long_req)
    long_req.prefill_pos = 8
    # the next chunk (to 16 tokens = 4 pages) needs 2 pages, free is 1:
    # stall — a RUNNING decoder will free pages, so no preemption
    assert not sched.grow_prefill(long_req), "pool should be dry"
    assert sched.prefill_stalls == 1
    assert mgr.lens[long_req.rid] == 8, "stall must not touch the reservation"
    assert long_req.status is Status.PREFILLING
    assert sched.preempted == 0

    sched.finish(decoder)  # frees 3 pages
    assert sched.grow_prefill(long_req)
    assert mgr.lens[long_req.rid] == 16  # resumed from 8, not from 0
    check_allocator_invariants(mgr, sched)


def test_cascaded_preemption_keeps_invariants():
    """Several sequences hitting page boundaries in the same step force
    multiple victims in one extend pass; each later extend must see the
    post-preemption allocator, never a stale snapshot."""
    mgr = HostPageManager(num_pages=9, page_size=4)
    sched = Scheduler(mgr, max_slots=3, max_seq_len=128, headroom_pages=1)
    reqs = [Request(prompt=[1] * 8, max_new_tokens=64) for _ in range(3)]
    for r in reqs:
        sched.add(r)
    assert len(sched.admit()) == 3  # 6 pages used, 3 free

    victims = []
    for _ in range(10):
        victims += sched.extend_for_decode()
        _drain_running_decode_token(sched)
        check_allocator_invariants(mgr, sched)
        if len(victims) >= 2:
            break
    assert len(victims) >= 2, "pool pressure must force multiple victims"
    for v in victims:
        assert v.status is Status.PREEMPTED
        assert v.rid not in mgr.tables and v.rid not in mgr.lens
    # exactly one survivor decodes on
    assert len(sched.running) == 1
    check_allocator_invariants(mgr, sched)
