"""Pallas flex-attention (prefill) kernel vs the jnp oracle.

Covers the paper's §III-B mask surface: causal, sliding-window, padding,
document (jagged), paged predicate, softcap/alibi score mods, and the
BlockMask tile-skip machinery.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flex
from repro.kernels.flex_attention.ops import flex_attention
from repro.kernels.flex_attention.ref import flex_attention_ref

from conftest import assert_close


def qkv(rng, B, H, Hkv, Q, K, D, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    return (jax.random.normal(ks[0], (B, H, Q, D), dtype),
            jax.random.normal(ks[1], (B, Hkv, K, D), dtype),
            jax.random.normal(ks[2], (B, Hkv, K, D), dtype))


SHAPES = [
    (1, 4, 4, 64, 64, 32),
    (2, 8, 2, 128, 128, 64),
    (2, 4, 1, 100, 100, 16),   # ragged vs block size
    (1, 8, 8, 257, 257, 32),   # prime-ish
]


@pytest.mark.parametrize("shape", SHAPES, ids=[str(s) for s in SHAPES])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_causal(rng, shape, dtype):
    B, H, Hkv, Q, K, D = shape
    q, k, v = qkv(rng, B, H, Hkv, Q, K, D, dtype)
    ref = flex_attention_ref(q, k, v, mask_mod=flex.causal_mask)
    out = flex_attention(q, k, v, mask_mod=flex.causal_mask, q_block=64,
                         kv_block=64, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    assert_close(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 100])
def test_sliding_window(rng, window):
    q, k, v = qkv(rng, 2, 4, 2, 160, 160, 32)
    mod = flex.sliding_window_mask(window)
    ref = flex_attention_ref(q, k, v, mask_mod=mod)
    out = flex_attention(q, k, v, mask_mod=mod, window=window, q_block=64,
                         kv_block=64, interpret=True)
    assert_close(out, ref)


def test_padding_mask(rng):
    lens = jnp.asarray([90, 17], jnp.int32)
    q, k, v = qkv(rng, 2, 4, 4, 128, 128, 32)
    mod = flex.and_masks(flex.causal_mask, flex.padding_mask(lens))
    ref = flex_attention_ref(q, k, v, mask_mod=mod)
    out = flex_attention(q, k, v, mask_mod=mod, q_block=64, kv_block=64,
                         interpret=True)
    # rows past len attend to nothing -> oracle softmax yields 0 (nan->0)
    assert_close(out, ref)


def test_document_mask_jagged_batch(rng):
    """The paper's packed-batch predicate «id_q == id_k»."""
    S = 128
    docs = jnp.asarray(
        np.repeat([0, 1, 2], [40, 50, 38])[None, :].repeat(2, 0))
    q, k, v = qkv(rng, 2, 4, 4, S, S, 32)
    mod = flex.and_masks(flex.causal_mask, flex.document_mask(docs))
    ref = flex_attention_ref(q, k, v, mask_mod=mod)
    out = flex_attention(q, k, v, mask_mod=mod, q_block=32, kv_block=32,
                         interpret=True)
    assert_close(out, ref)


def test_score_mods(rng):
    q, k, v = qkv(rng, 1, 4, 4, 64, 64, 32)
    score = flex.compose_score(flex.softcap_score(20.0),
                               flex.alibi_score(jnp.linspace(0.1, 0.4, 4)))
    ref = flex_attention_ref(q, k, v, mask_mod=flex.causal_mask,
                             score_mod=score)
    out = flex_attention(q, k, v, mask_mod=flex.causal_mask, score_mod=score,
                         q_block=32, kv_block=32, interpret=True)
    assert_close(out, ref)


# ---------------------------------------------------------------------------
# BlockMask machinery
# ---------------------------------------------------------------------------
def test_block_mask_matches_materialized():
    Q = K = 256
    mod = flex.sliding_window_mask(50)
    bm = flex.build_block_mask(mod, Q, K, 64, 64)
    dense = np.asarray(flex.materialize(mod, 1, 1, Q, K))[0, 0]
    nq, nk = Q // 64, K // 64
    tiles = dense.reshape(nq, 64, nk, 64).transpose(0, 2, 1, 3)
    live = tiles.any(axis=(2, 3))
    full = tiles.all(axis=(2, 3))
    counts = np.asarray(bm.kv_num_blocks)
    for i in range(nq):
        idx = np.asarray(bm.kv_indices[i, :counts[i]])
        assert set(idx.tolist()) == set(np.where(live[i])[0].tolist())
        isf = np.asarray(bm.is_full[i, :counts[i]])
        assert (isf == full[i][idx]).all()


def test_causal_block_mask_fast_path_equals_builder():
    for Q, K, w in [(256, 256, 0), (256, 256, 70), (192, 192, 64)]:
        mod = (flex.sliding_window_mask(w) if w else flex.causal_mask)
        a = flex.causal_block_mask(Q, K, 64, 64, window=w)
        b = flex.build_block_mask(mod, Q, K, 64, 64)
        ca, cb = np.asarray(a.kv_num_blocks), np.asarray(b.kv_num_blocks)
        assert (ca == cb).all()
        for i in range(len(ca)):
            sa = set(np.asarray(a.kv_indices[i, :ca[i]]).tolist())
            sb = set(np.asarray(b.kv_indices[i, :cb[i]]).tolist())
            assert sa == sb
            # full flags only ever differ conservatively (fast path may
            # mark a fully-live tile partial, never the reverse)
            fa = dict(zip(np.asarray(a.kv_indices[i, :ca[i]]).tolist(),
                          np.asarray(a.is_full[i, :ca[i]]).tolist()))
            fb = dict(zip(np.asarray(b.kv_indices[i, :cb[i]]).tolist(),
                          np.asarray(b.is_full[i, :cb[i]]).tolist()))
            for t in fa:
                assert (not fa[t]) or fb[t]


def test_block_mask_sparsity_skips_tiles(rng):
    """Windowed masks must actually skip tiles (perf contract, not just
    correctness)."""
    bm = flex.causal_block_mask(1024, 1024, 128, 128, window=128)
    assert bm.sparsity > 0.5


def test_paged_mask_predicate():
    """Paper §III-B: allow ⟺ (id_q == id_k) ∧ (pos_k < len(id_q))."""
    sid = jnp.asarray([0, 0, 0, 1, 1, 2, 2, 2])
    pos = jnp.asarray([0, 1, 2, 0, 1, 0, 1, 2])
    lens = jnp.asarray([3, 1, 2])
    mod = flex.paged_mask(sid, pos, lens)
    m = np.asarray(flex.materialize(mod, 1, 1, 8, 8))[0, 0]
    for qi in range(8):
        for ki in range(8):
            expect = (sid[qi] == sid[ki]) and (pos[ki] < lens[sid[qi]])
            assert m[qi, ki] == expect
