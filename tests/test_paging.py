"""Paper Alg. 1: page-manager invariants (device + host mirror).

Property tests (hypothesis) assert the paper's allocator contract: no page
is ever owned twice, refcounts match owners, free pages are conserved, and
the host mirror agrees with the functional device state machine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import paging
from repro.core.paging import HostPageManager, NULL_PAGE


PAGE = 8


def device_invariants(state, page_size):
    """Global invariants of a PageState."""
    tables = np.asarray(state.block_tables)
    lens = np.asarray(state.seq_lens)
    ref = np.asarray(state.refcount)
    top = int(state.free_top)
    stack = np.asarray(state.free_stack)[:top]

    owned = {}
    for s in range(tables.shape[0]):
        n = -(-int(lens[s]) // page_size)
        row = tables[s, :n]
        assert (row >= 0).all(), "live slots must map real pages"
        for p in row:
            owned[int(p)] = owned.get(int(p), 0) + 1
    # refcount == number of owners
    for p in range(len(ref)):
        assert ref[p] == owned.get(p, 0), f"refcount mismatch at page {p}"
    # free pages are exactly the unowned ones
    assert set(stack.tolist()).isdisjoint(owned.keys())
    assert top + len(owned) == len(ref)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 60)),
                min_size=1, max_size=12))
def test_reserve_free_invariants(ops):
    state = paging.init_state(num_pages=32, max_seqs=4, max_pages_per_seq=8)
    lens = [0, 0, 0, 0]
    for seq, length in ops:
        need = -(-length // PAGE)
        have = -(-lens[seq] // PAGE)
        if length >= lens[seq]:
            if need - have <= int(state.free_top):
                state = paging.reserve(state, jnp.int32(seq),
                                       jnp.int32(length), PAGE)
                lens[seq] = length
        else:
            state = paging.free(state, jnp.int32(seq), PAGE)
            lens[seq] = 0
    device_invariants(state, PAGE)


def test_reserve_is_idempotent_when_capacity_exhausted():
    state = paging.init_state(num_pages=2, max_seqs=2, max_pages_per_seq=4)
    state = paging.reserve(state, jnp.int32(0), jnp.int32(2 * PAGE), PAGE)
    assert int(state.free_top) == 0
    before = jax.tree_util.tree_map(np.asarray, state)
    state2 = paging.reserve(state, jnp.int32(1), jnp.int32(PAGE), PAGE)
    # no free pages -> nothing allocated for seq 1's pages
    assert int(state2.free_top) == 0
    assert (np.asarray(state2.block_tables[1]) == NULL_PAGE).all()


def test_fork_shares_full_pages_and_copies_tail():
    state = paging.init_state(num_pages=16, max_seqs=4, max_pages_per_seq=8)
    state = paging.reserve(state, jnp.int32(0), jnp.int32(2 * PAGE + 3), PAGE)
    state, tail = paging.fork(state, jnp.int32(0), jnp.int32(1), PAGE)
    t0 = np.asarray(state.block_tables[0])
    t1 = np.asarray(state.block_tables[1])
    # full pages shared
    assert t0[0] == t1[0] and t0[1] == t1[1]
    # tail page fresh
    assert t1[2] != t0[2] and t1[2] >= 0
    assert int(tail) == t0[2]
    ref = np.asarray(state.refcount)
    assert ref[t0[0]] == 2 and ref[t0[1]] == 2
    assert ref[t0[2]] == 1 and ref[t1[2]] == 1
    # freeing the fork returns only its exclusive + shared-decrement
    state = paging.free(state, jnp.int32(1), PAGE)
    ref = np.asarray(state.refcount)
    assert ref[t0[0]] == 1 and ref[t0[1]] == 1 and ref[t1[2]] == 0
    device_invariants(state, PAGE)


def test_lookup_translation():
    state = paging.init_state(num_pages=8, max_seqs=2, max_pages_per_seq=4)
    state = paging.reserve(state, jnp.int32(0), jnp.int32(3 * PAGE), PAGE)
    page, off = paging.lookup(state, jnp.int32(0), jnp.int32(2 * PAGE + 5),
                              PAGE)
    assert int(page) == int(state.block_tables[0, 2])
    assert int(off) == 5


# ---------------------------------------------------------------------------
# host mirror
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(st.lists(st.sampled_from(["reserve", "extend", "free", "fork"]),
                min_size=1, max_size=30),
       st.randoms())
def test_host_mirror_matches_device(ops, rnd):
    mgr = HostPageManager(num_pages=32, page_size=PAGE)
    state = paging.init_state(num_pages=32, max_seqs=8, max_pages_per_seq=4)
    live = set()
    next_id = 0
    for op in ops:
        if op == "reserve" and next_id < 8:
            seq = next_id
            next_id += 1
            length = rnd.randint(1, 4 * PAGE)
            ok = mgr.reserve(seq, length)
            if ok:
                state = paging.reserve(state, jnp.int32(seq),
                                       jnp.int32(length), PAGE)
                live.add(seq)
        elif op == "extend" and live:
            seq = rnd.choice(sorted(live))
            if mgr.lens[seq] < 4 * PAGE and mgr.extend(seq, 1):
                state = paging.reserve(state, jnp.int32(seq),
                                       jnp.int32(mgr.lens[seq]), PAGE)
        elif op == "free" and live:
            seq = rnd.choice(sorted(live))
            mgr.free(seq)
            state = paging.free(state, jnp.int32(seq), PAGE)
            live.discard(seq)
    # mirrors agree on usage and per-seq page counts
    assert mgr.used_pages == int(paging.used_pages(state))
    for seq in live:
        row = np.asarray(state.block_tables[seq])
        n = -(-mgr.lens[seq] // PAGE)
        assert mgr.tables[seq] == row[:n].tolist()
    device_invariants(state, PAGE)


def test_overhead_below_5_percent_for_long_sequences():
    """Paper objective: <5% memory overhead vs theoretical minimum."""
    mgr = HostPageManager(num_pages=4096, page_size=64)
    rng = np.random.default_rng(0)
    for seq, length in enumerate(rng.integers(1300, 8000, size=16)):
        assert mgr.reserve(seq, int(length))
    # waste is only the partial tail page per sequence
    assert mgr.overhead_frac() < 0.05


def test_contiguous_baseline_waste_matches_paper():
    """The paper's §I motivation: max-length preallocation wastes 60-80%
    for mixed-length batches."""
    max_len = 8192
    rng = np.random.default_rng(1)
    lens = rng.integers(256, 4096, size=16)  # paper's mixed-batch setup
    reserved = 16 * max_len
    used = int(lens.sum())
    waste = 1 - used / reserved
    assert 0.6 <= waste <= 0.8
