"""Training substrate: optimizer math, loop convergence, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import ByteTokenizer, synthetic_batches
from repro.models.api import build_model
from repro.training import (TrainState, adamw_init, adamw_update,
                            clip_by_global_norm, cosine_schedule, train_loop)
from repro.training.checkpoint import restore, save

from conftest import assert_close


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32)}
    state = adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.01
    m = np.zeros((4, 3))
    v = np.zeros((4, 3))
    pw = np.asarray(p["w"]).astype(np.float64)
    for t in range(1, 6):
        g = rng.standard_normal((4, 3))
        p, state = adamw_update({"w": jnp.asarray(g, jnp.float32)}, state, p,
                                lr=lr, b1=b1, b2=b2, eps=eps,
                                weight_decay=wd)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh, vh = m / (1 - b1 ** t), v / (1 - b2 ** t)
        pw = pw - lr * (mh / (np.sqrt(vh) + eps) + wd * pw)
        assert_close(p["w"], pw.astype(np.float32), rtol=1e-5, atol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((6,), 4.0)}
    norm = float(np.sqrt(10 * 9 + 6 * 16))
    clipped, gnorm = clip_by_global_norm(g, 1.0)
    assert abs(float(gnorm) - norm) < 1e-4
    total = np.sqrt(sum(np.sum(np.square(np.asarray(x)))
                        for x in jax.tree_util.tree_leaves(clipped)))
    assert abs(total - 1.0) < 1e-4


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_frac=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert abs(float(lr(jnp.int32(100))) - 0.1) < 1e-6
    assert float(lr(jnp.int32(55))) > float(lr(jnp.int32(90)))


def test_loss_decreases_on_learnable_data(rng):
    cfg = get_smoke("granite-8b")
    model = build_model(cfg)
    data = synthetic_batches(4, 32, cfg.vocab_size, seed=0, cfg=cfg)
    state, hist = train_loop(model, data, steps=40, lr=2e-3, log_every=10,
                             log_fn=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.1


def test_moe_aux_loss_is_finite_and_learns(rng):
    cfg = get_smoke("granite-moe-1b-a400m")
    model = build_model(cfg)
    data = synthetic_batches(4, 32, cfg.vocab_size, seed=0, cfg=cfg)
    state, hist = train_loop(model, data, steps=30, lr=2e-3, log_every=10,
                             log_fn=lambda s: None)
    assert np.isfinite(hist[-1]["aux"])
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_roundtrip(rng):
    cfg = get_smoke("olmoe-1b-7b")
    model = build_model(cfg)
    params = model.init_params(rng)
    state = TrainState.create(params)
    save("/tmp/repro_ck_test.npz", state)
    target = jax.eval_shape(lambda: state)
    state2 = restore("/tmp/repro_ck_test.npz", target)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        state, state2)


def test_checkpoint_shape_mismatch_raises(rng):
    cfg = get_smoke("granite-8b")
    model = build_model(cfg)
    p = model.init_params(rng)
    save("/tmp/repro_ck_bad.npz", p)
    bad = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((1,) + s.shape, s.dtype),
        jax.eval_shape(lambda: p))
    with pytest.raises(ValueError):
        restore("/tmp/repro_ck_bad.npz", bad)


def test_tokenizer_roundtrip():
    tok = ByteTokenizer()
    s = "paged attention ✓ 分页"
    assert tok.decode(tok.encode(s)) == s


def test_microbatched_step_matches_plain(rng):
    """Grad accumulation must give the same update as one big batch."""
    from repro.configs.base import RunConfig
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import build_train_step, plan_for
    from repro.distributed.sharding import use_mesh

    cfg = get_smoke("granite-8b")
    run = RunConfig(model=cfg, seq_len=16, global_batch=4, kind="train")
    mesh = make_local_mesh()
    batch = {
        "inputs": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size),
        "targets": jax.random.randint(rng, (4, 16), 0, cfg.vocab_size),
    }
    outs = []
    for mb in (1, 2):
        plan = plan_for(run, mesh, microbatches=mb, attn_impl="jnp")
        step, _, _, model = build_train_step(run, plan, dtype=jnp.float32)
        params = model.init_params(jax.random.PRNGKey(1))
        with use_mesh(mesh, plan.rules):
            state, metrics = jax.jit(step)(TrainState.create(params), batch)
        outs.append((state, metrics))
    l1, l2 = float(outs[0][1]["loss"]), float(outs[1][1]["loss"])
    assert abs(l1 - l2) < 2e-4
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4),
        outs[0][0].params, outs[1][0].params)
