"""Numerical-equivalence suite — the paper's C1 claim, end to end.

  * prefill == teacher-forced forward (same logits at prompt end)
  * prefill_scanned == prefill (the dry-run path is the engine path)
  * prefill + N×decode_step == forward over the full sequence
  * paged decode == contiguous-cache decode (the paper's baseline)
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.models.api import build_model

from conftest import assert_close

ARCHS = ["granite-8b", "olmoe-1b-7b", "recurrentgemma-9b", "xlstm-350m",
         "llama-3.2-vision-11b", "whisper-medium", "nemotron-4-340b"]
B, S = 2, 24


def setup(arch, rng, dropless=False):
    cfg = get_smoke(arch)
    if dropless and cfg.is_moe:
        # capacity-bounded routing is a function of the GLOBAL token set, so
        # comparing runs over different token sets (prefix vs full) needs
        # dropless dispatch; same-set comparisons keep the production factor
        cfg = cfg.replace(moe_capacity=0.0)
    model = build_model(cfg)
    params = model.init_params(rng)
    extra = None
    if cfg.family == "vlm":
        extra = {"image_embeds": jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_vision))}
    if cfg.family == "encdec":
        extra = {"frames": jax.random.normal(
            rng, (B, cfg.n_audio_frames, cfg.d_model))}
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    return cfg, model, params, toks, extra


def fresh_state(model, cfg, seq_len=64):
    run = RunConfig(model=cfg, seq_len=seq_len, global_batch=B, kind="decode")
    st = model.init_decode_state(run)
    if "tables" in st:
        b, n_sh, pps = st["tables"].shape
        st["tables"] = jnp.arange(b * n_sh * pps,
                                  dtype=jnp.int32).reshape(b, n_sh, pps)
    return st


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_forward(arch, rng):
    cfg, model, params, toks, extra = setup(arch, rng)
    st = fresh_state(model, cfg)
    logits_p, _ = model.prefill(params, toks, st, extra=extra)
    full = model.forward(params, toks, extra)
    assert_close(logits_p, full[:, -1], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_scanned_matches_prefill(arch, rng):
    cfg, model, params, toks, extra = setup(arch, rng)
    if not hasattr(model, "prefill_scanned"):
        pytest.skip("encdec uses the unrolled prefill only")
    lens = jnp.asarray([S, S - 7], jnp.int32)
    st = fresh_state(model, cfg)
    l1, s1 = model.prefill(params, toks, dict(st), lens=lens, extra=extra)
    l2, s2 = model.prefill_scanned(params, toks, dict(st), lens=lens,
                                   extra=extra)
    assert_close(l1, l2, rtol=1e-4, atol=1e-4)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-4, atol=1e-4), s1, s2)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_chain_matches_forward(arch, rng):
    """Teacher-forced forward == prefill + step-by-step decode."""
    cfg, model, params, toks, extra = setup(arch, rng, dropless=True)
    n_pre = S // 2
    full = model.forward(params, toks, extra)

    st = fresh_state(model, cfg)
    logits, st = model.prefill(params, toks[:, :n_pre], st, extra=extra)
    assert_close(logits, full[:, n_pre - 1], rtol=1e-4, atol=1e-4)
    for t in range(n_pre, S):
        logits, st = model.decode_step(params, toks[:, t], st)
        assert_close(logits, full[:, t], rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_decode_equals_contiguous_baseline(rng, impl):
    """C1 at the attention-layer level with the real Pallas kernel."""
    cfg, model, params, toks, extra = setup("granite-8b", rng)
    full = model.forward(params, toks, extra)
    st = fresh_state(model, cfg)
    logits, st = model.prefill(params, toks[:, :S // 2], st, extra=extra)
    for t in range(S // 2, S):
        logits, st = model.decode_step(params, toks[:, t], st, impl=impl,
                                       interpret=True)
        assert_close(logits, full[:, t], rtol=2e-4, atol=2e-4)


def test_sliding_window_ring_reuse(rng):
    """Windowed layers: ring pages stay correct far past the window."""
    cfg = get_smoke("recurrentgemma-9b")
    model = build_model(cfg)
    params = model.init_params(rng)
    S_long = 80  # >> window=64 → ring wraps
    toks = jax.random.randint(rng, (B, S_long), 0, cfg.vocab_size)
    full = model.forward(params, toks, None)
    st = fresh_state(model, cfg, seq_len=128)
    logits, st = model.prefill(params, toks[:, :10], st)
    for t in range(10, S_long):
        logits, st = model.decode_step(params, toks[:, t], st)
    assert_close(logits, full[:, -1], rtol=3e-4, atol=3e-4)


def test_swa_variant_long_context_decode(rng):
    """The beyond-paper `swa` variant (long_500k path for dense archs):
    a dense model rebuilt with sliding-window layers decodes correctly
    past the window with a bounded ring cache."""
    from repro.configs.base import make_run
    cfg = get_smoke("granite-8b")
    run = make_run(cfg, "decode_32k", variant="swa")
    m_cfg = run.model.replace(window=32)  # smoke-sized window
    assert m_cfg.pattern() == "WW"
    model = build_model(m_cfg)
    params = model.init_params(rng)
    S_long = 48  # > window -> ring wraps
    toks = jax.random.randint(rng, (B, S_long), 0, m_cfg.vocab_size)
    full = model.forward(params, toks, None)
    st = fresh_state(model, m_cfg, seq_len=128)
    # ring pools are bounded regardless of seq_len
    ring_pages = -(-32 // m_cfg.page_size) + 1
    assert st["k_pages"].shape[1] == B * ring_pages
    logits, st = model.prefill(params, toks[:, :8], st)
    for t in range(8, S_long):
        logits, st = model.decode_step(params, toks[:, t], st)
    assert_close(logits, full[:, -1], rtol=3e-4, atol=3e-4)


def test_moe_router_determinism_across_paths(rng):
    """MoE: routing (incl. capacity drops) identical in forward vs prefill."""
    cfg, model, params, toks, extra = setup("olmoe-1b-7b", rng)
    assert cfg.moe_capacity > 0  # production capacity factor is on
    st = fresh_state(model, cfg)
    logits_p, _ = model.prefill(params, toks, st, extra=extra)
    full = model.forward(params, toks, extra)
    assert_close(logits_p, full[:, -1], rtol=1e-4, atol=1e-4)
