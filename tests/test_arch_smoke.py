"""Per-assigned-architecture smoke tests (harness contract).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model ≤ 512, ≤ 4 experts), run one forward/train step on CPU,
assert output shapes and no NaNs.  Decode-capable archs also run one
prefill + decode_step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke
from repro.configs.base import RunConfig
from repro.models.api import build_model

B, S = 2, 16


def batch_for(cfg, rng):
    ks = jax.random.split(rng, 3)
    out = {
        "inputs": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_image_tokens, cfg.d_vision))
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            ks[2], (B, cfg.n_audio_frames, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_reduction_contract(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers == 2
    assert cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_and_train_step(arch, rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init_params(rng)
    batch = batch_for(cfg, rng)

    logits = model.forward(params, batch["inputs"],
                           {k: v for k, v in batch.items()
                            if k not in ("inputs", "targets")} or None)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()

    # one full train step (loss -> grads -> AdamW update)
    from repro.training.loop import make_train_step
    from repro.training.state import TrainState
    step = jax.jit(make_train_step(model, lr=1e-3))
    state = TrainState.create(params)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode_step(arch, rng):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init_params(rng)
    run = RunConfig(model=cfg, seq_len=32, global_batch=B, kind="decode")
    state = model.init_decode_state(run)
    if "tables" in state:
        b, n_sh, pps = state["tables"].shape
        state["tables"] = jnp.arange(b * n_sh * pps,
                                     dtype=jnp.int32).reshape(b, n_sh, pps)
    extra = None
    if cfg.family == "vlm":
        extra = {"image_embeds": jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_vision))}
    if cfg.family == "encdec":
        extra = {"frames": jax.random.normal(
            rng, (B, cfg.n_audio_frames, cfg.d_model))}
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    lens = jnp.asarray([S, S - 5], jnp.int32)
    logits, state = model.prefill(params, toks, state, lens=lens, extra=extra)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    logits2, state2 = model.decode_step(
        params, jnp.asarray([3, 5], jnp.int32), state)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits2)).any()
    assert (np.asarray(state2["pos"]) == np.asarray(state["pos"]) + 1).all()


def test_all_full_configs_match_assignment():
    """The full configs carry the exact assigned hyperparameters."""
    expect = {
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (L, d, H, kv, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == H, arch
        assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch
    moe = get_config("granite-moe-1b-a400m")
    assert moe.n_experts == 32 and moe.top_k == 8
    olmoe = get_config("olmoe-1b-7b")
    assert olmoe.n_experts == 64 and olmoe.top_k == 8
    assert get_config("nemotron-4-340b").activation == "relu2"
    assert get_config("recurrentgemma-9b").layer_pattern.count("R") == 2
