"""End-to-end training driver: train a ~small model for a few hundred
steps on the synthetic LM stream, checkpoint, and evaluate with the paged
decode path (proving train → serve round-trip through one nn-module, the
paper's "training, fine-tuning, and inference share the same module"
portability argument).

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data import synthetic_batches
from repro.models.api import build_model
from repro.serving import Engine, Request
from repro.training import train_loop
from repro.training.checkpoint import restore, save


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="granite-8b")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    model = build_model(cfg)
    data = synthetic_batches(8, 64, cfg.vocab_size, seed=0, cfg=cfg)

    print(f"training {cfg.name}: {args.steps} steps, batch 8 x 64")
    state, hist = train_loop(model, data, steps=args.steps, lr=1e-3,
                             log_every=max(args.steps // 10, 1))
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    save("/tmp/train_small.npz", state.params)
    params = restore("/tmp/train_small.npz",
                     jax.eval_shape(lambda: state.params))
    print("checkpoint saved + restored")

    # serve the trained weights through the paged engine
    eng = Engine(cfg, params=params, max_slots=2, max_seq_len=128)
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=12)]
    eng.generate(reqs)
    print(f"greedy continuation from trained model: {reqs[0].output}")

    # eval perplexity with the paged cache vs teacher-forced (C1)
    toks = jnp.asarray(next(synthetic_batches(2, 32, cfg.vocab_size,
                                              seed=7))["inputs"])
    logits = model.forward(params, toks)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    gold = jnp.take_along_axis(lp[:, :-1], toks[:, 1:, None], 2)[..., 0]
    print(f"teacher-forced eval loss: {float(-gold.mean()):.4f} "
          f"(ppl {float(jnp.exp(-gold.mean())):.2f})")


if __name__ == "__main__":
    main()
