"""End-to-end serving driver: batched requests through the paged engine.

The paper's §IV scenario (b): a mixed-length wave of requests served by
continuous batching on an oversubscribed page pool, compared against the
contiguous-baseline engine under the SAME byte budget. Prints throughput,
TTFT percentiles, preemption counts, and the memory ledger.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch granite-8b]
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.serving import Engine, Request


def wave(rng, n, max_prompt, max_new):
    return [Request(prompt=rng.integers(0, 256,
                                        size=int(rng.integers(8, max_prompt))
                                        ).tolist(),
                    max_new_tokens=max_new) for _ in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--impl", default="ref", choices=["ref", "pallas"],
                    help="decode attention op: jnp oracle or Pallas kernel")
    ap.add_argument("--pages-per-block", type=int, default=None,
                    help="Pallas kernel KV-block width (default: auto)")
    ap.add_argument("--num-splits", type=int, default=None,
                    help="Pallas kernel split-K factor (default: auto)")
    ap.add_argument("--combine-mode", default=None,
                    choices=["jnp", "pallas"],
                    help="split-K merge: fused Pallas combine kernel or "
                         "jnp epilogue (default: auto — pallas iff split-K)")
    ap.add_argument("--backend", default=None, choices=["tpu", "gpu"],
                    help="Pallas kernel lowering: TPU scalar-prefetch "
                         "pipeline or GPU/Triton in-kernel gather "
                         "(default: auto from jax.default_backend())")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens prefilled per engine step "
                         "(chunked continuous batching: prompts cache "
                         "chunk-by-chunk interleaved with decode, so a "
                         "long prompt never stalls the running batch; "
                         "default: whole prompt in one monolithic pass)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="enable the global radix prefix cache, and give "
                         "the wave a shared 48-token system-prompt head: "
                         "requests admitted after the first slot wave "
                         "attach to the cached head pages and prefill "
                         "only their own tail")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    slots, max_seq, pool = 8, 128, 640
    rng = np.random.default_rng(0)

    chunk = ("monolithic" if args.prefill_chunk is None
             else f"{args.prefill_chunk} tok/step")
    print(f"== paged engine: {slots} slots, pool {pool} tokens, "
          f"impl={args.impl}, prefill={chunk} ==")
    eng = Engine(cfg, max_slots=slots, max_seq_len=max_seq,
                 pool_tokens=pool, impl=args.impl,
                 pages_per_block=args.pages_per_block,
                 num_splits=args.num_splits,
                 combine_mode=args.combine_mode,
                 backend=args.backend,
                 prefill_chunk=args.prefill_chunk,
                 prefix_cache=args.prefix_cache)
    head = [7] * 48 if args.prefix_cache else []
    reqs = wave(rng, args.requests,
                max_seq - args.max_new - len(head), args.max_new)
    for r in reqs:
        r.prompt = head + r.prompt
    t0 = time.perf_counter()
    eng.generate(reqs, max_steps=3000)
    wall = time.perf_counter() - t0
    new_toks = sum(len(r.output) for r in reqs)
    ttfts = sorted(r.metrics["ttft_s"] for r in reqs)
    print(f"{new_toks} tokens in {wall:.1f}s = {new_toks/wall:.2f} tok/s; "
          f"ttft p50 {ttfts[len(ttfts)//2]:.2f}s "
          f"p95 {ttfts[int(len(ttfts)*0.95)]:.2f}s; "
          f"preemptions {eng.scheduler.preempted}; "
          f"prefill stalls {eng.scheduler.prefill_stalls}")
    print(eng.memory_report())
    if args.prefix_cache:
        rep = eng.robustness_report()
        print(f"prefix cache: {rep['prefix_hits']} hits / "
              f"{rep['prefix_misses']} misses, "
              f"{rep['prefix_hit_tokens']} prompt tokens skipped "
              f"({rep['prefix_hit_tokens'] // cfg.page_size} pages), "
              f"{rep['prefix_evicted_pages']} pages evicted")

    # contiguous baseline under the same KV byte budget -> fewer slots
    slots_c = max(1, pool // max_seq)
    print(f"\n== contiguous baseline: {slots_c} slots (same bytes) ==")
    eng2 = Engine(cfg, params=eng.params, paged=False, max_slots=slots_c,
                  max_seq_len=max_seq)
    reqs2 = wave(np.random.default_rng(0), args.requests,
                 max_seq - args.max_new, args.max_new)
    t0 = time.perf_counter()
    eng2.generate(reqs2, max_steps=3000)
    wall2 = time.perf_counter() - t0
    new2 = sum(len(r.output) for r in reqs2)
    print(f"{new2} tokens in {wall2:.1f}s = {new2/wall2:.2f} tok/s")
    print(f"\npaged speedup at equal memory: {new_toks/wall/(new2/wall2):.2f}x")


if __name__ == "__main__":
    main()
