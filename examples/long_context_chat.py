"""Paper §IV scenario (c): growing-context chat.

One conversation grows turn by turn; the paged cache extends page-by-page
(never reallocating or copying the KV history), and a *fork* shares the
conversation prefix with a speculative second branch copy-on-write — the
paper's prefix-sharing trick.

Run:  PYTHONPATH=src python examples/long_context_chat.py
"""

import numpy as np

from repro.configs import get_config
from repro.core.paging import HostPageManager
from repro.data import ByteTokenizer
from repro.serving import Engine, Request


def main():
    cfg = get_config("llama2-7b").smoke()
    tok = ByteTokenizer()
    eng = Engine(cfg, max_slots=2, max_seq_len=512, pool_tokens=1024)

    history = tok.encode("User: Explain paged attention.\nAssistant:")
    for turn in range(4):
        req = Request(prompt=list(history), max_new_tokens=12,
                      temperature=0.7, top_k=50)
        eng.generate([req])
        history += req.output + tok.encode(
            f"\nUser: tell me more ({turn}).\nAssistant:", bos=False)
        used = eng.mgr.used_pages
        print(f"turn {turn}: context {len(history):4d} tokens "
              f"(pages used at peak this turn: {used})")

    # prefix sharing: fork a RUNNING conversation into two branches —
    # the child aliases the parent's full KV pages (refcount++), copies
    # only the partial tail page, and decodes immediately (no re-prefill).
    parent = Request(prompt=list(history), max_new_tokens=24,
                     temperature=0.8, top_k=50)
    eng.add_request(parent)
    while len(parent.output) < 8:
        eng.step()
    before = eng.mgr.used_pages
    child = eng.fork_request(parent, max_new_tokens=8, temperature=1.2,
                             top_k=50)
    print(f"\nforked at {parent.total_len} tokens: +{eng.mgr.used_pages - before} "
          f"page(s) allocated (copy-on-write; "
          f"{parent.total_len // cfg.page_size} pages shared)")
    while not (parent.done and child.done):
        eng.step()
    print(f"parent branch: ...{parent.output[-8:]}")
    print(f"child  branch: ...{child.output}")
    print(f"child ttft: {child.metrics['ttft_s']:.3f}s (no prefill — "
          f"prefix shared)")


if __name__ == "__main__":
    main()
