"""Quickstart: the paper's technique in 60 lines.

Builds a small LLaMA-style model, turns on PagedAttention with one config
flag (the paper's "drop-in deployability"), serves a few requests through
the continuous-batching engine, and prints the memory accounting that
motivates the whole paper.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_config
from repro.data import ByteTokenizer
from repro.serving import Engine, Request


def main():
    # 1. pick a model config; .smoke() gives the CPU-runnable reduction
    cfg = get_config("llama2-7b").smoke()
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model}, "
          f"page_size={cfg.page_size}, paged={cfg.paged_attention})")

    # 2. an engine with an intentionally small page pool: 4 slots x 256
    #    max tokens would need 1024 tokens of KV; we give it 512 and let
    #    the scheduler admit/preempt (the paper's memory win)
    eng = Engine(cfg, max_slots=4, max_seq_len=256, pool_tokens=512)

    # 3. requests (byte-tokenized text prompts of mixed length)
    tok = ByteTokenizer()
    prompts = [
        "Paged attention partitions the KV cache into fixed-size pages.",
        "A block table maps logical positions to physical pages.",
        "Short prompt.",
        "Fragmentation wastes 60-80% of KV memory in mixed batches, " * 3,
    ]
    reqs = [Request(prompt=tok.encode(p)[:200], max_new_tokens=16,
                    temperature=0.8, top_k=40) for p in prompts]

    # 4. run the continuous-batching loop to completion
    eng.generate(reqs)

    for r in reqs:
        print(f"req {r.rid}: {r.prompt_len:3d} prompt tokens -> "
              f"{len(r.output)} new, ttft {r.metrics['ttft_s']*1e3:.0f} ms, "
              f"{r.metrics['tok_s']:.1f} tok/s")
    print(f"engine steps: {eng.steps}, preemptions: {eng.scheduler.preempted}")

    # 5. the paper's point: near-zero waste vs max-length preallocation
    rep = eng.memory_report()
    contiguous = 4 * 256  # slots x max_seq_len tokens
    print(f"paged pool: {eng.num_pages} pages "
          f"({eng.num_pages * cfg.page_size} tokens) vs contiguous "
          f"reservation {contiguous} tokens")
    print(f"post-run overhead vs theoretical minimum: "
          f"{rep['overhead_frac']*100:.1f}%")


if __name__ == "__main__":
    main()
