"""Paper contribution 1: O(1) lock-free allocator — latency microbench.

RESERVE/FREE wall time must be independent of pool occupancy and pool
size (the paper's "constant-time allocation off the critical path").
Measured for the host mirror (scheduler path) and the jitted device state
machine (decode path).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table
from repro.core import paging
from repro.core.paging import HostPageManager


def host_alloc_us(num_pages: int, occupancy: float) -> float:
    mgr = HostPageManager(num_pages=num_pages, page_size=64)
    n_busy = int(num_pages * occupancy)
    seq = 0
    while mgr.used_pages < n_busy:
        mgr.reserve(seq, 64 * min(16, n_busy - mgr.used_pages))
        seq += 1
    # measure single-page extend + free cycles at this occupancy
    t0 = time.perf_counter()
    iters = 2000
    for i in range(iters):
        mgr.reserve(10_000, 64)
        mgr.free(10_000)
    return (time.perf_counter() - t0) / iters / 2 * 1e6


def run(fast: bool = False):
    t = Table("tbl_allocator",
              ["pool_pages", "occupancy", "host_us_per_op",
               "device_us_per_op"])
    sizes = [1024, 16384] if fast else [1024, 16384, 131072]
    for num_pages in sizes:
        for occ in (0.0, 0.5, 0.9):
            dev = device_alloc_us(num_pages)
            t.add(num_pages, occ, round(host_alloc_us(num_pages, occ), 3),
                  round(dev, 1))
    t.show()
    # O(1) check: latency at 128k pages within 3x of 1k pages
    host = {(r[0], r[1]): r[2] for r in t.rows}
    big = host[(sizes[-1], 0.9)]
    small = host[(sizes[0], 0.0)]
    t.add("o1_ratio", round(big / max(small, 1e-9), 2), "", "")
    t.show()
    return t


_dev_cache = {}


def device_alloc_us(num_pages: int) -> float:
    """Jitted reserve+free cycle on the functional device state."""
    if num_pages not in _dev_cache:
        state = paging.init_state(num_pages, max_seqs=8, max_pages_per_seq=8)

        @jax.jit
        def cycle(st):
            st = paging.reserve(st, jnp.int32(0), jnp.int32(64), 64)
            return paging.free(st, jnp.int32(0), 64)

        cycle(state)  # compile
        _dev_cache[num_pages] = (cycle, state)
    cycle, state = _dev_cache[num_pages]
    t0 = time.perf_counter()
    iters = 200
    for _ in range(iters):
        state = cycle(state)
    jax.block_until_ready(state.free_top)
    return (time.perf_counter() - t0) / iters / 2 * 1e6
