"""Paper §III-B1: page size ℓ_p grid search (64-128 "chosen via
grid-search to minimize table overhead while keeping memory reads
coalesced").

The trade-off the paper searched over, reproduced with exact accounting:
  * smaller pages → less tail waste (overhead ↓) but more block-table
    entries + more DMA descriptors per token (table overhead ↑, and on
    TPU the page must still tile the (8,128) VMEM register file);
  * larger pages → fewer, bigger DMAs but more tail waste.

Columns: memory overhead vs theoretical min (paper's <5% objective),
block-table entries per 32k sequence (scheduler metadata), DMA grid steps
per decode token (kernel work), MXU-aligned (page a multiple of the 8-row
sublane tile at bf16).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Table
from repro.core.paging import HostPageManager


def run(fast: bool = False):
    t = Table("tbl_pagesize",
              ["page_size", "overhead", "table_entries_32k",
               "grid_steps_32k", "mxu_aligned"])
    rng = np.random.default_rng(0)
    lens = rng.integers(256, 8192, size=64)  # mixed-batch trace
    for ps in (8, 16, 32, 64, 128, 256, 512):
        mgr = HostPageManager(num_pages=int(lens.sum() // ps + 64 + 1),
                              page_size=ps)
        for i, ln in enumerate(lens):
            assert mgr.reserve(i, int(ln))
        t.add(ps, f"{mgr.overhead_frac():.3%}", -(-32768 // ps),
              -(-32768 // ps), "yes" if ps % 8 == 0 else "no")
    t.show()
    # the paper's chosen band: 64-128 keeps overhead ~1% with 256-512
    # table entries; our production configs use 64
    return t
