"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json (run after repro.launch.dryrun sweeps).

Usage: PYTHONPATH=src python -m benchmarks.make_experiments_tables
Prints markdown to stdout.
"""

from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["nemotron-4-340b", "granite-moe-1b-a400m", "olmoe-1b-7b",
              "xlstm-350m", "llama3-405b", "nemotron-4-15b",
              "llama-3.2-vision-11b", "whisper-medium", "granite-8b",
              "recurrentgemma-9b"]


def load(outdir="experiments/dryrun"):
    data = {}
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("tag"):
            continue  # perf-iteration runs handled separately
        data[(r["arch"], r["shape"], r["mesh"])] = r
    return data


def gib(x):
    return f"{x/2**30:.2f}"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f} s"
    if x >= 1e-3:
        return f"{x*1e3:.2f} ms"
    return f"{x*1e6:.0f} µs"


def dryrun_table(data):
    print("| arch | shape | pod: peak GiB/dev (TPU-est) | compile s | "
          "scheme | mb | multipod: peak GiB/dev | status |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            pod = data.get((arch, shape, "pod"))
            mp = data.get((arch, shape, "multipod"))
            if pod is None:
                continue
            if pod["status"] == "skipped":
                print(f"| {arch} | {shape} | — | — | — | — | — | "
                      f"skipped: {pod['reason'][:60]}… |")
                continue
            if pod["status"] != "ok":
                print(f"| {arch} | {shape} | — | — | — | — | — | ERROR |")
                continue
            m = pod["memory"]
            pk = f"{gib(m['peak_bytes'])} ({gib(m.get('peak_bytes_tpu_est', m['peak_bytes']))})"
            plan = pod["plan"]
            mpk = "—"
            status = "ok (pod)"
            if mp and mp["status"] == "ok":
                mm = mp["memory"]
                mpk = f"{gib(mm['peak_bytes'])} ({gib(mm.get('peak_bytes_tpu_est', mm['peak_bytes']))})"
                status = "ok (pod+multipod)"
            print(f"| {arch} | {shape} | {pk} | {pod['compile_s']} | "
                  f"{plan['scheme']} | {plan['microbatches']} | {mpk} | "
                  f"{status} |")


def roofline_table(data):
    print("| arch | shape | compute | memory | collective | bottleneck | "
          "useful frac | coll. mix (top) |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            pod = data.get((arch, shape, "pod"))
            if pod is None or pod.get("status") != "ok" or "roofline" not in pod:
                continue
            r = pod["roofline"]
            coll = pod["collectives_full"]
            top = max((k for k in coll if k != "total"),
                      key=lambda k: coll[k], default="-")
            uf = r["useful_frac"]
            uf_s = ("n/a (time-scan)" if arch == "xlstm-350m"
                    and shape in ("train_4k", "prefill_32k")
                    else f"{uf:.2f}")
            print(f"| {arch} | {shape} | {fmt_s(r['compute_s'])} | "
                  f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
                  f"{r['bottleneck'].replace('_s','')} | {uf_s} | {top} |")


def main():
    data = load()
    print("### §Dry-run — 40-pair baseline (single-pod 16×16 + multi-pod "
          "2×16×16)\n")
    dryrun_table(data)
    print("\n### §Roofline — three-term analysis (single-pod, v5e "
          "constants)\n")
    roofline_table(data)


if __name__ == "__main__":
    main()
