"""§Roofline: aggregate the dry-run JSONs into the per-(arch × shape)
three-term roofline table (EXPERIMENTS.md source of truth).

Reads experiments/dryrun/*.json produced by repro.launch.dryrun.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Table


def load(outdir="experiments/dryrun"):
    rows = []
    for p in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def run(fast: bool = False):
    t = Table("roofline",
              ["arch", "shape", "mesh", "tag", "compute", "memory",
               "collective", "bottleneck", "useful_frac", "peak_GiB"])
    for r in load():
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        t.add(r["arch"], r["shape"], r["mesh"], r.get("tag", ""),
              fmt_s(rf["compute_s"]), fmt_s(rf["memory_s"]),
              fmt_s(rf["collective_s"]),
              rf["bottleneck"].replace("_s", ""),
              round(rf["useful_frac"], 3),
              round(r["memory"]["peak_bytes"] / 2**30, 2))
    t.show()
    return t
