"""Paper §IV-B3: numerical equivalence — eval loss with the paged cache
vs the contiguous baseline vs teacher-forced forward.

The paper reports WikiText-103 perplexity 7.32 (baseline) vs 7.31 (paged):
identical up to kernel-order noise.  We train a small model briefly, then
evaluate the SAME weights three ways; losses must agree to ~1e-4.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table
from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.data import synthetic_batches
from repro.models.api import build_model
from repro.training import train_loop


def eval_loss_decode(model, params, toks, paged: bool) -> float:
    """Next-token NLL via step-by-step decode (prefill 1 + decode rest)."""
    B, S = toks.shape
    cfg = model.cfg
    run = RunConfig(model=cfg, seq_len=S + 8, global_batch=B, kind="decode")
    nll = []
    if paged:
        st = model.init_decode_state(run)
        b, n_sh, pps = st["tables"].shape
        st["tables"] = jnp.arange(b * n_sh * pps,
                                  dtype=jnp.int32).reshape(b, n_sh, pps)
        logits, st = model.prefill(params, toks[:, :1], st)
    else:
        from repro.serving.engine import Engine  # baseline path lives there
        st = None
        logits = None
    if paged:
        step = jax.jit(lambda p, tk, s: model.decode_step(p, tk, s))
        for t in range(1, S):
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll.append(-np.asarray(
                jnp.take_along_axis(lp, toks[:, t][:, None], 1))[:, 0])
            logits, st = step(params, toks[:, t], st)
        return float(np.mean(nll))
    raise NotImplementedError


def run(fast: bool = False):
    cfg = get_smoke("llama2-7b")
    model = build_model(cfg)
    data = synthetic_batches(4, 32, cfg.vocab_size, seed=0, cfg=cfg)
    state, _ = train_loop(model, data, steps=10 if fast else 30, lr=2e-3,
                          log_every=100, log_fn=lambda s: None)
    params = state.params

    toks = next(synthetic_batches(2, 24, cfg.vocab_size, seed=9))["inputs"]
    toks = jnp.asarray(toks)

    # teacher-forced reference
    logits = model.forward(params, toks)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(lp[:, :-1], toks[:, 1:, None], 2)[..., 0]
    loss_fwd = float(-jnp.mean(gold))

    loss_paged = eval_loss_decode(model, params, toks, paged=True)

    t = Table("tbl_perplexity", ["path", "loss", "ppl"])
    t.add("teacher_forced", round(loss_fwd, 6), round(np.exp(loss_fwd), 4))
    t.add("paged_decode", round(loss_paged, 6), round(np.exp(loss_paged), 4))
    t.add("delta", round(abs(loss_fwd - loss_paged), 8),
          "equivalent" if abs(loss_fwd - loss_paged) < 5e-4 else "MISMATCH")
    t.show()
    assert abs(loss_fwd - loss_paged) < 5e-4
    return t
