"""Paper Figs. 1-2 + §IV-B1: peak KV memory, paged vs baseline allocator.

Exact byte accounting from the engine's page manager:
  * mixed-length batch (the paper's fragmentation scenario, §I): paged
    reserves only the pages touched; the baseline reserves
    max_seq_len × slots.
  * growing context (§IV scenario c): paged memory rises in page-sized
    (power-of-two pool) increments, baseline is flat at the max.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import Table
from repro.configs import get_smoke
from repro.core.paging import HostPageManager


def run(fast: bool = False):
    cfg = get_smoke("llama2-7b")
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    ps = 64

    # --- mixed batch (paper: lengths 500..8000, 16 requests) -------------
    t = Table("fig12_memory_mixed",
              ["batch", "paged_MiB", "contig_MiB", "paged_overhead",
               "contig_waste"])
    rng = np.random.default_rng(0)
    max_len = 8192
    for n_req in (4, 8, 16):
        lens = rng.integers(500, 8000, size=n_req)
        mgr = HostPageManager(num_pages=n_req * max_len // ps, page_size=ps)
        for i, ln in enumerate(lens):
            assert mgr.reserve(i, int(ln))
        paged = mgr.bytes_reserved(Hkv, D, L)
        minimum = mgr.bytes_theoretical_min(Hkv, D, L)
        contig = n_req * max_len * 2 * L * Hkv * D * 2
        t.add(n_req, round(paged / 2**20, 1), round(contig / 2**20, 1),
              f"{paged/minimum-1:.3%}", f"{1-minimum/contig:.1%}")
    t.show()

    # --- growing context (chat growth 1k → 32k) ---------------------------
    t2 = Table("fig12_memory_growth",
               ["context", "paged_pages", "paged_MiB", "contig_MiB"])
    per_page = ps * Hkv * D * 2 * L * 2
    for S in (1024, 2048, 4096, 8192, 16384, 32768):
        mgr = HostPageManager(num_pages=32768 // ps, page_size=ps)
        mgr.reserve(0, S)
        t2.add(S, mgr.used_pages, round(mgr.used_pages * per_page / 2**20, 1),
               round(32768 * per_page / ps / 2**20, 1))
    t2.show()
    t.rows += [[f"growth_{r[0]}", *r[1:]] for r in t2.rows]
    return t
