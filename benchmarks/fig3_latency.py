"""Paper Fig. 3: inference latency vs sequence length, with/without the
global KV cache.

The paper's claim (C2): with the paged KV cache, per-token latency grows
~linearly as context grows 128→2048; without caching (re-running the full
prefix every token) it grows ~like the square (reported "exponential" —
~10× per doubling on their stack).  We reproduce the *scaling shapes* on
CPU with the reduced model; absolute numbers are CPU-scale.

Second axis (prefix-cache PR): the same latency-vs-context question one
level up — TTFT for a *repeated* prompt, cold vs warm through the global
prefix cache.  A warm hit skips the cached pages' prefill entirely, so
warm TTFT stays ~flat in the shared-prefix length while cold TTFT grows
with it.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, Tables, timeit
from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.models.api import build_model

SEQ_LENS = [128, 256, 512, 1024, 2048]


def _prefix_cache_axis(fast: bool) -> Table:
    """Engine-level TTFT for an identical prompt, cold vs warm."""
    from repro.serving import Engine, Request

    cfg = get_smoke("llama2-7b")
    probe = Engine(cfg, max_slots=1, max_seq_len=8)  # params donor
    lens = [64, 128] if fast else [64, 128, 256]
    t = Table("fig3_prefix_cache",
              ["prompt_len", "cold_ms", "warm_ms", "ttft_ratio",
               "hit_tokens", "pages_saved"])
    for L in lens:
        eng = Engine(cfg, params=probe.params, max_slots=2,
                     max_seq_len=L + 16, prefix_cache=True)

        def ttft(tok, L=L, eng=eng):
            r = Request(prompt=[tok] * L, max_new_tokens=2)
            eng.add_request(r)
            t0 = time.perf_counter()
            while not r.output and not r.done:
                eng.step()
            dt = (time.perf_counter() - t0) * 1e3
            while not r.done:
                eng.step()
            return dt, r

        # compile both code paths off the clock (the warm resume runs a
        # different prefill shape than the cold monolithic pass)
        ttft(3)
        ttft(3)
        cold_ms, _ = ttft(5)   # distinct tokens: guaranteed cache miss
        warm_ms, r = ttft(5)   # identical prompt: attach + suffix only
        assert r.cached_prefix > 0, "warm run never hit the cache"
        t.add(L, round(cold_ms, 2), round(warm_ms, 2),
              round(cold_ms / max(warm_ms, 1e-9), 2), r.cached_prefix,
              r.cached_prefix // cfg.page_size)
    t.show()
    return t


def run(fast: bool = False, backend: str = None, prefix_cache: str = None):
    cfg = get_smoke("llama2-7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    seq_lens = SEQ_LENS[:3] if fast else SEQ_LENS
    t = Table("fig3_latency",
              ["seq_len", "backend", "cached_us_tok", "uncached_us_tok",
               "ratio"])
    bk = backend or "auto"

    # --backend picks the cached path's decode-kernel lowering (the
    # oracle impl ignores it; impl="pallas" exercises it end-to-end)
    impl = "ref" if backend is None else "pallas"
    decode = jax.jit(lambda p, tok, st: model.decode_step(
        p, tok, st, impl=impl, backend=backend,
        interpret=True if backend is not None else None))
    forward = jax.jit(lambda p, toks: model.forward(p, toks))

    rows = []
    for S in seq_lens:
        B = 1
        run_cfg = RunConfig(model=cfg, seq_len=S + 8, global_batch=B,
                            kind="decode")
        st = model.init_decode_state(run_cfg)
        b, n_sh, pps = st["tables"].shape
        st["tables"] = jnp.arange(b * n_sh * pps,
                                  dtype=jnp.int32).reshape(b, n_sh, pps)
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        _, st = model.prefill(params, toks, st)
        tok = jnp.ones((B,), jnp.int32)

        # cached: one decode step against an S-token cache
        t_cached = timeit(decode, params, tok, st)
        # uncached: regenerate the whole prefix every new token
        t_uncached = timeit(forward, params, toks)
        rows.append((S, t_cached, t_uncached))
        t.add(S, bk, round(t_cached * 1e6, 1), round(t_uncached * 1e6, 1),
              round(t_uncached / t_cached, 1))

    # C2 scaling check: cached grows sub-linearly vs uncached growth
    c0, cN = rows[0][1], rows[-1][1]
    u0, uN = rows[0][2], rows[-1][2]
    span = rows[-1][0] / rows[0][0]
    t.add("growth_x", bk, round(cN / c0, 2), round(uN / u0, 2),
          f"context x{span:.0f}")
    t.show()
    if prefix_cache == "off":
        return t
    return Tables(t, _prefix_cache_axis(fast))
