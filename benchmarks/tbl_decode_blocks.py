"""Decode-kernel tuning sweep: backend × pages_per_block × num_splits ×
combine_mode.

For each knob combination this reports the grid-step count per
(batch, kv_head) pair, interpret-mode wall time, and max abs error vs the
jnp oracle — so a perf win is never a silent correctness loss.  Each
(ppb, splits) point runs under both split-K combine implementations
("jnp" epilogue vs the fused "pallas" kernel); ``jnp_vs_pallas`` is the
max abs divergence between the two, the bench-level echo of the
conformance suite's 1e-5 gate.

The ``backend`` axis runs the same sweep through both kernel lowerings —
the TPU scalar-prefetch pipeline and the GPU/Triton in-kernel gather —
each with its own auto-tuned row (`choose_decode_params` targets
MXU-width blocks on TPU, warp-width on GPU).  ``--backend tpu|gpu``
restricts the axis; default sweeps both.

``grid_steps`` is the hardware-relevant metric: on a real TPU each grid
step pays fixed pipeline overhead and a sliver-shaped matmul, so fewer,
fatter steps (ppb·page_size = 128 KV tokens) feed the MXU at full width,
and split-K adds parallel grid slots for long single sequences.  On GPU
the same count is CTAs' inner-loop trips; split-K there buys SM
occupancy.  ``us_per_call`` is CPU interpret mode, where python-level
per-*page* work dominates instead — it validates semantics and tracks
relative knob cost, not hardware speed.

The ``auto`` rows are `choose_decode_params`, the heuristic the serving
engine uses when the knobs are left unset.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from benchmarks.common import Table, timeit
from repro.core.attention import choose_decode_params, decode_attention
from repro.kernels.paged_attention.paged_attention import decode_grid_steps

PAGE_SIZE = 16
SEQ_LEN = 1024
B = 2
HKV, G, D = 2, 4, 64  # GQA 4:1
BACKENDS = ("tpu", "gpu")


def _case(seq_len: int):
    mp = -(-seq_len // PAGE_SIZE)
    H = HKV * G
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp = jax.random.normal(ks[1], (B * mp, PAGE_SIZE, HKV, D))
    vp = jax.random.normal(ks[2], (B * mp, PAGE_SIZE, HKV, D))
    bt = jnp.arange(B * mp, dtype=jnp.int32).reshape(B, mp)
    lens = jnp.asarray([seq_len, seq_len - 3 * PAGE_SIZE - 5], jnp.int32)
    return q, kp, vp, bt, lens, mp


def run(fast: bool = False, backend: Optional[str] = None):
    seq_len = 256 if fast else SEQ_LEN
    q, kp, vp, bt, lens, mp = _case(seq_len)
    ref = decode_attention(q, kp, vp, bt, lens, impl="ref")

    sweep = ([(1, 1), (8, 1), (8, 4)] if fast else
             [(1, 1), (2, 1), (4, 1), (8, 1), (8, 2), (8, 4), (8, 8),
              (4, 4), (16, 4)])
    backends = (backend,) if backend else BACKENDS

    t = Table(f"tbl_decode_blocks_s{seq_len}",
              ["backend", "ppb_x_splits", "combine", "us_per_call",
               "grid_steps", "max_abs_err", "jnp_vs_pallas"])
    for be in backends:
        # label rows with the *effective* (clamped) knobs, deduped — a
        # short sequence clamps num_splits down and a mislabeled row would
        # read as "split-K costs more for nothing"
        ppb_a, ns_a, cm_auto = choose_decode_params(mp, PAGE_SIZE, D,
                                                    backend=be)
        rows = [("auto", ppb_a, ns_a)]
        seen = {(ppb_a, ns_a)}
        for req in sweep:
            ppb_e, ns_e, _ = choose_decode_params(mp, PAGE_SIZE, D, *req,
                                                  backend=be)
            if (ppb_e, ns_e) not in seen:
                seen.add((ppb_e, ns_e))
                rows.append(("fixed", ppb_e, ns_e))

        for tag, ppb, ns in rows:
            steps = decode_grid_steps(mp, pages_per_block=ppb, num_splits=ns)
            label = f"{ppb}x{ns}" + ("_auto" if tag == "auto" else "")
            outs, uss, errs = {}, {}, {}
            for cm in ("jnp", "pallas"):
                fn = jax.jit(
                    lambda q, kp, vp, bt, l, ppb=ppb, ns=ns, cm=cm, be=be:
                    decode_attention(q, kp, vp, bt, l, impl="pallas",
                                     interpret=True, pages_per_block=ppb,
                                     num_splits=ns, combine_mode=cm,
                                     backend=be))
                uss[cm] = timeit(fn, q, kp, vp, bt, lens,
                                 warmup=1, iters=2) * 1e6
                outs[cm] = fn(q, kp, vp, bt, lens)
                errs[cm] = float(jnp.max(jnp.abs(outs[cm] - ref)))
            div = float(jnp.max(jnp.abs(outs["jnp"] - outs["pallas"])))
            for cm in ("jnp", "pallas"):
                # '*' marks the mode the auto-tuner picks for these knobs
                star = "*" if (tag == "auto" and cm == cm_auto) else ""
                t.add(be, label, cm + star, round(uss[cm], 1), steps,
                      f"{errs[cm]:.2e}", f"{div:.2e}")
    t.show()
    return t
