"""Shared benchmark helpers."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import numpy as np


def timeit(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (block_until_ready-aware)."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        r = fn(*args)
        jax.block_until_ready(r)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


class Table:
    """Collects rows; prints aligned text + the harness CSV contract."""

    def __init__(self, name: str, columns: List[str]):
        self.name = name
        self.columns = columns
        self.rows: List[List] = []

    def add(self, *row):
        self.rows.append(list(row))

    def show(self):
        print(f"\n== {self.name} ==")
        widths = [max(len(str(c)), *(len(str(r[i])) for r in self.rows))
                  if self.rows else len(str(c))
                  for i, c in enumerate(self.columns)]
        print("  ".join(str(c).ljust(w) for c, w in zip(self.columns, widths)))
        for r in self.rows:
            print("  ".join(str(x).ljust(w) for x, w in zip(r, widths)))

    def csv_lines(self) -> List[str]:
        """name,us_per_call,derived rows for benchmarks.run's contract."""
        out = []
        for r in self.rows:
            out.append(f"{self.name}/{r[0]}," + ",".join(str(x) for x in r[1:]))
        return out


class Tables:
    """Aggregates several scenario tables behind run.py's csv_lines
    contract (one bench module, multiple result tables)."""

    def __init__(self, *tables):
        self.tables = tables

    def csv_lines(self) -> List[str]:
        return [line for t in self.tables for line in t.csv_lines()]
