"""Paper Fig. 4: steady-state decode latency (per token) across sequence
lengths — PagedAttention vs the default (contiguous max-length) kernel.

Both paths run the identical model; only the KV layout + attention op
differ.  The paper reports paged consistently at-or-below the default with
near-linear scaling.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Table, timeit
from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.core.attention import (decode_attention,
                                  decode_attention_contiguous)

SEQ_LENS = [128, 256, 512, 1024, 2048]


def run(fast: bool = False):
    cfg = get_smoke("llama2-7b")
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ps = cfg.page_size
    B = 4
    seq_lens = SEQ_LENS[:3] if fast else SEQ_LENS
    t = Table("fig4_decode",
              ["seq_len", "paged_us", "contiguous_us", "paged/contig"])

    paged = jax.jit(lambda q, kp, vp, bt, l: decode_attention(
        q, kp, vp, bt, l, impl="ref"))
    contig = jax.jit(decode_attention_contiguous)

    for S in seq_lens:
        mp = -(-S // ps)
        ks = jax.random.split(jax.random.PRNGKey(S), 5)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (B * mp, ps, Hkv, D))
        vp = jax.random.normal(ks[2], (B * mp, ps, Hkv, D))
        bt = jnp.arange(B * mp, dtype=jnp.int32).reshape(B, mp)
        lens = jnp.full((B,), S, jnp.int32)
        kc = jax.random.normal(ks[3], (B, S, Hkv, D))
        vc = jax.random.normal(ks[4], (B, S, Hkv, D))

        tp = timeit(paged, q, kp, vp, bt, lens)
        tc = timeit(contig, q, kc, vc, lens)
        t.add(S, round(tp * 1e6, 1), round(tc * 1e6, 1), round(tp / tc, 2))
    t.show()
    return t
