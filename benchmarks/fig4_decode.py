"""Paper Fig. 4: steady-state decode latency (per token) across sequence
lengths — PagedAttention vs the default (contiguous max-length) kernel.

Both paths run the identical model; only the KV layout + attention op
differ.  The paper reports paged consistently at-or-below the default with
near-linear scaling.

Also reports the Pallas kernel's grid economics (fixed page_size=16, the
paper's decode page size): ``grid_1p`` is the one-page-per-step baseline
(= max_pages steps per (batch, kv_head) pair), ``grid_blk`` the blocked +
split-K kernel with auto-tuned ``(pages_per_block, num_splits)``, and
``grid_x`` the reduction factor — ≥4× at seq 2048 is the kernel-overhead
win the blocked rewrite targets.  ``pallas_us`` times the real kernel in
interpret mode (CPU): it measures *semantics*, not TPU speed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Table, timeit
from repro.configs import get_smoke
from repro.configs.base import RunConfig
from repro.core.attention import (choose_decode_params, decode_attention,
                                  decode_attention_contiguous)
from repro.kernels.paged_attention.paged_attention import decode_grid_steps

SEQ_LENS = [128, 256, 512, 1024, 2048]
PAGE_SIZE = 16  # the paper's decode page size (fixed for comparability)


def run(fast: bool = False, backend: str = None):
    cfg = get_smoke("llama2-7b")
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ps = PAGE_SIZE
    B = 4
    seq_lens = SEQ_LENS[:3] if fast else SEQ_LENS
    t = Table("fig4_decode",
              ["seq_len", "backend", "paged_us", "contiguous_us",
               "paged/contig", "pallas_us", "ppb", "splits", "grid_blk",
               "grid_1p", "grid_x"])

    paged = jax.jit(lambda q, kp, vp, bt, l: decode_attention(
        q, kp, vp, bt, l, impl="ref"))
    # the kernel axis honours --backend (TPU scalar-prefetch pipeline or
    # GPU/Triton in-kernel gather; None → auto from the platform)
    pallas = jax.jit(lambda q, kp, vp, bt, l: decode_attention(
        q, kp, vp, bt, l, impl="pallas", interpret=True, backend=backend))
    contig = jax.jit(decode_attention_contiguous)
    bk = backend or "auto"

    for S in SEQ_LENS:
        mp = -(-S // ps)
        # grid accounting is free — report it for every seq_len, even the
        # ones --fast skips timing for
        ppb, ns, _ = choose_decode_params(mp, ps, D, backend=backend)
        g1 = decode_grid_steps(mp)
        gb = decode_grid_steps(mp, pages_per_block=ppb, num_splits=ns)
        gx = round(g1 / gb, 2)
        if S not in seq_lens:
            t.add(S, bk, "-", "-", "-", "-", ppb, ns, gb, g1, gx)
            continue

        ks = jax.random.split(jax.random.PRNGKey(S), 5)
        q = jax.random.normal(ks[0], (B, H, D))
        kp = jax.random.normal(ks[1], (B * mp, ps, Hkv, D))
        vp = jax.random.normal(ks[2], (B * mp, ps, Hkv, D))
        bt = jnp.arange(B * mp, dtype=jnp.int32).reshape(B, mp)
        lens = jnp.full((B,), S, jnp.int32)
        kc = jax.random.normal(ks[3], (B, S, Hkv, D))
        vc = jax.random.normal(ks[4], (B, S, Hkv, D))

        tp = timeit(paged, q, kp, vp, bt, lens)
        tc = timeit(contig, q, kc, vc, lens)
        # interpret-mode kernel steps run in python — keep iters low
        tk = timeit(pallas, q, kp, vp, bt, lens, warmup=1, iters=2)
        t.add(S, bk, round(tp * 1e6, 1), round(tc * 1e6, 1),
              round(tp / tc, 2), round(tk * 1e6, 1), ppb, ns, gb, g1, gx)
    t.show()
    return t
