"""Paper §IV scenario (b): mixed-length batch throughput under a fixed
memory budget — the system-level payoff of paging — plus the chunked-
prefill decode-stall sweep (ISSUE 5).

Part 1 (rows ``paged`` / ``contiguous``): same pool bytes for both
engines; the paged engine admits more concurrent requests (no max-length
reservation), so aggregate tokens/s is higher.

Part 2 (rows ``chunk=...``): a long prompt arrives while short requests
are decoding.  With monolithic prefill (``chunk=mono``) the whole prompt
runs in one forward pass and every running decode stalls behind it — the
worst decode step's wall time scales with the prompt length.  With
``prefill_chunk=c`` the prompt caches ``c`` tokens per engine step
interleaved with decode, so per-step decode latency is bounded by the
chunk, not the prompt: ``stall_p99_ms`` / ``stall_max_ms`` collapse and
stay ~flat as the chunk shrinks.

Part 3 (``mixed_batch_robustness`` table, ISSUE 6): the same engine under
deliberate abuse — a request burst against a bounded queue + pool
high-watermark (structured ``Backpressure`` sheds), random cancellations,
per-request deadlines, and a seeded ``FaultPlan`` injecting allocation
failures / NaN logits / transient device errors.  Reports the failure
surface a deployment dashboards on: finished / failed / cancelled / shed
counts, the deadline-miss rate, and preemption/retry totals.
"""

from __future__ import annotations

import random
import time

import jax
import numpy as np

from benchmarks.common import Table, Tables
from repro.configs import get_smoke
from repro.errors import Backpressure, EngineError
from repro.serving import Engine, Request
from repro.serving.faults import FaultPlan, FaultRule
from repro.serving.request import Status


def run_engine(paged: bool, pool_tokens: int, params=None, cfg=None):
    cfg = cfg or get_smoke("llama2-7b")
    slots = 8
    max_seq = 128
    if paged:
        eng = Engine(cfg, params=params, max_slots=slots, max_seq_len=max_seq,
                     pool_tokens=pool_tokens)
    else:
        # contiguous baseline: the same byte budget only fits
        # pool_tokens // max_seq slots (max-length preallocation)
        slots_c = max(1, pool_tokens // max_seq)
        eng = Engine(cfg, params=params, paged=False, max_slots=slots_c,
                     max_seq_len=max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=[1] * int(rng.integers(8, 100)), max_new_tokens=8)
            for _ in range(12)]
    t0 = time.perf_counter()
    eng.generate(reqs, max_steps=2000)
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    return eng, toks / wall, wall


def decode_stalls(params, cfg, prefill_chunk, long_prompt=96, fast=False):
    """Per-step decode latency while a long prompt enters a busy batch.

    Returns (p50_ms, p99_ms, max_ms, steps_to_first_token) over the steps
    in which at least one request decoded.  The long prompt is injected
    after the short decoders are warm, so with monolithic prefill the
    stalled decode steps absorb the whole-prompt forward pass.

    The whole scenario runs once untimed first: eager per-primitive XLA
    compiles (first occurrence of each chunk shape) would otherwise swamp
    the p99 and hide the thing being measured — steady-state stall.
    """
    def scenario():
        eng = Engine(cfg, params=params, max_slots=4, max_seq_len=128,
                     prefill_chunk=prefill_chunk)
        shorts = [Request(prompt=[2 + i] * 6, max_new_tokens=40)
                  for i in range(3)]
        for r in shorts:
            eng.add_request(r)
        for _ in range(3):  # decode path warm before injection
            eng.step()
        long_req = Request(prompt=[7] * long_prompt,
                           max_new_tokens=4 if fast else 8)
        eng.add_request(long_req)
        stall_ms = []
        steps_to_first = None
        steps = 0
        while not long_req.done and steps < 600:
            decoding = any(r.status is Status.RUNNING
                           for r in eng.scheduler.running.values())
            t0 = time.perf_counter()
            eng.step()
            dt = (time.perf_counter() - t0) * 1e3
            steps += 1
            if decoding:
                stall_ms.append(dt)
            if steps_to_first is None and long_req.output:
                steps_to_first = steps
        return np.asarray(stall_ms), steps_to_first

    scenario()  # first run warms every shape on the path (eager compiles)
    arr, steps_to_first = scenario()
    return (float(np.percentile(arr, 50)), float(np.percentile(arr, 99)),
            float(arr.max()), steps_to_first)


def robustness_scenario(params, cfg, fast=False):
    """Serve a faulty, overloaded wave and report the failure surface.

    Deterministic end to end: the arrival process, cancellations and the
    fault plan all draw from pinned seeds, so the reported counts are
    stable run-to-run (modulo wall-clock-free scheduling, which is
    step-indexed here).
    """
    rnd = random.Random(7)
    plan = FaultPlan(seed=7, rules=[
        FaultRule(site="extend", kind="alloc_fail", prob=0.02, times=None),
        FaultRule(site="reserve", kind="alloc_fail", prob=0.01, times=None),
        FaultRule(site="sample", kind="nan", prob=0.005, times=None),
        FaultRule(site="decode", kind="transient", prob=0.01, times=None),
    ])
    eng = Engine(cfg, params=params, max_slots=4, max_seq_len=64,
                 pool_tokens=160, prefill_chunk=8, faults=plan,
                 max_waiting=6, admit_watermark=0.9, max_step_retries=6)
    steps = 120 if fast else 400
    lens = (6, 10, 18, 30)
    accepted, submitted, shed, with_deadline = [], 0, 0, 0
    for _ in range(steps):
        # bursty arrivals: a steady trickle plus occasional floods that
        # overrun the bounded queue (that is what backpressure is for)
        n_arrive = (5 if rnd.random() < 0.08
                    else 1 if rnd.random() < 0.55 else 0)
        for _ in range(n_arrive):
            submitted += 1
            deadline = rnd.randint(10, 40) if rnd.random() < 0.5 else None
            r = Request(prompt=[1 + rnd.randrange(50)] * rnd.choice(lens),
                        max_new_tokens=rnd.randint(2, 8),
                        deadline_steps=deadline)
            try:
                eng.add_request(r)
                accepted.append(r)
                with_deadline += deadline is not None
            except Backpressure:
                shed += 1
        live = [r for r in accepted if not r.done]
        if live and rnd.random() < 0.04:
            eng.cancel_request(rnd.choice(live).rid)
        try:
            eng.step()
        except EngineError:
            pass  # structured by contract; the engine stays serviceable
    # drain the tail with injection off (capture the fire count first:
    # robustness_report reads it from eng.faults, which is now cleared)
    fault_fires = plan.fires
    eng.faults = None
    eng.mgr.plan = FaultPlan([])
    for _ in range(800):
        if all(r.done for r in accepted):
            break
        eng.step()
    rep = eng.robustness_report()
    finished = sum(r.status is Status.FINISHED for r in accepted)
    miss_rate = (rep["deadline_misses"] / with_deadline
                 if with_deadline else 0.0)
    t = Table("mixed_batch_robustness", ["metric", "value"])
    t.add("submitted", submitted)
    t.add("accepted", len(accepted))
    t.add("finished", finished)
    t.add("failed", rep["failed"])
    t.add("cancelled", rep["cancelled"])
    t.add("shed", shed)
    t.add("deadline_misses", rep["deadline_misses"])
    t.add("deadline_miss_rate", round(miss_rate, 3))
    t.add("preempted", rep["preempted"])
    t.add("prefill_stalls", rep["prefill_stalls"])
    t.add("transient_retries", rep["transient_retries"])
    t.add("fault_fires", fault_fires)
    return t


def prefix_cache_workload(params, cfg, enabled: bool, fast=False):
    """Shared-system-prompt wave (prefix-cache PR): every request repeats
    the same 48-token head with a distinct tail — the agent/chat serving
    shape the global prefix cache targets.

    With ``prefix_cache=True`` the first request's pages seed the radix
    trie (progressively, mid-prefill), every later request attaches to
    the shared head and prefills only its tail, and mean TTFT (in engine
    steps — wall-free, so the numbers are stable) drops accordingly.
    The cache-off row is the control: same schedule, zero hits.
    """
    ps = cfg.page_size
    head = [9] * (6 * ps)  # 48-token shared system prompt at page_size 8
    n_reqs = 4 if fast else 8
    eng = Engine(cfg, params=params, max_slots=4, max_seq_len=128,
                 prefill_chunk=8, prefix_cache=enabled)
    reqs = [Request(prompt=head + [20 + i] * (ps + i), max_new_tokens=6)
            for i in range(n_reqs)]
    # staggered arrivals (one request every other step): attach happens
    # at admission, so later arrivals hit the pages earlier requests
    # have already indexed — including mid-prefill (progressive insert)
    pending = list(enumerate(reqs))
    arrive: dict = {}
    ttft: dict = {}
    steps = 0
    while (pending or not all(r.done for r in reqs)) and steps < 4000:
        while pending and pending[0][0] * 2 <= steps:
            _, r = pending.pop(0)
            arrive[r.rid] = steps
            eng.add_request(r)
        eng.step()
        steps += 1
        for r in reqs:
            if r.rid not in ttft and r.output:
                ttft[r.rid] = steps - arrive[r.rid]
    rep = eng.robustness_report()
    if enabled:
        # the PR's acceptance claim, enforced on every bench-fast run
        assert rep["prefix_hits"] > 0, "shared-prompt wave never hit"
        assert all(r.status is Status.FINISHED for r in reqs)
    attempts = rep["prefix_hits"] + rep["prefix_misses"]
    return {
        "hits": rep["prefix_hits"],
        "hit_rate": round(rep["prefix_hits"] / attempts, 3) if attempts else 0.0,
        "hit_tokens": rep["prefix_hit_tokens"],
        "pages_saved": rep["prefix_hit_tokens"] // ps,
        "mean_ttft_steps": round(sum(ttft.values()) / len(ttft), 2),
        "total_steps": steps,
    }


def run(fast: bool = False, prefix_cache: str = None):
    cfg = get_smoke("llama2-7b")
    probe = Engine(cfg, max_slots=1, max_seq_len=8)  # params donor
    t = Table("mixed_batch",
              ["engine", "tok_s", "wall_s", "preemptions", "slots",
               "stall_p50_ms", "stall_p99_ms", "stall_max_ms", "ttft_steps"])
    pool = 512  # tokens of KV budget
    e1, tps1, w1 = run_engine(True, pool, params=probe.params, cfg=cfg)
    t.add("paged", round(tps1, 2), round(w1, 2), e1.scheduler.preempted,
          e1.max_slots, "-", "-", "-", "-")
    e2, tps2, w2 = run_engine(False, pool, params=probe.params, cfg=cfg)
    t.add("contiguous", round(tps2, 2), round(w2, 2), "-", e2.max_slots,
          "-", "-", "-", "-")
    t.add("speedup", round(tps1 / tps2, 2), "", "", "", "", "", "", "")

    # --- chunked-prefill decode-stall sweep -------------------------------
    long_prompt = 64 if fast else 96
    chunks = [None, 32, 16] if fast else [None, 64, 32, 16, 8]
    for c in chunks:
        p50, p99, mx, ttft = decode_stalls(probe.params, cfg, c,
                                           long_prompt=long_prompt,
                                           fast=fast)
        t.add("mono" if c is None else f"chunk={c}", "-", "-", "-", 4,
              round(p50, 2), round(p99, 2), round(mx, 2), ttft)
    t.show()

    # --- fault-tolerance scenario (ISSUE 6) -------------------------------
    rt = robustness_scenario(probe.params, cfg, fast=fast)
    rt.show()

    # --- shared-system-prompt wave, prefix cache on vs off ---------------
    # `--prefix-cache {on,off}` restricts to one row; default runs both
    pt = Table("mixed_batch_prefix_cache",
               ["cache", "hits", "hit_rate", "hit_tokens", "pages_saved",
                "mean_ttft_steps", "total_steps"])
    modes = ((True, "on"), (False, "off"))
    if prefix_cache in ("on", "off"):
        modes = tuple(m for m in modes if m[1] == prefix_cache)
    for enabled, label in modes:
        m = prefix_cache_workload(probe.params, cfg, enabled, fast=fast)
        pt.add(label, m["hits"], m["hit_rate"], m["hit_tokens"],
               m["pages_saved"], m["mean_ttft_steps"], m["total_steps"])
    pt.show()
    return Tables(t, rt, pt)
