"""Paper §IV scenario (b): mixed-length batch throughput under a fixed
memory budget — the system-level payoff of paging.

Same pool bytes for both engines; the paged engine admits more concurrent
requests (no max-length reservation), so aggregate tokens/s is higher.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import Table
from repro.configs import get_smoke
from repro.serving import Engine, Request


def run_engine(paged: bool, pool_tokens: int, params=None, cfg=None):
    cfg = cfg or get_smoke("llama2-7b")
    slots = 8
    max_seq = 128
    if paged:
        eng = Engine(cfg, params=params, max_slots=slots, max_seq_len=max_seq,
                     pool_tokens=pool_tokens)
    else:
        # contiguous baseline: the same byte budget only fits
        # pool_tokens // max_seq slots (max-length preallocation)
        slots_c = max(1, pool_tokens // max_seq)
        eng = Engine(cfg, params=params, paged=False, max_slots=slots_c,
                     max_seq_len=max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=[1] * int(rng.integers(8, 100)), max_new_tokens=8)
            for _ in range(12)]
    t0 = time.perf_counter()
    eng.generate(reqs, max_steps=2000)
    wall = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    return eng, toks / wall, wall


def run(fast: bool = False):
    cfg = get_smoke("llama2-7b")
    probe = Engine(cfg, max_slots=1, max_seq_len=8)  # params donor
    t = Table("mixed_batch",
              ["engine", "tok_s", "wall_s", "preemptions", "slots"])
    pool = 512  # tokens of KV budget
    e1, tps1, w1 = run_engine(True, pool, params=probe.params, cfg=cfg)
    t.add("paged", round(tps1, 2), round(w1, 2), e1.scheduler.preempted,
          e1.max_slots)
    e2, tps2, w2 = run_engine(False, pool, params=probe.params, cfg=cfg)
    t.add("contiguous", round(tps2, 2), round(w2, 2), "-", e2.max_slots)
    t.add("speedup", round(tps1 / tps2, 2), "", "", "")
    t.show()
    return t
