"""Benchmark runner — one module per paper table/figure.

  fig3_latency    latency vs context, cached vs uncached        (Fig. 3)
  fig4_decode     decode ms/token, paged vs contiguous kernel   (Fig. 4)
  fig12_memory    KV memory accounting, paged vs baseline       (Figs. 1-2)
  tbl_allocator   O(1) RESERVE/FREE microbenchmark              (contrib. 1)
  tbl_decode_blocks  pages_per_block × num_splits kernel sweep  (kernel v2)
  tbl_perplexity  numerical equivalence of eval loss            (§IV-B3)
  mixed_batch     throughput under a fixed memory budget        (§IV b)
  roofline        dry-run roofline aggregation                  (§Roofline)

Prints ``name,us_per_call,derived`` CSV at the end (harness contract).
"""

from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list of bench names")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default=None, choices=["tpu", "gpu"],
                    help="restrict kernel benches to one Pallas lowering "
                         "(default: sweep both where the bench supports it)")
    ap.add_argument("--prefix-cache", dest="prefix_cache", default=None,
                    choices=["on", "off"],
                    help="restrict prefix-cache-aware benches to one mode "
                         "(default: benches report both on and off rows)")
    args = ap.parse_args()

    from benchmarks import (fig3_latency, fig4_decode, fig12_memory,
                            mixed_batch, roofline, tbl_allocator,
                            tbl_decode_blocks, tbl_pagesize, tbl_perplexity)
    benches = {
        "fig3_latency": fig3_latency.run,
        "fig4_decode": fig4_decode.run,
        "fig12_memory": fig12_memory.run,
        "tbl_allocator": tbl_allocator.run,
        "tbl_decode_blocks": tbl_decode_blocks.run,
        "tbl_pagesize": tbl_pagesize.run,
        "tbl_perplexity": tbl_perplexity.run,
        "mixed_batch": mixed_batch.run,
        "roofline": roofline.run,
    }
    only = [s for s in args.only.split(",") if s]
    csv = ["name,us_per_call,derived"]
    failed = []
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            kw = {}
            if "backend" in inspect.signature(fn).parameters:
                kw["backend"] = args.backend
            if "prefix_cache" in inspect.signature(fn).parameters:
                kw["prefix_cache"] = args.prefix_cache
            table = fn(fast=args.fast, **kw)
            csv.extend(table.csv_lines())
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    print("\n--- CSV ---")
    print("\n".join(csv))
    if failed:
        print(f"\nFAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
