# Local verification targets — run `make verify` before pushing.
#
#   test        the tier-1 gate, verbatim (pytest -x -q) — halts on the
#               known pre-existing failures below, like the harness does
#   test-clean  tier-1 minus the failures that ship with the seed, so new
#               regressions are actually reachable locally
#   bench-fast  smoke run of the decode benches, incl. the blocked/split-K
#               kernel sweep — catches perf-knob regressions (grid-step
#               blowups, kernel/oracle divergence) that unit tests miss
#   verify      test-clean + bench-fast

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Failing since the seed commit (see CHANGES.md) — not gated on here:
KNOWN_FAIL = \
  --deselect tests/test_engine.py::test_fork_prefix_sharing_is_exact_and_copy_on_write \
  --deselect tests/test_distributed_multi.py::test_ring_attention_matches_dense \
  --deselect tests/test_distributed_multi.py::test_kvp_flash_decoding_matches_local

.PHONY: test test-clean bench-fast verify

test:
	$(PY) -m pytest -x -q

test-clean:
	$(PY) -m pytest -x -q $(KNOWN_FAIL)

bench-fast:
	$(PY) -m benchmarks.run --fast --only fig4_decode,tbl_decode_blocks

verify: test-clean bench-fast
