# Local verification targets — run `make verify` before pushing.
#
#   test        the tier-1 gate, verbatim (pytest -x -q)
#   test-clean  tier-1 minus KNOWN_FAIL (empty since PR 2 fixed every
#               seed-era failure — the two targets currently coincide)
#   bench-fast  smoke run of the decode benches, incl. the blocked/split-K
#               kernel sweep — catches perf-knob regressions (grid-step
#               blowups, kernel/oracle divergence) that unit tests miss
#   verify      test-clean + bench-fast

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Seed-era failures, all fixed in PR 2 (fork tail-copy length bug; the
# jax.lax.axis_size compat shim) — the deselect list is empty and stays
# here only as the hook for any future genuinely-pre-existing failure.
KNOWN_FAIL =

.PHONY: test test-clean bench-fast verify

test:
	$(PY) -m pytest -x -q

test-clean:
	$(PY) -m pytest -x -q $(KNOWN_FAIL)

bench-fast:
	$(PY) -m benchmarks.run --fast --only fig4_decode,tbl_decode_blocks

verify: test-clean bench-fast
