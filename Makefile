# Local verification targets — run `make verify` before pushing.
#
#   test        the tier-1 gate, verbatim (pytest -x -q)
#   test-clean  tier-1 minus KNOWN_FAIL (empty since PR 2 fixed every
#               seed-era failure — the two targets currently coincide)
#   test-gpu-interpret
#               the backend-parametrized kernel + conformance suites
#               filtered to the GPU (Triton) lowering, run through the
#               Pallas interpreter on CPU — the same differential gate
#               the TPU lowering gets, no GPU required (CI runs this as
#               its own matrix leg so a GPU-path break is named in the
#               job list, not buried in the full run)
#   bench-fast  smoke run of the decode benches, incl. the blocked/split-K
#               kernel sweep over both backends — catches perf-knob
#               regressions (grid-step blowups, kernel/oracle divergence)
#               that unit tests miss
#   test-faults the fault-tolerance gate (ISSUE 6): error taxonomy,
#               cancellation in every lifecycle state, backpressure +
#               deadlines, seeded fault injection with bit-identical
#               survivor streams, and the pinned-seed chaos soak (300+
#               engine steps with allocator invariants asserted every
#               step).  Part of the tier-1 run too; its own target so CI
#               names a robustness break.
#   test-prefix the global prefix-cache gate: radix-trie index/attach/
#               evict unit tests, the generalized allocator invariant
#               (refcount == table occurrences + cache residency) under
#               a 250-step admit/attach/evict/preempt/cancel stress, and
#               end-to-end cache-on == cache-off output equality through
#               chunked prefill, stalls, preemption and eviction racing
#               admission.  Part of the tier-1 run too; its own target so
#               CI names a prefix-cache break.
#   lint        replint, the project-native static-analysis suite
#               (`python -m repro.analysis`): Pallas grid/BlockSpec
#               contracts, knob threading, the structured-error taxonomy,
#               tracer safety in kernels/jitted steps, allocator refcount
#               discipline.  Fails on any finding that is neither
#               suppressed in source nor in replint_baseline.json.
#   lint-changed
#               the same rules scoped to .py files changed vs git (dirty
#               worktree + commits since the merge-base with origin/main)
#               — the fast pre-commit/pre-push loop
#   install-hooks
#               point git at the committed .githooks/ directory so every
#               commit runs `make lint-changed` first (bypass one commit
#               with `git commit --no-verify`)
#   verify      lint + test-clean + test-gpu-interpret + test-faults +
#               test-prefix + bench-fast

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Seed-era failures, all fixed in PR 2 (fork tail-copy length bug; the
# jax.lax.axis_size compat shim) — the deselect list is empty and stays
# here only as the hook for any future genuinely-pre-existing failure.
KNOWN_FAIL =

GPU_GATE_SUITES = tests/test_kernels_paged.py tests/test_combine_conformance.py

.PHONY: test test-clean test-gpu-interpret test-chunked test-faults \
        test-prefix bench-fast lint lint-changed install-hooks verify

test:
	$(PY) -m pytest -x -q

test-clean:
	$(PY) -m pytest -x -q $(KNOWN_FAIL)

test-gpu-interpret:
	$(PY) -m pytest -x -q $(GPU_GATE_SUITES) -k "gpu"

# the chunked-prefill equivalence gate (ISSUE 5): prefix-aware prefill
# kernels vs oracle (both backends) + chunked == monolithic logits/outputs
# across chunk sizes, preemption, and mid-prefill stalls.  Part of the
# tier-1 run too; kept as its own target so CI names a chunking break.
test-chunked:
	$(PY) -m pytest -x -q tests/test_chunked_prefill.py

# the fault-tolerance gate (ISSUE 6).  The chaos soak inside runs with a
# pinned seed (SOAK_SEED in the suite) so every CI run replays the same
# 300+-step admit/cancel/fail/preempt/stall schedule byte-for-byte.
test-faults:
	$(PY) -m pytest -x -q tests/test_faults.py

# the global prefix-cache gate (radix page sharing across requests):
# lossless-hit equality, LRU eviction, and the cache-aware allocator
# invariants under stress.
test-prefix:
	$(PY) -m pytest -x -q tests/test_prefix_cache.py

bench-fast:
	$(PY) -m benchmarks.run --fast --only fig4_decode,tbl_decode_blocks,mixed_batch

# replint: the cross-layer contracts, proven at lint time.  See
# `python -m repro.analysis --list-rules` and README "Static analysis".
lint:
	$(PY) -m repro.analysis

lint-changed:
	$(PY) -m repro.analysis --changed-only

install-hooks:
	git config core.hooksPath .githooks
	@echo "pre-commit hook installed (runs 'make lint-changed')"

verify: lint test-clean test-gpu-interpret test-faults test-prefix bench-fast
